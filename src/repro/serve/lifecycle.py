"""Explicit slot lifecycle for the continuous-batching scheduler.

FaaSKeeper's lesson (PAPER.md §3-4) applied to the decode plane: compute is
ephemeral and reclaimable, durable state belongs in storage.  A decode slot
is the unit of reclaimable compute, and its lifecycle — previously implicit
in scattered ``admitting`` flags and completion-time frees — is an explicit
state machine::

    EMPTY ──▶ ADMITTING ──▶ ACTIVE ──▶ DRAINED ──▶ EMPTY
                 ▲            │  ▲        │
                 │   preempt  ▼  │        ▼ park (session retention)
                 │        PREEMPTED ──▶ RESTORING
                 │                        ▲
                 └──────── PARKED ────────┘-ish    (see below)

* **EMPTY** — no request; every per-slot cache row cleared / unmapped.
* **ADMITTING** — prompt chunks landing (one per step); masked out of
  sampling, token writes, and cache-row updates.
* **ACTIVE** — decoding one token per step.
* **PREEMPTED** — KV pages offloaded to the object store and freed back to
  the pool; the slot keeps its row (recurrent state, lengths, output ring
  stay frozen under the decode mask) but holds **zero pool pages and zero
  reservation** — the capacity a long-running session was pinning is
  reclaimed.
* **RESTORING** — page blobs re-allocated and injected chunk-by-chunk,
  interleaved with the batch's decode steps exactly like prefill chunks.
* **DRAINED** — request completed this step; transitions to EMPTY when the
  slot is released for reuse, or — with session parking on — to PARKED.
* **PARKED** — the FaaSKeeper session move: the request completed but its
  session's KV pages (and recurrent rows) stay resident, owned by the
  scheduler's parked-session record, so the session's *next* request maps
  them shared (copy-on-write) and prefills only its new tail tokens.  A
  parked slot is masked out of decode like EMPTY, pins **zero
  reservation**, and is reclaimable: a new admission may evict it (rows
  snapshotted to the parked record; under pool pressure the pages offload
  through the page-blob store).  PARKED -> ADMITTING is the in-place
  unpark; PARKED -> EMPTY is eviction or TTL expiry.

Transitions outside :data:`TRANSITIONS` raise — the scheduler cannot
silently re-grow the flag soup.  ``reset()`` (crash recovery) is the one
escape hatch: any state force-returns to EMPTY via :meth:`Slot.force_empty`.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, List, Optional


class SlotState(enum.Enum):
    EMPTY = "empty"
    ADMITTING = "admitting"
    ACTIVE = "active"
    PREEMPTED = "preempted"
    RESTORING = "restoring"
    DRAINED = "drained"
    PARKED = "parked"


# Legal transitions.  RESTORING -> PREEMPTED is deliberately absent: a
# restore, once funded by the reservation gate, always runs to completion
# (re-preempting a half-injected slot would interleave two blob generations).
# PARKED -> ACTIVE is likewise absent: an unpark always re-enters through
# ADMITTING (at least the last history token is re-fed to seed sampling).
TRANSITIONS: Dict[SlotState, tuple] = {
    SlotState.EMPTY: (SlotState.ADMITTING,),
    SlotState.ADMITTING: (SlotState.ACTIVE,),
    SlotState.ACTIVE: (SlotState.PREEMPTED, SlotState.DRAINED),
    SlotState.PREEMPTED: (SlotState.RESTORING,),
    SlotState.RESTORING: (SlotState.ACTIVE,),
    SlotState.DRAINED: (SlotState.EMPTY, SlotState.PARKED),
    SlotState.PARKED: (SlotState.ADMITTING, SlotState.EMPTY),
}


class IllegalTransition(RuntimeError):
    pass


@dataclasses.dataclass
class Slot:
    """One decode slot: state + the per-request bookkeeping that used to
    live in an ad-hoc dict.  The device never sees this object — it is the
    host-side mirror the scheduler plans against."""

    index: int
    state: SlotState = SlotState.EMPTY

    req: Any = None                    # the admitted _Request
    chunks: Optional[List] = None      # pending prompt chunks (ADMITTING)
    chunk_i: int = 0
    len: int = 0                       # host mirror of the slot's live length
    pages: List[int] = dataclasses.field(default_factory=list)   # owned (rc 1 at alloc)
    shared: List[int] = dataclasses.field(default_factory=list)  # share-mapped refs
    need: int = 0                      # worst-case page count (reservation)
    reused: int = 0                    # prompt tokens served from shared pages
    n_out: int = 0
    admitted_step: int = 0             # step the request entered the slot
    submitted_step: int = 0
    active_since: int = 0              # step the slot last became ACTIVE

    # -- offload bookkeeping (PREEMPTED / RESTORING) ------------------------
    blob_key: Optional[str] = None
    blob_pidx: List[int] = dataclasses.field(default_factory=list)
    blob: Any = None                   # host-side page blob during restore
    restore_i: int = 0                 # pages injected so far
    preempts: int = 0                  # times this request was preempted

    # -- parking bookkeeping (PARKED) ---------------------------------------
    session: Optional[str] = None      # session whose parked record owns this slot
    parked_step: int = 0               # step the slot entered PARKED (TTL clock)

    # -- speculative-decoding bookkeeping (draft-and-verify, ACTIVE) --------
    # The draft model keeps its own per-slot ring cache; these host mirrors
    # track how much of the *canonical* stream (prompt + accepted tokens) the
    # draft has consumed, and which canonical tokens it still has to catch up
    # on before proposing the next window.  Rejected proposals advance none
    # of this — the draft row's device length is rewound to ``draft_len``
    # after every verify round.
    draft_len: int = 0                 # canonical tokens the draft consumed
    spec_pending: List[int] = dataclasses.field(default_factory=list)
    # ^ canonical tokens the draft must consume next round (prompt + first
    #   sampled token at admission; 1-2 tokens per round thereafter)
    spec_last: int = 0                 # host mirror of last_tokens[index] (the
    # newest canonical token, not yet consumed by the target — the hybrid
    # rollback replay re-feeds it)

    def to(self, new_state: SlotState) -> "Slot":
        if new_state not in TRANSITIONS[self.state]:
            raise IllegalTransition(
                f"slot {self.index}: {self.state.value} -> {new_state.value} "
                f"(legal: {[s.value for s in TRANSITIONS[self.state]]})")
        self.state = new_state
        return self

    def force_empty(self) -> "Slot":
        """Crash-recovery escape hatch: wipe the slot back to EMPTY from any
        state.  Only ``reset()`` may use this."""
        self.__init__(index=self.index)
        return self

    # -- predicates the scheduler plans with --------------------------------

    @property
    def empty(self) -> bool:
        return self.state is SlotState.EMPTY

    @property
    def occupied(self) -> bool:
        return self.state is not SlotState.EMPTY

    @property
    def parked(self) -> bool:
        return self.state is SlotState.PARKED

    @property
    def working(self) -> bool:
        """Carrying an in-flight request (PARKED retention is not work —
        ``busy()`` must not spin on it)."""
        return self.state not in (SlotState.EMPTY, SlotState.PARKED)

    @property
    def decoding(self) -> bool:
        """In the batched decode step's active mask this step."""
        return self.state is SlotState.ACTIVE

    def age(self, step: int) -> int:
        """Steps spent ACTIVE since last (re)activation — the idleness
        signal the preemption policy ranks victims by."""
        return step - self.active_since
