"""Elastic scale-to-zero fleet of disposable ``DecodeScheduler`` workers.

The FaaSKeeper thesis applied to LLM serving: a scheduler worker is a
*function*, not a server.  Everything a worker must not lose already lives
outside it — preempt spills and parked-session journals in the shared
:class:`~repro.core.storage.PageBlobStore`, shared prefixes in the
content-addressed index journal (``index/<chain-hash>`` blobs), and the
cross-request session directory as ``park-meta/<session>`` records — so the
controller can spawn workers on queue bursts, drain-then-park them on
scale-down, kill them on crashes, and scale the whole fleet to zero, with a
cold start rebuilding a worker from storage alone.

Coordination uses the repo's own primitives: each worker holds an ephemeral
znode via :class:`~repro.coord.membership.MembershipService` (heartbeat
eviction is the crash detector — a wedged worker stops renewing and the
controller reaps it when its znode disappears), and crash points are driven
by :class:`~repro.core.simcloud.FaultPlan` under the function names
``fleet:<worker-id>`` at the labels ``mid-decode``, ``mid-restore`` and
``mid-park``.

Durable-state protocol (what survives which failure):

- **Worker drain** offloads every parked journal's pages to the shared
  store (`park/<ns><session>/...` KV blob), then commits a
  ``park-meta/<session>`` record pointing at it.  The meta PUT is the
  commit point: a crash between the two leaves an orphaned KV blob that
  the controller garbage-collects — the session re-prefills (correct,
  just slower).
- **Worker crash** loses everything resident (pool pages, slots, its
  in-flight requests) but nothing committed: in-flight requests are
  requeued fleet-level in original submit order, metas keep their KV
  blobs alive across the GC of the dead worker's namespaced keys, and
  journaled index entries were already content-addressed blobs.
- **Cold start** re-adopts journaled index pages into the fresh pool and
  lazily re-attaches ``park-meta`` journals when their session's next
  request is routed — prefilling only tokens the journal does not cover.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from .lifecycle import SlotState
from .scheduler import CompletedRequest, DecodeScheduler, ParkedSession

PARK_META_PREFIX = "park-meta/"
# nominal serialized overhead of a park-meta record beyond its arrays
# (key, lengths, blob pointer) — billed so the directory is not free
_META_OVERHEAD_BYTES = 256


@dataclasses.dataclass
class FleetRequest:
    """A request queued fleet-level (not yet owned by any worker)."""

    session: str
    request_id: str
    prompt: np.ndarray
    max_new: int
    seq: int = 0                # fleet-wide submit order (requeue key)


@dataclasses.dataclass
class WorkerEvent:
    """Lifecycle event feed the frontend drains for per-worker billing."""

    kind: str                   # spawn | retire | crash | evicted
    worker_id: str
    step: int
    busy_steps: int = 0
    from_zero: bool = False     # spawn while the fleet was at zero workers


class FleetWorker:
    """One live worker: a recycled ``DecodeScheduler`` incarnation plus its
    membership handle and scaling bookkeeping."""

    def __init__(self, worker_id: str, sched: DecodeScheduler,
                 incarnation: int, spawned_step: int):
        self.worker_id = worker_id
        self.sched = sched
        self.incarnation = incarnation
        self.spawned_step = spawned_step
        self.handle = None              # membership WorkerHandle
        self.state = "running"          # running | draining | wedged
        self.idle_steps = 0
        self.busy_steps = 0


class FleetController:
    """N disposable scheduler workers behind one dispatch queue.

    ``schedulers`` is the prebuilt worker pool (compile once, reuse across
    incarnations — a "spawn" is a FaaS container start, not a new program).
    All of them must share one blob store and have ``park_sessions`` and
    (for index survival) ``index_journal`` enabled.  ``max_workers`` is
    ``len(schedulers)``.
    """

    def __init__(self, schedulers: Sequence[DecodeScheduler], *,
                 min_workers: int = 0, scale_to_zero: bool = True,
                 drain_idle_steps: int = 4, membership=None, faults=None):
        if not schedulers:
            raise ValueError("a fleet needs at least one worker scheduler")
        store = schedulers[0].blob_store
        for s in schedulers:
            if s.blob_store is not store:
                raise ValueError("fleet workers must share one blob store "
                                 "(it is the durable substrate)")
        self.blob_store = store
        self._pool: List[DecodeScheduler] = list(schedulers)
        self.max_workers = len(self._pool)
        self.min_workers = min(min_workers, self.max_workers)
        self.scale_to_zero = bool(scale_to_zero)
        self.drain_idle_steps = drain_idle_steps
        self.membership = membership
        self.faults = faults

        self.workers: Dict[str, FleetWorker] = {}
        self.pending: List[FleetRequest] = []
        self._inflight: Dict[str, Tuple[str, FleetRequest]] = {}
        self._incarnations: Dict[str, int] = {}
        self._seq = 0
        self.steps = 0
        self.events: List[WorkerEvent] = []
        self.last_decoded_workers = 0   # workers that decoded in the last tick

        # gauges
        self.spawns = 0
        self.retires = 0
        self.crashes = 0
        self.evictions = 0
        self.cold_starts_from_zero = 0
        self.meta_puts = 0
        self.meta_adoptions = 0
        self.meta_dropped = 0
        self.gc_blobs = 0

    # -- submission ----------------------------------------------------------

    def submit(self, session: str, request_id: str, prompt,
               max_new: int) -> None:
        """Queue a request fleet-level; routing happens inside ``step()``
        (per-session stickiness to the worker holding the session's state,
        least-loaded otherwise, held when nothing can take it)."""
        self.pending.append(FleetRequest(
            session=session, request_id=request_id,
            prompt=np.asarray(prompt), max_new=max_new, seq=self._seq))
        self._seq += 1

    def busy(self) -> bool:
        return (bool(self.pending) or bool(self._inflight)
                or any(w.sched.busy() for w in self.workers.values()))

    def free_slots(self) -> int:
        """Admission capacity a queue claim can target: free slots on
        running workers plus whole-worker capacity still spawnable."""
        free = sum(w.sched.free_slots() for w in self.workers.values()
                   if w.state == "running")
        free += sum(s.n_slots for s in self._pool)
        return free

    def wants_more(self) -> bool:
        return self.free_slots() > 0

    def live_workers(self) -> int:
        return len(self.workers)

    def _all_scheds(self) -> List[DecodeScheduler]:
        return [w.sched for w in self.workers.values()] + self._pool

    def prefill_tokens(self) -> int:
        """Fleet-wide prefill tokens (counters survive worker recycling, so
        the sum over live workers + the warm pool is monotone)."""
        return sum(s.prefill_tokens for s in self._all_scheds())

    def slot_steps(self) -> int:
        """Fleet-wide slot-step count (decode work units), same monotone
        aggregation as :meth:`prefill_tokens`."""
        return sum(s.slot_steps for s in self._all_scheds())

    # -- fault injection -----------------------------------------------------

    def _crash(self, w: FleetWorker, point: str) -> bool:
        if self.faults is None:
            return False
        return self.faults.should_crash(f"fleet:{w.worker_id}", point)

    def fail_worker(self, worker_id: str) -> None:
        """Wedge a worker (frozen process): it stops heartbeating and stops
        making progress, but its znode lingers until the membership sweep
        evicts it — only then does the controller reap and requeue.  This is
        the crash-*detection* path, vs the fail-stop `FaultPlan` crashes
        the controller observes synchronously."""
        w = self.workers[worker_id]
        w.state = "wedged"
        if self.membership is not None and w.handle is not None:
            self.membership.fail(w.handle)

    def crash_worker(self, worker_id: str) -> None:
        """Fail-stop crash, observed immediately (the dispatch layer sees
        the connection drop): requeue its work, GC its keys, free its id."""
        self._kill(self.workers[worker_id], "crash")

    # -- scaling -------------------------------------------------------------

    def scale_up(self) -> Optional[FleetWorker]:
        """Force one spawn (burst hint); returns None at max_workers."""
        if not self._pool:
            return None
        return self._spawn()

    def scale_down(self, worker_id: Optional[str] = None) -> Optional[str]:
        """Begin drain-then-park on one running worker (forced scale-down).
        The worker finishes its in-flight requests, externalizes every
        parked journal to the shared store, then leaves membership and
        returns its scheduler to the warm pool."""
        if worker_id is None:
            running = [w for w in self.workers.values()
                       if w.state == "running"]
            if not running:
                return None
            worker_id = min(running, key=lambda w: self._load(w)).worker_id
        self.workers[worker_id].state = "draining"
        return worker_id

    def _load(self, w: FleetWorker) -> int:
        return sum(1 for wid, _ in self._inflight.values()
                   if wid == w.worker_id)

    def _spawn(self) -> FleetWorker:
        sched = self._pool.pop()
        k = 0
        while f"w{k}" in self.workers:
            k += 1
        wid = f"w{k}"
        inc = self._incarnations.get(wid, 0) + 1
        self._incarnations[wid] = inc
        sched.blob_ns = f"{wid}.{inc}/"
        from_zero = not self.workers
        w = FleetWorker(wid, sched, inc, self.steps)
        if self.membership is not None:
            # re-using the lowest free id means a restart-after-crash joins
            # before the heartbeat evicted its predecessor's ephemeral —
            # the stale-znode takeover branch of MembershipService.join
            w.handle = self.membership.join(wid)
        self.workers[wid] = w
        # cold start: rebuild the prefix index from the journal blobs
        sched.adopt_index_journal()
        self.spawns += 1
        if from_zero:
            self.cold_starts_from_zero += 1
        self.events.append(WorkerEvent("spawn", wid, self.steps,
                                       from_zero=from_zero))
        return w

    def _autoscale(self) -> None:
        floor = self.min_workers
        if not self.scale_to_zero:
            floor = max(floor, 1)
        # hold the floor (an always-warm reserve when scale-to-zero is off)
        while (sum(1 for w in self.workers.values()
                   if w.state == "running") < floor and self._pool):
            self._spawn()
        # up: queued work the running workers cannot absorb
        free = sum(w.sched.free_slots() for w in self.workers.values()
                   if w.state == "running")
        while len(self.pending) > free and self._pool:
            free += self._spawn().sched.n_slots
        # down: workers idle past the threshold, beyond the floor
        for w in list(self.workers.values()):
            if w.state != "running":
                continue
            if w.sched.busy() or self._load(w) or self.pending:
                w.idle_steps = 0
                continue
            w.idle_steps += 1
            running = sum(1 for x in self.workers.values()
                          if x.state == "running")
            if w.idle_steps > self.drain_idle_steps and running > floor:
                w.state = "draining"

    # -- durable session directory (park-meta records) -----------------------

    def _put_meta(self, rec: ParkedSession) -> None:
        """Commit an externalized journal to the directory.  The record is
        pure host data + a blob pointer after ``externalize_session``; this
        PUT is what makes the session survive the worker."""
        meta = {"session": rec.session, "history": rec.history,
                "consumed": rec.consumed, "page_row": rec.page_row,
                "state": rec.state, "blob_key": rec.blob_key,
                "blob_pidx": list(rec.blob_pidx)}
        nbytes = _META_OVERHEAD_BYTES + rec.history.nbytes
        if rec.state is not None:
            nbytes += sum(
                leaf.size * leaf.dtype.itemsize
                for leaf in jax.tree_util.tree_leaves(rec.state))
        self.blob_store.put(PARK_META_PREFIX + rec.session, meta, nbytes)
        self.meta_puts += 1

    def _try_adopt_meta(self, w: FleetWorker, session: str) -> bool:
        """Route a directory journal to the worker about to serve its
        session.  A dangling pointer (crash-during-drain GC'd the KV blob,
        or a live worker superseded it) drops the meta — the session falls
        back to a full re-prefill."""
        key = PARK_META_PREFIX + session
        if key not in self.blob_store.blobs:
            return False
        meta = self.blob_store.get(key)
        if meta["blob_key"] not in self.blob_store.blobs:
            self.blob_store.delete(key)
            self.meta_dropped += 1
            return False
        rec = ParkedSession(
            session=session, history=np.asarray(meta["history"]),
            consumed=int(meta["consumed"]),
            page_row=np.asarray(meta["page_row"]), pages=[], slot=None,
            state=meta["state"], blob_key=meta["blob_key"],
            blob_pidx=list(meta["blob_pidx"]))
        w.sched.adopt_parked(rec)
        self.meta_adoptions += 1
        # the meta stays until this session next completes: if the adopter
        # crashes mid-restore, the journal must still be re-adoptable
        return True

    def _iter_metas(self) -> Dict[str, dict]:
        # direct (unbilled) view — controller bookkeeping, not data-path IO
        return {k: self.blob_store.blobs[k] for k in self.blob_store.blobs
                if k.startswith(PARK_META_PREFIX)}

    # -- worker death --------------------------------------------------------

    def _kill(self, w: FleetWorker, reason: str) -> None:
        """Remove a worker (crash / eviction / completed drain): requeue its
        in-flight requests in original submit order, garbage-collect its
        namespaced transient blobs (everything except KV blobs a committed
        ``park-meta`` record still points at), settle membership, and recycle
        the scheduler — without touching shared durable state."""
        back = sorted((req for wid, req in self._inflight.values()
                       if wid == w.worker_id), key=lambda r: r.seq)
        for req in back:
            del self._inflight[req.request_id]
        self.pending = sorted(self.pending + back, key=lambda r: r.seq)
        ns = w.sched.blob_ns
        protected = {m["blob_key"] for m in self._iter_metas().values()}
        for key in list(self.blob_store.blobs):
            if (key.startswith((f"park/{ns}", f"kv/{ns}"))
                    and key not in protected):
                self.blob_store.delete(key)
                self.gc_blobs += 1
        if self.membership is not None and w.handle is not None:
            if reason == "retire":
                self.membership.leave(w.handle)
            elif reason == "crash":
                # fail-stop: the znode lingers until the heartbeat sweep
                # (or a restart-takeover) clears it
                self.membership.fail(w.handle)
        w.sched.reset(clear_blob_store=False)
        w.sched.blob_ns = ""
        self._pool.append(w.sched)
        del self.workers[w.worker_id]
        if reason == "crash":
            self.crashes += 1
        elif reason == "evicted":
            self.evictions += 1
        elif reason == "retire":
            self.retires += 1
        self.events.append(WorkerEvent(reason, w.worker_id, self.steps,
                                       busy_steps=w.busy_steps))

    def _reap_evicted(self) -> None:
        """Heartbeat-eviction crash detection: any worker whose ephemeral
        znode vanished (the membership sweep removed a failed session) is
        dead to the fleet, whatever its host object thinks."""
        if self.membership is None or not self.workers:
            return
        alive = set(self.membership.members())
        for w in list(self.workers.values()):
            if w.handle is not None and w.worker_id not in alive:
                self._kill(w, "evicted")

    def _finish_drain(self, w: FleetWorker) -> None:
        """Drain complete (no in-flight work): externalize every parked
        journal — KV blob first, then the park-meta commit — and retire.
        The ``mid-park`` crash point sits between the two PUTs: a crash
        there orphans the KV blob (GC'd in the kill path) and the session
        re-prefills on its next request."""
        sched = w.sched
        for session in list(sched._parked):
            rec = sched.externalize_session(session)
            if self._crash(w, "mid-park"):
                self._kill(w, "crash")
                return
            self._put_meta(rec)
        self._kill(w, "retire")

    # -- routing -------------------------------------------------------------

    def _home_worker(self, session: str) -> Optional[FleetWorker]:
        for wid, req in self._inflight.values():
            if req.session == session:
                return self.workers[wid]
        for w in self.workers.values():
            if (session in w.sched._active_sessions
                    or session in w.sched._parked):
                return w
        return None

    def _pick_worker(self) -> Optional[FleetWorker]:
        ready = [w for w in self.workers.values()
                 if w.state == "running" and w.sched.free_slots() > 0]
        if not ready:
            return None
        return min(ready, key=lambda w: (self._load(w), w.worker_id))

    def _dispatch(self) -> None:
        held: set = set()
        still: List[FleetRequest] = []
        for req in self.pending:
            if req.session in held:       # per-session FIFO across the fleet
                still.append(req)
                continue
            w = self._home_worker(req.session)
            if w is None:
                w = self._pick_worker()
                if w is not None:
                    self._try_adopt_meta(w, req.session)
            if w is None or w.state != "running":
                held.add(req.session)
                still.append(req)
                continue
            w.sched.submit(req.session, req.request_id, req.prompt,
                           req.max_new)
            self._inflight[req.request_id] = (w.worker_id, req)
        self.pending = still

    # -- the fleet tick ------------------------------------------------------

    def step(self) -> List[CompletedRequest]:
        """One controller tick: reap evictions, autoscale, route queued
        work, step every live worker (fault points consulted first), finish
        drains.  Wedged workers do not step — a frozen process makes no
        progress; its work comes back only through heartbeat eviction."""
        self._reap_evicted()
        self._autoscale()
        self._dispatch()
        fins: List[CompletedRequest] = []
        self.last_decoded_workers = 0
        for w in list(self.workers.values()):
            if w.state == "wedged" or not w.sched.busy():
                continue
            slots = w.sched.slots
            restoring = any(
                s.state is SlotState.RESTORING
                or (s.state is SlotState.ADMITTING and s.reused)
                for s in slots)
            if restoring and self._crash(w, "mid-restore"):
                self._kill(w, "crash")
                continue
            if any(s.decoding for s in slots) and self._crash(w, "mid-decode"):
                self._kill(w, "crash")
                continue
            w.busy_steps += 1
            s0 = w.sched.slot_steps
            fins_w = w.sched.step()
            if w.sched.slot_steps > s0:
                self.last_decoded_workers += 1
            for fin in fins_w:
                self._inflight.pop(fin.request_id, None)
                # the live worker's fresh park supersedes any directory
                # journal for this session (no-op when absent)
                self.blob_store.delete(PARK_META_PREFIX + fin.session)
                fins.append(fin)
        for w in list(self.workers.values()):
            if (w.state == "draining" and not w.sched.busy()
                    and not self._load(w)):
                self._finish_drain(w)
        self.steps += 1
        return fins

    def abort(self) -> None:
        """Controller crash (the serving invocation died): every live worker
        is gone with it — fail-stop kill each one (requeue + GC + membership
        fail), then drop the fleet-level queue; the dispatch queue redelivers
        the originating messages and dedup keeps completions exactly-once.
        Committed durable state (park-metas, index journal blobs) survives."""
        for w in list(self.workers.values()):
            self._kill(w, "crash")
        self.pending = []
        self._inflight.clear()

    def drain_events(self) -> List[WorkerEvent]:
        ev, self.events = self.events, []
        return ev

    def drain_offload_ops(self) -> list:
        return self.blob_store.drain_ops()

    def reset(self, faults=None) -> None:
        """Back to an empty fleet over an empty store (test-sequence reuse;
        NOT a crash path — crashes go through ``_kill``)."""
        for w in list(self.workers.values()):
            if self.membership is not None and w.handle is not None:
                self.membership.leave(w.handle)
            w.sched.reset(clear_blob_store=False)
            w.sched.blob_ns = ""
            self._pool.append(w.sched)
        self.workers.clear()
        for s in self._pool:
            s.index_journal_puts = 0
            s.index_adopted = 0
        self.blob_store.clear()
        self.blob_store.drain_ops()
        self.pending = []
        self._inflight.clear()
        self._incarnations.clear()
        self.events = []
        self._seq = 0
        self.steps = 0
        self.faults = faults
        for name in ("spawns", "retires", "crashes", "evictions",
                     "cold_starts_from_zero", "meta_puts", "meta_adoptions",
                     "meta_dropped", "gc_blobs"):
            setattr(self, name, 0)

    # -- cross-worker ledger audit ------------------------------------------

    def audit(self) -> None:
        """Fleet-wide invariants on top of each worker's own ``audit()``:

        - no session is live (active or parked) on two workers at once;
        - every live blob pointer (preempt spill, parked journal) resolves
          in the shared store;
        - every transient ``kv/``/``park/`` blob in the store is owned by
          exactly one live referent — plus, for an adopted journal, its
          not-yet-superseded ``park-meta`` record (orphans are GC'd at kill
          time, so nothing accretes);
        - every in-flight request maps to a live worker.
        """
        store = self.blob_store
        owner: Dict[str, str] = {}
        for wid, w in self.workers.items():
            w.sched.audit()
            for sess in (set(w.sched._active_sessions)
                         | set(w.sched._parked)):
                prev = owner.setdefault(sess, wid)
                assert prev == wid, (
                    f"session {sess!r} live on workers {prev} and {wid}")
        referenced: Counter = Counter()
        for w in self.workers.values():
            for sl in w.sched.slots:
                if sl.blob_key:
                    assert sl.blob_key in store.blobs, (
                        f"slot spill {sl.blob_key!r} missing from store")
                    referenced[sl.blob_key] += 1
            for rec in w.sched._parked.values():
                if rec.blob_key:
                    assert rec.blob_key in store.blobs, (
                        f"parked journal {rec.blob_key!r} missing from store")
                    referenced[rec.blob_key] += 1
        meta_refs = Counter(m["blob_key"]
                            for m in self._iter_metas().values())
        for key in store.blobs:
            if key.startswith("kv/"):
                assert referenced[key] == 1, (
                    f"preempt spill {key!r} has {referenced[key]} owners")
            elif key.startswith("park/"):
                n = referenced[key] + meta_refs[key]
                assert 1 <= n <= 2, (
                    f"park journal {key!r} has {n} owners "
                    f"(records {referenced[key]}, metas {meta_refs[key]})")
        for rid, (wid, _req) in self._inflight.items():
            assert wid in self.workers, (
                f"in-flight request {rid!r} maps to dead worker {wid}")

    # -- reporting -----------------------------------------------------------

    def fleet_stats(self) -> Dict[str, float]:
        return {
            "fleet_steps": self.steps,
            "workers_live": len(self.workers),
            "workers_max": self.max_workers,
            "spawns": self.spawns,
            "retires": self.retires,
            "crashes": self.crashes,
            "evictions": self.evictions,
            "cold_starts_from_zero": self.cold_starts_from_zero,
            "meta_puts": self.meta_puts,
            "meta_adoptions": self.meta_adoptions,
            "meta_dropped": self.meta_dropped,
            "gc_blobs": self.gc_blobs,
            "index_journal_puts": sum(
                s.index_journal_puts for s in self._all_scheds()),
            "index_adopted": sum(
                s.index_adopted for s in self._all_scheds()),
            "fleet_prefill_tokens": self.prefill_tokens(),
            "fleet_slot_steps": self.slot_steps(),
        }
