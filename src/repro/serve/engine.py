"""Serving steps: prefill + single-token decode against a KV/state cache.

``make_decode_step`` is what the decode_* / long_* dry-run cells lower: one
new token per sequence with a cache of ``seq_len`` (per the assignment, these
cells lower ``serve_step``, not ``train_step``).

Every factory takes optional ``policy`` / ``cache_specs`` keywords for
mesh-sharded execution (the scheduler's ``mesh=`` mode): ``policy`` is a
:class:`repro.dist.sharding.ShardingPolicy` installed *inside* the traced
body — jit executes the Python function once per trace, so the context
manager is live exactly while the model constrains activations — and
``cache_specs`` is the cache's PartitionSpec pytree, re-asserted on the
returned cache so the carried decode state never drifts off its storage
layout between steps.  Both default to None: the single-device call sites
are byte-for-byte the old factories.
"""

from __future__ import annotations

import contextlib
from typing import Callable, Optional

import jax
import jax.numpy as jnp


def _policy_scope(policy):
    """Context installing ``policy`` for the trace; ambient pass-through when
    the caller has no policy (None must not *clear* an outer policy here —
    dry-run traces under an outer ``activation_sharding``)."""
    if policy is None:
        return contextlib.nullcontext()
    from ..dist import sharding as shd

    return shd.activation_sharding(policy)


def _constrain_cache(cache, cache_specs):
    """Pin the returned cache pytree to its storage PartitionSpecs (identity
    without specs or without an active policy mesh)."""
    if cache_specs is None:
        return cache
    from ..dist import sharding as shd

    return shd.constrain_tree(cache, cache_specs)


def make_decode_step(model, *, policy=None, cache_specs=None) -> Callable:
    def serve_step(params, cache, tokens):
        with _policy_scope(policy):
            logits, new_cache = model.decode_step(params, cache, tokens)
            next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            new_cache = _constrain_cache(new_cache, cache_specs)
        return next_token, logits, new_cache

    return serve_step


def make_chunk_step(model, *, policy=None, cache_specs=None) -> Callable:
    """Prefill one prompt chunk for a *single slot* of a batched paged cache.

    The chunk runs as a B=1 forward against the shared page pool: per-slot
    leaves (lengths, recurrent states, page-table rows) are sliced at
    ``slot``, the pool is passed through whole (the slot exclusively owns the
    pages its table maps, so the scatter is race-free against the other
    slots' decode traffic), and the updated row is scattered back.  ``slot``
    is traced, so one compile covers every slot at a given chunk length.
    """
    from ..models import kvcache

    def chunk_step(params, cache, tokens, slot):
        with _policy_scope(policy):
            one = kvcache.cache_slot_view(cache, slot)
            logits, one_new = model.decode_step(params, one, tokens)
            new_cache = kvcache.cache_insert_slot(cache, one_new, slot)
            new_cache = _constrain_cache(new_cache, cache_specs)
        return logits, new_cache

    return chunk_step


def make_draft_step(model, *, policy=None, cache_specs=None) -> Callable:
    """Batched S=1 greedy step for the *draft* model of a speculative
    decoder: one proposed token per masked-in slot against the draft's own
    per-slot ring cache.  Inactive rows keep their state and their last
    token — same masking contract as the target's decode step."""
    from ..models import kvcache

    def draft_step(params, cache, last_tokens, active):
        with _policy_scope(policy):
            logits, new_cache = model.decode_step(params, cache, last_tokens[:, None])
            new_cache = kvcache.mask_slot_rows(new_cache, cache, active)
            new_cache = _constrain_cache(new_cache, cache_specs)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return new_cache, jnp.where(active, tok, last_tokens)

    return draft_step


def make_draft_catchup_step(model, *, policy=None, cache_specs=None) -> Callable:
    """Batched draft catch-up on the canonical token stream: every masked-in
    slot replays the canonical tokens its draft ring has not consumed — ONE
    dispatch per verify round instead of one B=1 chunk per slot.

    ``tokens`` (B, W) is back-padded to the round's widest pending span and
    ``counts`` (B,) holds each row's real span (>= 1 for active rows).  The
    whole padded chunk runs through ``decode_step``; then each active row's
    length advances by its *own* count, so the pad positions land past the
    canonical length.  Pad-position KV is garbage but never observable: the
    ring path writes every chunk's KV before attending (post-update view),
    so a later dispatch overwrites a pad lane's position before any query's
    causal mask could admit it — provided the ring is deep enough that a pad
    write never wraps onto a live lane (the scheduler sizes the draft ring
    for the padded worst case).  The returned last token is row ``counts-1``
    of the greedy argmax — exactly the B=1 chunk's final-position token.
    """
    from ..models import kvcache

    def catchup(params, cache, tokens, counts, active):
        with _policy_scope(policy):
            logits, new_cache = model.decode_step(params, cache, tokens)
            # decode_step advanced every row by the padded width W; the
            # canonical advance is each row's own pending count
            new_cache["length"] = jnp.where(
                active, cache["length"] + counts, cache["length"])
            new_cache = kvcache.mask_slot_rows(new_cache, cache, active)
            new_cache = _constrain_cache(new_cache, cache_specs)
            y = jnp.argmax(logits, axis=-1).astype(jnp.int32)       # (B, W)
            last = jnp.take_along_axis(
                y, jnp.maximum(counts - 1, 0)[:, None], axis=1)[:, 0]
        return new_cache, last

    return catchup


def make_spec_verify_step(model, *, max_seq: int, policy=None,
                          cache_specs=None) -> Callable:
    """One draft-and-verify round's target half: score ``spec_k + 1`` tokens
    per slot in a single chunked decode step and accept the longest prefix
    of drafts that matches the target's own greedy argmax.

    ``verify_tokens[:, 0]`` is each slot's newest canonical token (the
    sampled-but-unconsumed one) and ``verify_tokens[:, 1:]`` the draft's
    proposals.  Position ``j``'s argmax ``y[:, j]`` is what the target would
    have sampled after consuming ``verify_tokens[:, :j+1]`` — so draft
    ``j+1`` is accepted iff it equals ``y[:, j]``, and ``a`` (the accepted
    count, clamped per-slot by ``k_eff`` so a slot never overruns its
    ``max_new`` budget) emits ``a + 1`` tokens: the accepted drafts plus the
    target's own bonus/correction token.  Exactness is structural, not
    statistical: every emitted token is the target's argmax conditioned on
    a fully canonical prefix, so the output stream is token-for-token what
    S=1 non-speculative decode produces (the S=1 decode path *is* the chunk
    path at S=1 — the bitwise KV contract this feature stands on).

    The cache write runs ahead: the chunk writes KV for all ``S`` positions,
    so rejected positions hold non-canonical KV — the returned lengths are
    rewound to the canonical ``old + a + 1``, which puts those positions
    past every later read's validity mask until the next round's chunk
    overwrites them (write-before-read, same contract as prefill chunks).
    Recurrent (non-KV) rows advance through all ``S`` tokens and cannot be
    rewound here — hybrid callers snapshot rows before the round and
    replay the accepted span through the chunk path on partial accepts.
    """
    from ..models import kvcache

    def verify(params, cache, verify_tokens, active, k_eff, out_buf, out_pos,
               last_tokens):
        B, S = verify_tokens.shape
        with _policy_scope(policy):
            logits, new_cache = model.decode_step(params, cache, verify_tokens)
            new_cache = kvcache.mask_slot_rows(new_cache, cache, active)
            new_cache = _constrain_cache(new_cache, cache_specs)
        y = jnp.argmax(logits, axis=-1).astype(jnp.int32)          # (B, S)
        match = (verify_tokens[:, 1:] == y[:, :-1]).astype(jnp.int32)
        a = jnp.minimum(jnp.cumprod(match, axis=1).sum(axis=1), k_eff)
        b = jnp.arange(B, dtype=jnp.int32)
        for j in range(S):
            # emitted tokens y[:, :a+1] land on the output ring; masked-out
            # rows and rejected columns scatter out of bounds -> dropped
            ok = active & (j <= a)
            col = jnp.where(ok, out_pos + j, max_seq)
            out_buf = out_buf.at[b, col].set(y[:, j])
        last = jnp.take_along_axis(y, a[:, None], axis=1)[:, 0]
        last_tokens = jnp.where(active, last, last_tokens)
        out_pos = out_pos + jnp.where(active, a + 1, 0)
        new_cache["length"] = jnp.where(
            active, new_cache["length"] - (S - 1 - a), new_cache["length"])
        return new_cache, y, a, out_buf, out_pos, last_tokens

    return verify


def make_offload_steps(*, policy=None, cache_specs=None,
                       stage_specs=None) -> tuple:
    """Jitted staging steps for storage-backed preemption.

    ``extract(cache, page_ids)`` gathers the victim's pool pages (in the
    page table's logical order) into the staging buffer the scheduler ships
    to the object store; ``inject(cache, page_ids, blob)`` scatters a blob
    chunk back onto freshly allocated pages during a chunked restore.  Both
    are pure pool-pytree programs (:func:`repro.models.kvcache.gather_pages`
    / :func:`scatter_pages`) jitted once and re-traced only per distinct
    chunk length, so a restore step costs one dispatch — same budget as a
    prefill chunk.

    With a concrete-mesh ``policy`` plus the cache/stage PartitionSpec
    pytrees, both run under ``jax.shard_map``: the page dim of the pool is
    unsharded, so the per-page take/scatter is local to each lane shard and
    the staged chunk comes out in :func:`offload_stage_shardings`' layout —
    no reshuffle of the pool, no gather of anything but the page ids.
    """
    from ..models import kvcache

    mesh = getattr(policy, "mesh", None)
    if mesh is None or cache_specs is None or stage_specs is None:
        return jax.jit(kvcache.gather_pages), jax.jit(kvcache.scatter_pages)
    from jax.sharding import PartitionSpec as P

    def extract_body(cache, ids):
        return kvcache.gather_pages(cache, ids)

    def inject_body(cache, ids, blob):
        return kvcache.scatter_pages(cache, ids, blob)

    extract = jax.jit(jax.shard_map(
        extract_body, mesh=mesh, in_specs=(cache_specs, P()),
        out_specs=stage_specs, check_vma=False))
    inject = jax.jit(jax.shard_map(
        inject_body, mesh=mesh, in_specs=(cache_specs, P(), stage_specs),
        out_specs=cache_specs, check_vma=False))
    return extract, inject


def make_prefill(model, seq_len: Optional[int] = None, *,
                 policy=None) -> Callable:
    """``seq_len`` sizes the cache for the *total* sequence (prompt + decode
    budget): without it the legacy prompt-sized ring silently evicts the
    oldest prompt tokens once decode wraps it."""

    def prefill(params, tokens, *extra):
        with _policy_scope(policy):
            if seq_len is None:
                logits, cache = model.prefill(params, tokens, *extra)
            else:
                logits, cache = model.prefill(params, tokens, *extra,
                                              seq_len=seq_len)
            next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, cache

    return prefill


def generate(model, params, prompt: jnp.ndarray, max_new: int, *extra,
             seq_len: Optional[int] = None) -> jnp.ndarray:
    """Greedy autoregressive generation (examples / integration tests).

    Pass ``seq_len >= prompt + max_new`` for an eviction-free decode — the
    layout the continuous-batching scheduler uses, and the reference the
    paged parity suite compares against."""
    prefill = jax.jit(make_prefill(model, seq_len))
    step = jax.jit(make_decode_step(model))
    tok, cache = prefill(params, prompt, *extra)
    out = [tok]
    for _ in range(max_new - 1):
        tok, _, cache = step(params, cache, tok[:, None])
        out.append(tok)
    return jnp.stack(out, axis=1)
