"""Serving steps: prefill + single-token decode against a KV/state cache.

``make_decode_step`` is what the decode_* / long_* dry-run cells lower: one
new token per sequence with a cache of ``seq_len`` (per the assignment, these
cells lower ``serve_step``, not ``train_step``).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def make_decode_step(model) -> Callable:
    def serve_step(params, cache, tokens):
        logits, new_cache = model.decode_step(params, cache, tokens)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, logits, new_cache

    return serve_step


def make_prefill(model) -> Callable:
    def prefill(params, tokens, *extra):
        logits, cache = model.prefill(params, tokens, *extra)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, cache

    return prefill


def generate(model, params, prompt: jnp.ndarray, max_new: int, *extra) -> jnp.ndarray:
    """Greedy autoregressive generation (examples / integration tests)."""
    prefill = jax.jit(make_prefill(model))
    step = jax.jit(make_decode_step(model))
    tok, cache = prefill(params, prompt, *extra)
    out = [tok]
    for _ in range(max_new - 1):
        tok, _, cache = step(params, cache, tok[:, None])
        out.append(tok)
    return jnp.stack(out, axis=1)
