"""Serving steps: prefill + single-token decode against a KV/state cache.

``make_decode_step`` is what the decode_* / long_* dry-run cells lower: one
new token per sequence with a cache of ``seq_len`` (per the assignment, these
cells lower ``serve_step``, not ``train_step``).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp


def make_decode_step(model) -> Callable:
    def serve_step(params, cache, tokens):
        logits, new_cache = model.decode_step(params, cache, tokens)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, logits, new_cache

    return serve_step


def make_chunk_step(model) -> Callable:
    """Prefill one prompt chunk for a *single slot* of a batched paged cache.

    The chunk runs as a B=1 forward against the shared page pool: per-slot
    leaves (lengths, recurrent states, page-table rows) are sliced at
    ``slot``, the pool is passed through whole (the slot exclusively owns the
    pages its table maps, so the scatter is race-free against the other
    slots' decode traffic), and the updated row is scattered back.  ``slot``
    is traced, so one compile covers every slot at a given chunk length.
    """
    from ..models import kvcache

    def chunk_step(params, cache, tokens, slot):
        one = kvcache.cache_slot_view(cache, slot)
        logits, one_new = model.decode_step(params, one, tokens)
        return logits, kvcache.cache_insert_slot(cache, one_new, slot)

    return chunk_step


def make_offload_steps() -> tuple:
    """Jitted staging steps for storage-backed preemption.

    ``extract(cache, page_ids)`` gathers the victim's pool pages (in the
    page table's logical order) into the staging buffer the scheduler ships
    to the object store; ``inject(cache, page_ids, blob)`` scatters a blob
    chunk back onto freshly allocated pages during a chunked restore.  Both
    are pure pool-pytree programs (:func:`repro.models.kvcache.gather_pages`
    / :func:`scatter_pages`) jitted once and re-traced only per distinct
    chunk length, so a restore step costs one dispatch — same budget as a
    prefill chunk.
    """
    from ..models import kvcache

    extract = jax.jit(kvcache.gather_pages)
    inject = jax.jit(kvcache.scatter_pages)
    return extract, inject


def make_prefill(model, seq_len: Optional[int] = None) -> Callable:
    """``seq_len`` sizes the cache for the *total* sequence (prompt + decode
    budget): without it the legacy prompt-sized ring silently evicts the
    oldest prompt tokens once decode wraps it."""

    def prefill(params, tokens, *extra):
        if seq_len is None:
            logits, cache = model.prefill(params, tokens, *extra)
        else:
            logits, cache = model.prefill(params, tokens, *extra, seq_len=seq_len)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, cache

    return prefill


def generate(model, params, prompt: jnp.ndarray, max_new: int, *extra,
             seq_len: Optional[int] = None) -> jnp.ndarray:
    """Greedy autoregressive generation (examples / integration tests).

    Pass ``seq_len >= prompt + max_new`` for an eviction-free decode — the
    layout the continuous-batching scheduler uses, and the reference the
    paged parity suite compares against."""
    prefill = jax.jit(make_prefill(model, seq_len))
    step = jax.jit(make_decode_step(model))
    tok, cache = prefill(params, prompt, *extra)
    out = [tok]
    for _ in range(max_new - 1):
        tok, _, cache = step(params, cache, tok[:, None])
        out.append(tok)
    return jnp.stack(out, axis=1)
