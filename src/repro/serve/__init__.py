from .engine import make_decode_step, make_prefill
from .sampling import greedy, temperature_sample
from .scheduler import CompletedRequest, DecodeScheduler, supports_continuous

__all__ = ["make_decode_step", "make_prefill", "greedy", "temperature_sample",
           "CompletedRequest", "DecodeScheduler", "supports_continuous"]
