from .engine import make_decode_step, make_prefill
from .sampling import greedy, temperature_sample

__all__ = ["make_decode_step", "make_prefill", "greedy", "temperature_sample"]
