from .engine import make_decode_step, make_offload_steps, make_prefill
from .fleet import FleetController, FleetWorker
from .lifecycle import IllegalTransition, Slot, SlotState
from .sampling import greedy, temperature_sample
from .scheduler import CompletedRequest, DecodeScheduler, supports_continuous

__all__ = ["make_decode_step", "make_offload_steps", "make_prefill",
           "greedy", "temperature_sample", "IllegalTransition", "Slot",
           "SlotState", "CompletedRequest", "DecodeScheduler",
           "FleetController", "FleetWorker", "supports_continuous"]
