"""Slot-based continuous-batching decode scheduler over a paged KV pool.

A fixed-width decode batch (``n_slots``) steps one token per active slot per
call; free slots are re-admitted from a shared cross-session queue of pending
requests.  Every slot runs the explicit lifecycle in
:mod:`repro.serve.lifecycle`::

    EMPTY -> ADMITTING -> ACTIVE -> (PREEMPTED -> RESTORING -> ACTIVE)* -> DRAINED

Two KV layouts:

* ``kv_mode='paged'`` (default): one shared ``(n_pages, page_size, Hkv, D)``
  pool per layer plus a per-slot page table
  (:func:`repro.models.kvcache.paged_cache`).  Pages are handed out by a
  host-side free list (:class:`repro.models.kvcache.PageAllocator`) —
  mapped on first write, freed on completion — so KV memory scales with
  *live tokens*, not ``n_slots * max_seq``.  Admission is **chunked**: the
  prompt is split into ``prefill_chunk``-sized pieces and one chunk runs per
  :meth:`step` call (a B=1 forward against the shared pool, interleaved with
  the batch's decode step), so a long-prompt admission never stalls the
  other slots for more than one chunk.  Admission is reservation-gated: a
  request is only admitted when the pool's uncommitted pages cover its worst
  case, so lazy mapping can never deadlock mid-decode.

* ``kv_mode='ring'``: the PR 2 baseline — per-slot rings sized ``max_seq``
  and monolithic prefill-on-admit.

**Storage-backed preemption** (``offload=True``, paged mode): the FaaSKeeper
move — durable state belongs in cloud storage, compute is ephemeral and
reclaimable — applied to the KV pool.  When a pending request is pool-gated
(an admission stall), the preemption policy picks victim slots among the
ACTIVE ones (oldest resident first — the idleness signal — then most pages
pinned; ``idle_preempt_steps`` sets the minimum residency so fresh slots are
never thrashed), extracts each victim's pages through its page table into a
position-ordered blob (:func:`kvcache.gather_pages`), PUTs it to the
:class:`repro.core.storage.PageBlobStore`, and frees the pages *and* the
victim's whole reservation back to the pool.  The victim parks in PREEMPTED:
its slot row (recurrent state, lengths, output ring) stays frozen under the
decode mask, but it pins zero pool capacity.  When pool pressure clears (no
pending request is pool-gated and the uncommitted margin covers the
victim's worst case again), the slot funds a restore: the blob is fetched
and injected **chunk by chunk, interleaved with decode exactly like prefill
chunks** (:func:`kvcache.scatter_pages` onto freshly allocated pages, the
page table re-mapped), and the slot resumes ACTIVE — token-for-token
identical to a never-preempted run, because the gather/scatter pair is an
exact inverse through the page table and the masked rows never advanced.
Restores are FIFO in preemption order and, once funded, run to completion
(RESTORING slots are never re-preempted), so offload cannot deadlock or
livelock the pool.  Storage traffic is journaled on the blob store and
billed by the serving frontend under the calibrated object-store models.

Either way the batched decode step masks non-ACTIVE slots out of the token
write, the output ring advance, and every per-slot cache row
(``kvcache.mask_slot_rows``): a freed, mid-admission, or preempted slot's
stale state cannot advance, and its dangling pool writes are dropped by the
unmapped page table.

**Refcounted copy-on-write prefix sharing** (``prefix_sharing=True``, paged
mode): KV pages are a shared resource.  The :class:`kvcache.PageAllocator`
refcounts every page (alloc/share/release); completed requests publish their
*full* pages — generated span included, since decode-written KV is bitwise
prefill KV — into a content-addressed :class:`kvcache.PrefixIndex` keyed by
token-chain hashes, and a new request whose prompt carries an indexed prefix
maps those pages **read-only** (one extra reference each) and prefills only
its tail.  Any write through a page with refcount > 1 — a chunked-prefill
tail landing in a shared boundary page, or a decode append — first
copy-on-write splits the page (:func:`kvcache.copy_pages`) onto a fresh
page and remaps only the writer's table, so every other reference keeps
reading the original bytes (FaaSFS's journaled CoW consistency model).
Index sharing is only consulted for pure-attention families (dense/moe):
recurrent rows (hybrid conv/RG-LRU state) cannot be reconstructed from KV
pages alone.

**Cross-request session parking** (``park_sessions=True``): the FaaSKeeper
session move — a session's state outlives the invocation that built it.  A
completed slot enters ``PARKED`` instead of freeing its pages: a
per-session record takes ownership of the page references (plus the token
history and, once the slot itself is reclaimed, a host snapshot of the
per-slot rows), so the session's *next* request — whose prompt extends the
recorded history, the multi-turn chat shape — maps the parked pages shared
and prefills only the new tokens.  Parked capacity is fully reclaimable:
a new admission may take the slot (rows snapshot to the record), and under
pool pressure parked pages offload through the same
:class:`~repro.core.storage.PageBlobStore` path preemption uses — the next
request then restores the blob instead of re-prefilling, trading a storage
GET + retention for prompt-length compute.  ``park_ttl_steps`` bounds the
retention window.  ``reset()`` clears the prefix index and the parked
table: a crash-replayed run must never observe another life's shared state.

**Draft-and-verify speculative decoding** (``draft_model=..., spec_k=k``,
paged + gather + greedy): a small draft model proposes ``k`` tokens per
active slot per tick; the target scores all of them (plus the pending
canonical token) in ONE chunked decode step against the shared paged pool
and accepts the longest prefix matching its own argmax, emitting 1..k+1
tokens per round.  The invariant is exactness, not luck: every emitted
token is the target's greedy argmax over a fully canonical prefix, so the
output stream is token-for-token identical to the non-speculative run.
This leans on the same contract that un-blocked generated-tail reuse — an
S=1 decode step IS the chunk path at S=1 and writes bitwise-identical KV —
so a verify chunk's accepted span needs no fixup, rejected KV positions
simply sit past the rewound length until the next round overwrites them,
and hybrid recurrent rows snapshot/replay around partial accepts
(:meth:`_spec_round`).

Per-session FIFO is preserved structurally: a session's next request is only
admitted after its predecessor completes (the ``_active_sessions`` gate), and
the pending list is scanned in arrival order.

``mesh`` applies :func:`repro.dist.sharding.cache_shardings` to the live
decode cache; with offload enabled the staging-buffer specs resolve through
:func:`repro.dist.sharding.offload_stage_shardings` into ``stage_specs``.

Supported families: ``dense``, ``moe``, ``ssm``, ``hybrid`` (decoder-only
LMs; the enc-dec families keep the whole-batch serving path).  SSM keeps its
ring-free O(1) state — no pool, so nothing to offload, but admission still
chunks.
"""

from __future__ import annotations

import copy
import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.storage import PageBlobStore
from ..models import kvcache
from . import sampling
from .engine import _policy_scope, make_chunk_step, make_offload_steps
from .lifecycle import Slot, SlotState

CONTINUOUS_FAMILIES = ("dense", "moe", "ssm", "hybrid")

PREEMPT_POLICIES = ("none", "pressure")


def supports_continuous(cfg) -> bool:
    return getattr(cfg, "family", None) in CONTINUOUS_FAMILIES


@dataclasses.dataclass
class _Request:
    session: str
    request_id: str
    prompt: Any                 # (P,) int tokens
    max_new: int
    submit_step: int = 0
    hashes: Any = None          # prompt page-chain hashes, computed once (a
    # held request is re-matched every _fill_slots pass)


@dataclasses.dataclass
class CompletedRequest:
    session: str
    request_id: str
    tokens: np.ndarray          # (max_new,) generated tokens
    admitted_step: int
    finished_step: int
    submitted_step: int = 0     # admission stall = admitted - submitted
    preempts: int = 0           # times this request was preempted mid-decode
    reused_tokens: int = 0      # prompt tokens served from shared/parked pages


@dataclasses.dataclass
class ParkedSession:
    """The durable half of a parked session: the KV-page journal a completed
    request leaves behind so its session's next request restores instead of
    re-prefilling.  Owns one allocator reference per resident page; the
    journal is immutable (writers CoW-split), dropped only when superseded
    by a longer history, diverged from, expired, or reset."""

    session: str
    history: np.ndarray         # prompt + generated tokens
    consumed: int               # tokens whose KV/recurrent state is captured
    # (decode-written KV is bitwise what a re-prefill would write — the S=1
    # decode path IS the chunk path at S=1 — so the whole consumed span is
    # reusable, generated tokens included; no prefill-path/decode-path split)
    page_row: np.ndarray        # logical -> physical page map at park time
    pages: List[int]            # resident page references the record owns
    slot: Optional[int] = None  # still holding its slot (rows live on device)
    state: Any = None           # host row snapshot once the slot is reclaimed
    blob_key: Optional[str] = None        # pages offloaded under pool pressure
    blob_pidx: List[int] = dataclasses.field(default_factory=list)
    parked_step: int = 0


@dataclasses.dataclass
class _MatchPlan:
    """How much of an arriving prompt is already resident, and where."""

    kind: str = "none"          # none | park | park-blob | index
    C: int = 0                  # matched tokens (their KV will be reused)
    pages: List[int] = dataclasses.field(default_factory=list)  # logical order
    record: Optional[ParkedSession] = None


class DecodeScheduler:
    """Continuous batching over a shared paged pool (or per-slot rings)."""

    def __init__(self, model, params, *, n_slots: int = 4, max_seq: int = 64,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 mesh=None, kv_mode: str = "paged", page_size: int = 16,
                 kv_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 offload: bool = False,
                 preempt_policy: Optional[str] = None,
                 idle_preempt_steps: int = 0,
                 blob_store: Optional[PageBlobStore] = None,
                 prefix_sharing: bool = False,
                 park_sessions: bool = False,
                 park_ttl_steps: int = 0,
                 index_journal: bool = False,
                 attn_backend: str = "gather",
                 draft_model=None, draft_params=None, spec_k: int = 0):
        if not supports_continuous(model.cfg):
            raise ValueError(
                f"family {model.cfg.family!r} has no per-slot decode path; "
                f"continuous batching supports {CONTINUOUS_FAMILIES}")
        if kv_mode not in ("paged", "ring"):
            raise ValueError(f"kv_mode must be 'paged' or 'ring', got {kv_mode!r}")
        if attn_backend not in ("gather", "paged_kernel"):
            raise ValueError("attn_backend must be 'gather' or 'paged_kernel', "
                             f"got {attn_backend!r}")
        if attn_backend == "paged_kernel":
            if kv_mode != "paged":
                raise ValueError(
                    "attn_backend='paged_kernel' streams the shared page pool "
                    "through the Pallas kernel; it needs kv_mode='paged'")
            if model.cfg.family == "ssm":
                raise ValueError("attn_backend='paged_kernel' needs attention "
                                 "layers; SSM decode has no KV pool")
            # rebind a copy so a gather-mode scheduler sharing this model
            # object keeps the reference dispatch (cfg drives the decode
            # paths' backend branch at trace time)
            model = copy.copy(model)
            model.cfg = dataclasses.replace(model.cfg,
                                            attn_backend="paged_kernel")
        self.attn_backend = attn_backend
        if preempt_policy is None:
            preempt_policy = "pressure" if offload else "none"
        if preempt_policy not in PREEMPT_POLICIES:
            raise ValueError(f"preempt_policy must be one of {PREEMPT_POLICIES}, "
                             f"got {preempt_policy!r}")
        if offload and kv_mode != "paged":
            raise ValueError("KV offload needs the paged pool (kv_mode='paged'); "
                             "per-slot rings have no page granularity to evict")
        if (prefix_sharing or park_sessions) and kv_mode != "paged":
            raise ValueError(
                "prefix sharing / session parking need the paged pool "
                "(kv_mode='paged'); per-slot rings have no shareable pages")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.temperature = temperature
        self.top_k = top_k
        self.kv_mode = kv_mode
        self._seed = seed
        self._key = jax.random.key(seed)
        self._has_kv = model.cfg.family != "ssm"   # SSM state is ring-free
        self.offload = bool(offload) and kv_mode == "paged" and self._has_kv
        self.preempt_policy = preempt_policy if self.offload else "none"
        self.idle_preempt_steps = idle_preempt_steps
        # -- prefix sharing / session parking -------------------------------
        self.prefix_sharing = (bool(prefix_sharing) and kv_mode == "paged"
                               and self._has_kv)
        self.park_sessions = (bool(park_sessions) and kv_mode == "paged"
                              and self._has_kv)
        self.park_ttl_steps = park_ttl_steps
        # index sharing reconstructs state from KV pages alone, which only
        # pure-attention families allow (hybrid conv/RG-LRU rows are not in
        # the pool); parking keeps the rows, so it covers every family
        self._attention_only = model.cfg.family in ("dense", "moe")
        self._index_sharing = self.prefix_sharing and self._attention_only
        self.prefix_index = kvcache.PrefixIndex()
        # fleet mode: journal every published index entry to the (shared)
        # blob store so shared prefixes survive this worker's death, and
        # namespace this worker's transient blob keys (preempt spills,
        # parked-journal offloads) so a dead worker's keys can be garbage
        # collected without racing a successor's
        self.index_journal = bool(index_journal) and self._index_sharing
        self.blob_ns = ""
        self.index_journal_puts = 0
        self.index_adopted = 0
        self._parked: Dict[str, ParkedSession] = {}
        self._copy_pages = jax.jit(kvcache.copy_pages)
        self._gather_state = jax.jit(kvcache.gather_slot_state)
        self._scatter_state = jax.jit(kvcache.scatter_slot_state)
        self.shared_prefix_tokens = 0   # prompt tokens never re-prefilled
        self.park_hits = 0
        self.park_misses = 0
        self.index_hits = 0
        self.cow_splits = 0
        self.parks = 0
        self.park_evictions = 0         # parked slots reclaimed for admissions
        self.park_offloads = 0          # parked page sets pushed to the blob store
        self.park_expirations = 0

        if kv_mode == "paged":
            self.page_size = page_size
            self.max_pages = -(-max_seq // page_size)
            self.n_pages = (kv_pages if kv_pages is not None
                            else n_slots * self.max_pages)
            if self._has_kv and self.n_pages < self.max_pages:
                raise ValueError(
                    f"kv_pages={self.n_pages} cannot hold even one slot's "
                    f"max_pages={self.max_pages}")
            self.prefill_chunk = prefill_chunk   # None -> whole prompt, one chunk
            self.allocator = kvcache.PageAllocator(
                self.n_pages if self._has_kv else 0)
            # host mirror of the device page table + pages committed to
            # admitted-but-not-yet-mapped growth (the admission gate)
            self._page_rows = np.full((n_slots, self.max_pages), -1, np.int32)
            self._reserved = 0
            self.cache = kvcache.paged_cache(
                model, n_slots, page_size=page_size, n_pages=self.n_pages,
                max_pages=self.max_pages)
        else:
            self.cache = kvcache.batched_cache(model, n_slots, max_seq)

        # -- offload plumbing ------------------------------------------------
        self.blob_store = blob_store if blob_store is not None else PageBlobStore()
        # restore chunking mirrors prefill chunking: a restore step moves
        # about one prefill chunk's worth of tokens (>= 1 page)
        self._restore_chunk_pages = (
            max(1, self.prefill_chunk // self.page_size)
            if kv_mode == "paged" and self.prefill_chunk else None)
        self._preempted_order: List[int] = []   # slot indices, FIFO restores
        self.preemptions = 0
        self.restores = 0
        self.restore_chunks = 0
        self.offload_pages = 0
        self.restored_pages = 0

        # -- mesh placement + sharded step set -------------------------------
        # With a *concrete* mesh the whole hot path goes multi-device: state
        # (params, cache, slot arrays) is device_put through the storage
        # registry, and every jitted step below binds a ShardingPolicy so
        # activations constrain to the mesh and the fused paged gather runs
        # under shard_map against the lane-sharded pool.  An AbstractMesh
        # still resolves the spec pytrees (lowering / analysis callers) but
        # binds the single-device steps.
        self.cache_specs = None
        self.stage_specs = None
        self._mesh = mesh if isinstance(mesh, jax.sharding.Mesh) else None
        self._policy = None
        if mesh is not None:
            from ..dist import sharding as shd

            shardings = shd.cache_shardings(self.cache, mesh)
            self.cache_specs = jax.tree_util.tree_map(
                lambda s: s.spec, shardings)
            if self.offload:
                stage = jax.eval_shape(
                    lambda c: kvcache.gather_pages(c, jnp.zeros((1,), jnp.int32)),
                    self.cache)
                self.stage_specs = jax.tree_util.tree_map(
                    lambda s: s.spec, shd.offload_stage_shardings(stage, mesh))
            if self._mesh is not None:   # concrete: place state, build policy
                self.cache = jax.device_put(self.cache, shardings)
                self.params = jax.device_put(
                    self.params, shd.param_shardings(self.params, self._mesh))
                self._policy = self._build_policy(model, self._mesh)

        # steps bind the policy + spec pytrees only when a concrete mesh is
        # live — with cache_specs but no policy (AbstractMesh) the constrain
        # helpers would be dead weight in the trace
        skw = (dict(policy=self._policy, cache_specs=self.cache_specs)
               if self._policy is not None else {})
        if kv_mode == "paged":
            self._chunk = jax.jit(make_chunk_step(model, **skw))
        else:
            ring_policy = self._policy

            def _ring_prefill(p, toks):
                with _policy_scope(ring_policy):
                    return model.prefill(p, toks, seq_len=max_seq)

            self._prefill = jax.jit(_ring_prefill)
        self._extract, self._inject = make_offload_steps(
            policy=self._policy, cache_specs=self.cache_specs,
            stage_specs=self.stage_specs)

        self._decode = jax.jit(self._step_impl)

        # -- draft-and-verify speculative decoding --------------------------
        # The draft proposes spec_k tokens per slot per round; the target
        # scores all of them (plus the pending canonical token) in ONE
        # chunked decode step against the shared paged pool and accepts the
        # longest matching prefix.  Output is token-for-token identical to
        # non-speculative decode because every emitted token is the target's
        # greedy argmax over a fully canonical prefix — which requires the
        # bitwise S=1-decode==chunked-prefill KV contract the models now
        # hold.  Rejected positions' KV is rewound by length (pool pages
        # stay mapped; the next round's chunk overwrites before any read),
        # and hybrid recurrent rows snapshot/replay around partial accepts.
        self.spec_k = int(spec_k)
        self._spec = draft_model is not None and self.spec_k >= 1
        if self._spec:
            if kv_mode != "paged" or not self._has_kv:
                raise ValueError(
                    "speculative decoding verifies chunks against the shared "
                    "paged pool; it needs kv_mode='paged' and a KV-bearing "
                    "target (dense/moe/hybrid)")
            if temperature > 0.0:
                raise ValueError(
                    "speculative decoding is greedy-only: accept/reject "
                    "compares the target's argmax, which temperature "
                    "sampling does not produce")
            if attn_backend != "gather":
                raise ValueError(
                    "speculative decoding needs attn_backend='gather': the "
                    "fused paged kernel only serves S=1 steps, so a verify "
                    "chunk would switch dispatch mid-request")
            if draft_params is None:
                raise ValueError("spec decoding needs draft_params")
            if getattr(draft_model.cfg, "family", None) not in ("dense", "moe"):
                raise ValueError(
                    "draft family must be dense or moe: the draft rewinds "
                    "to the accepted prefix every round, which recurrent "
                    "state cannot do cheaply")
            if draft_model.cfg.vocab != model.cfg.vocab:
                raise ValueError(
                    f"draft vocab {draft_model.cfg.vocab} != target vocab "
                    f"{model.cfg.vocab}")
            self.draft_model = draft_model
            self.draft_params = draft_params
            # per-slot ring sized for the deepest proposal the draft reaches
            # (the page table's span can overhang max_seq by a partial page)
            # PLUS the batched catch-up's back-padding: a round's widest
            # pending span W pads every row, so a row at canonical length L
            # writes (garbage, never-read) lanes up to L + W - 1.  The ring
            # scatter wraps at capacity, so the ring must be deeper than the
            # padded worst case (L <= span + spec_k, W <= max_seq + 1) or a
            # pad write would land on a live lane.
            span = self.max_pages * self.page_size
            self.draft_cache = kvcache.batched_cache(
                draft_model, n_slots, 2 * span + self.spec_k + 2)
            from .engine import (make_draft_catchup_step, make_draft_step,
                                 make_spec_verify_step)

            self._draft_policy = None
            self._draft_cache_specs = None
            if self._mesh is not None:
                from ..dist import sharding as shd

                self._draft_policy = self._build_policy(draft_model,
                                                        self._mesh)
                d_sh = shd.cache_shardings(self.draft_cache, self._mesh)
                self._draft_cache_specs = jax.tree_util.tree_map(
                    lambda s: s.spec, d_sh)
                self.draft_cache = jax.device_put(self.draft_cache, d_sh)
                self.draft_params = jax.device_put(
                    self.draft_params,
                    shd.param_shardings(self.draft_params, self._mesh))
            dkw = (dict(policy=self._draft_policy,
                        cache_specs=self._draft_cache_specs)
                   if self._draft_policy is not None else {})
            self._draft_catchup = jax.jit(
                make_draft_catchup_step(draft_model, **dkw))
            self._draft_step = jax.jit(make_draft_step(draft_model, **dkw))
            self._verify = jax.jit(make_spec_verify_step(model,
                                                         max_seq=max_seq,
                                                         **skw))
        self.spec_rounds = 0
        self.spec_proposed = 0          # draft tokens offered to the verifier
        self.spec_accepted = 0          # draft tokens accepted
        self.spec_emitted = 0           # tokens emitted by verify rounds

        self.slots: List[Slot] = [Slot(index=i) for i in range(n_slots)]
        self.last_tokens = jnp.zeros((n_slots,), jnp.int32)
        # device-side per-slot output ring: tokens accumulate on device and
        # are pulled to host once per *completion*, not once per step — a
        # decode step is a single async dispatch with no host sync
        self.out_buf = jnp.zeros((n_slots, max_seq), jnp.int32)
        self.out_pos = jnp.zeros((n_slots,), jnp.int32)
        if self._mesh is not None:
            # slot-batched state follows the cache's slot axis onto dp
            from ..dist.sharding import batch_shardings

            state = {"last": self.last_tokens, "buf": self.out_buf,
                     "pos": self.out_pos}
            state = jax.device_put(state, batch_shardings(state, self._mesh))
            self.last_tokens = state["last"]
            self.out_buf = state["buf"]
            self.out_pos = state["pos"]
        self.pending: List[_Request] = []
        self._active_sessions: set = set()
        self._chunk_rr = 0            # round-robin over admitting slots
        self._restore_rr = 0          # round-robin over restoring slots
        # -- occupancy / throughput accounting --------------------------------
        self.steps = 0
        self.slot_steps = 0           # sum over steps of active slots
        self.page_step_sum = 0        # sum over steps of pages in use
        self.prefill_tokens = 0
        self.prefill_chunks = 0
        self.decode_tokens = 0
        self.admitted = 0
        self.completed = 0

    # -- mesh mode -----------------------------------------------------------------

    def _build_policy(self, model, mesh):
        """ShardingPolicy for one model on the live mesh: slots on dp when
        they divide, heads on model when the kv-head count divides (else the
        seq fallback), and — for the fused paged backend — the shard_map
        pool decomposition switched on so :func:`paged_attn_decode`
        dispatches the per-shard kernel instead of letting GSPMD all-gather
        the lane-sharded pool."""
        from ..dist import sharding as shd

        rules = shd.MeshRules.for_mesh(mesh)
        msize = rules.model_size(mesh)
        cfg = model.cfg
        n_kv = getattr(cfg, "n_kv_heads", 0) or getattr(cfg, "n_heads", 1)
        return shd.ShardingPolicy.default(
            mesh,
            batch_shardable=bool(rules.dp)
            and self.n_slots % rules.dp_size(mesh) == 0,
            attn_mode="head" if n_kv % msize == 0 else "seq",
            decode_stationary=True,
            shard_map_pool=self.attn_backend == "paged_kernel")

    def _stage_put(self, blob):
        """Place a staging blob (restore chunk / parked-session blob) on the
        mesh per ``offload_stage_shardings`` *before* injecting, so the
        sharded scatter's operand already sits in the pool's lane layout —
        the host->device transfer is the reshard, not an extra collective
        inside the step."""
        if self._mesh is None or self.stage_specs is None:
            return blob
        from jax.sharding import NamedSharding

        return jax.tree_util.tree_map(
            lambda leaf, spec: jax.device_put(
                leaf, NamedSharding(self._mesh, spec)),
            blob, self.stage_specs)

    # -- admission ----------------------------------------------------------------

    def submit(self, session: str, request_id: str, prompt, max_new: int) -> None:
        """Enqueue a request; admitted into a free slot as soon as its
        session has no in-flight predecessor (per-session FIFO gate) and —
        in paged mode — the pool's uncommitted pages cover its worst case
        (or the preemption policy can evict enough to make them).

        ``max_new`` is clamped to what the slot can hold without silent
        corruption: the output ring caps it at ``max_seq``, and on a
        full-attention KV layout (no sliding window — detected via
        ``cache_len``) generation past ``max_seq - len(prompt)`` would wrap
        the ring / run off the page table, so the budget stops there; a
        prompt that leaves no decode room at all is rejected outright
        (clamping would silently drop its leading tokens).  Windowed rings
        wrap by design; the paged table is linear, so windowed families are
        bounded by its ``max_pages * page_size`` span instead.  SSM states
        never bound the budget beyond the output ring.
        """
        prompt = np.asarray(prompt)
        P = int(prompt.shape[-1])
        limit = self.max_seq
        cache_len = getattr(self.model, "cache_len", None)
        has_full_ring = (self._has_kv
                         and cache_len is not None
                         and cache_len(self.max_seq + 1) > self.max_seq)
        if has_full_ring:
            room = self.max_seq - P
            if room <= 0:
                raise ValueError(
                    f"request {request_id!r}: prompt of {P} "
                    f"tokens leaves no decode room in the max_seq={self.max_seq} "
                    "full-attention ring; size max_seq >= prompt + max_new")
            limit = min(limit, room)
        elif self.kv_mode == "paged" and self._has_kv:
            # windowed attention wraps a ring but cannot wrap the linear
            # page table: bound the budget by the table's span
            room = self.max_pages * self.page_size - P
            if room <= 0:
                raise ValueError(
                    f"request {request_id!r}: prompt of {P} tokens overruns "
                    f"the {self.max_pages}x{self.page_size} page table")
            limit = min(limit, room)
        max_new = max(1, min(max_new, limit))
        self.pending.append(_Request(session, request_id, prompt, max_new,
                                     submit_step=self.steps))
        self._fill_slots()

    def busy(self) -> bool:
        """In-flight work pending.  PARKED retention is not work: a parked
        slot is a cache entry, not a request — spinning on it would hold the
        serving invocation open forever."""
        return any(s.working for s in self.slots) or bool(self.pending)

    def free_slots(self) -> int:
        """Slots a new admission can take (EMPTY, plus PARKED ones — parked
        residency is reclaimable, its record survives on the host)."""
        return sum(1 for s in self.slots if s.empty or s.parked)

    def parked_slots(self) -> int:
        return sum(1 for s in self.slots if s.parked)

    def active_slots(self) -> int:
        """Slots decoding+sampling this step (admitting/preempted excluded)."""
        return sum(1 for s in self.slots if s.decoding)

    def admitting_slots(self) -> int:
        return sum(1 for s in self.slots if s.state is SlotState.ADMITTING)

    def preempted_slots(self) -> int:
        return sum(1 for s in self.slots
                   if s.state in (SlotState.PREEMPTED, SlotState.RESTORING))

    def wants_more(self) -> bool:
        """Whether claiming more queued work could improve occupancy.

        Any free slot justifies claiming deeper: a FIFO queue can hold a long
        run of one session's (gated) requests in front of another session's
        admissible one, so the lookahead must not be capped — held-back
        requests wait in ``pending`` in arrival order and are requeued on a
        crash, so over-claiming never loses or reorders work."""
        return self.free_slots() > 0

    def _pages_needed(self, req: _Request) -> int:
        """Worst-case page count: prompt + all decode writes (the completing
        step samples its last token from a write at P + max_new - 2)."""
        if not (self.kv_mode == "paged" and self._has_kv):
            return 0
        tokens = int(np.asarray(req.prompt).shape[-1]) + req.max_new - 1
        return -(-tokens // self.page_size)

    def _uncommitted(self) -> int:
        """Pool pages not yet promised to anyone (the admission currency)."""
        return self.allocator.free_count - self._reserved

    def _fill_slots(self) -> None:
        held: List[_Request] = []
        held_sessions: set = set()    # a held request gates its whole session:
        # a page-starved r0 must not be overtaken by its session's smaller r1
        pool_starved = False
        if self.park_sessions and self.park_ttl_steps > 0:
            self._expire_parked()
        for req in self.pending:
            if req.session in self._active_sessions or req.session in held_sessions:
                held.append(req)      # FIFO gate: predecessor decoding or held
                held_sessions.add(req.session)
                continue
            plan = self._match_prefix(req)
            slot = self._slot_for(plan)
            if slot is None and not any(s.parked for s in self.slots):
                held.append(req)
                held_sessions.add(req.session)
                continue
            need = self._plan_pages(req, plan)
            if need and self._uncommitted() < need:
                # pool gate: reclaim shareable capacity first (index refs,
                # then parked retention), then try the preemption policy
                self._reclaim_pool(need, keep=plan.record, pinned=plan.pages)
                if (self._uncommitted() < need
                        and not self._preempt_for(need)):
                    pool_starved = True
                    held.append(req)
                    held_sessions.add(req.session)
                    continue
            if slot is None:
                # only now — with the pool gate passed — reclaim a parked
                # residency (a held request must not cost a snapshot);
                # _reclaim_pool may already have freed one by offloading
                slot = next((s for s in self.slots if s.empty), None)
                if slot is None:
                    victim = min((s for s in self.slots if s.parked),
                                 key=lambda s: s.parked_step)
                    self._evict_parked_slot(self._parked[victim.session])
                    slot = self.slots[victim.index]
            self._admit(slot, req, plan)
        self.pending = held
        # restores only start when pool pressure has cleared: no pending
        # request is pool-gated, and the uncommitted margin funds the
        # victim's whole worst case (prevents preempt<->restore thrash)
        if not pool_starved:
            self._start_restores()

    # -- prefix matching / parked-capacity planning -------------------------

    def _match_prefix(self, req: _Request) -> _MatchPlan:
        """The longest resident prefix of this prompt: the session's parked
        journal if the prompt extends it (pages or blob), else the longest
        indexed full-page chain.  At least the last prompt token always
        re-runs — its logits seed sampling."""
        plan = _MatchPlan()
        if not (self.kv_mode == "paged" and self._has_kv):
            return plan
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        P = len(prompt)
        rec = self._parked.get(req.session) if self.park_sessions else None
        if rec is not None:
            lim = min(P, len(rec.history))
            eq = prompt[:lim] == rec.history[:lim]
            common = lim if eq.all() else int(np.argmin(eq))
            if self._attention_only:
                # reuse everything consumed — generated tokens included
                # (decode KV is bitwise prefill KV) — capped at P-1: the
                # last prompt token always re-runs to seed sampling
                C = min(rec.consumed, common, P - 1)
            else:
                # recurrent rows advanced through every consumed token and
                # cannot rewind: all or nothing, with >= 1 tail token left
                # to re-run for the seeding logits
                C = rec.consumed if (common >= rec.consumed
                                     and P >= rec.consumed + 1) else 0
            if C > 0:
                plan.kind = "park-blob" if rec.blob_key else "park"
                plan.C = C
                plan.record = rec
                if not rec.blob_key:
                    plan.pages = [int(rec.page_row[i])
                                  for i in range(-(-C // self.page_size))]
                return plan
            if common < lim:
                # the prompt *contradicts* the journal: it can never serve
                # this session again (per-session FIFO — this req is next)
                self._drop_record(self._parked.pop(req.session))
                self.park_misses += 1
            # else: consistent but too short to reuse (hybrid: an exact
            # resubmission of the recorded history) — keep the journal;
            # completion supersedes it
        if self._index_sharing:
            if req.hashes is None:
                req.hashes = kvcache.page_hashes(prompt, self.page_size)
            k_max = max(0, P - 1) // self.page_size   # tail >= 1 token
            pids = self.prefix_index.lookup(req.hashes[:k_max])
            if pids:
                plan.kind = "index"
                plan.C = len(pids) * self.page_size
                plan.pages = [int(p) for p in pids]
        return plan

    def _plan_pages(self, req: _Request, plan: _MatchPlan) -> int:
        """Reservation size under the plan: full worst case minus the full
        pages mapped read-only (shared pages cost nothing until a CoW split;
        the boundary partial page's split is inside the writable span, and a
        blob unpark re-allocates its pages out of the same reservation)."""
        total = self._pages_needed(req)
        if plan.kind in ("park", "index"):
            return total - plan.C // self.page_size
        return total

    def _slot_for(self, plan: _MatchPlan) -> Optional[Slot]:
        """A free admission target: the plan's own parked slot (in-place
        unpark) or any EMPTY slot.  PARKED residencies are reclaimable too,
        but only *after* the pool gate passes — ``_fill_slots`` defers that
        eviction so a held request never costs a journal its row snapshot."""
        if (plan.kind == "park" and plan.record.slot is not None):
            return self.slots[plan.record.slot]
        return next((s for s in self.slots if s.empty), None)

    def _admit(self, slot: Slot, req: _Request,
               plan: Optional[_MatchPlan] = None) -> None:
        if self.kv_mode == "paged":
            self._admit_paged(slot, req, plan or _MatchPlan())
            return
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]      # (1, P)
        logits, one = self._prefill(self.params, prompt)
        tok = self._sample(logits[:, -1])                      # (1,)
        self.cache = kvcache.cache_insert_slot(self.cache, one, slot.index)
        self.last_tokens = self.last_tokens.at[slot.index].set(tok[0])
        self.out_buf = self.out_buf.at[slot.index, 0].set(tok[0])
        self.out_pos = self.out_pos.at[slot.index].set(1)
        slot.to(SlotState.ADMITTING).to(SlotState.ACTIVE)  # monolithic prefill
        slot.req = req
        slot.n_out = 1
        slot.admitted_step = self.steps
        slot.submitted_step = req.submit_step
        slot.active_since = self.steps
        self._active_sessions.add(req.session)
        self.prefill_tokens += int(prompt.shape[1])
        self.admitted += 1

    def _admit_paged(self, slot: Slot, req: _Request, plan: _MatchPlan) -> None:
        """Begin a chunked admission.  With no resident prefix the slot's
        rows are cleared and the whole prompt is staged; with one, the
        matched pages are mapped read-only (shared) or restored from the
        parked blob, the parked rows are reinstalled if the slot changed,
        and only the prompt's tail is staged — the prefill the shared pages
        already paid for is skipped."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        C = plan.C
        need = self._plan_pages(req, plan)
        # plain chunking — a 1-token final chunk is fine (the S=1 forward IS
        # the chunk path at S=1 and writes bitwise-identical KV)
        tail = prompt[C:]
        size = self.prefill_chunk or len(tail)
        chunks = [tail[i:i + size] for i in range(0, len(tail), size)]
        in_place = (plan.kind == "park" and plan.record.slot == slot.index)
        if not in_place:
            self.cache = kvcache.cache_clear_slot(self.cache, slot.index)
            self._page_rows[slot.index, :] = -1
        self._reserved += need
        slot.to(SlotState.ADMITTING)
        slot.session = None
        slot.req = req
        slot.chunks = chunks
        slot.chunk_i = 0
        slot.len = C                  # host mirror of the slot's live length
        slot.pages = []
        slot.shared = []
        slot.need = need
        slot.reused = C
        slot.n_out = 0
        slot.preempts = 0
        slot.admitted_step = self.steps
        slot.submitted_step = req.submit_step
        self._active_sessions.add(req.session)
        if plan.kind in ("park", "index"):
            # map the matched prefix read-only: one extra reference per page
            self.allocator.share(plan.pages)
            slot.shared = list(plan.pages)
            for i, pid in enumerate(plan.pages):
                self._page_rows[slot.index, i] = pid
            self.cache = kvcache.set_page_row(
                self.cache, slot.index, self._page_rows[slot.index])
            if plan.kind == "park":
                rec = plan.record
                if in_place:
                    # the new request will overwrite the live rows; keep the
                    # journal self-contained so it can offload mid-flight
                    rec.state = jax.device_get(
                        self._gather_state(self.cache, slot.index))
                    rec.slot = None
                elif rec.state is not None:
                    self.cache = self._scatter_state(
                        self.cache, slot.index, rec.state)
                # the snapshot's length is rec.consumed; rewind to the
                # matched span C (attention families may reuse less than
                # consumed when the prompt diverges inside the generated
                # span or the seeding-tail cap bites)
                self.cache["length"] = self.cache["length"].at[slot.index].set(C)
                self.park_hits += 1
            else:
                # index pages carry KV only — set the slot's consumed length
                # (index matches are gated to pure-attention families, so
                # there are no recurrent rows to reconstruct)
                self.cache["length"] = self.cache["length"].at[slot.index].set(C)
                self.index_hits += 1
        elif plan.kind == "park-blob":
            # restore only the reused span of the journal's blob out of
            # this admission's own reservation (an attention family may
            # reuse far fewer pages than the blob holds — a long generated
            # tail re-prefills instead of restoring); the record keeps its
            # whole blob until superseded
            rec = plan.record
            npg = -(-C // self.page_size)
            pids = self.allocator.alloc(npg)
            self._reserved -= npg
            slot.pages = list(pids)
            for j in range(npg):
                self._page_rows[slot.index, rec.blob_pidx[j]] = pids[j]
            blob = self.blob_store.get(rec.blob_key)
            if npg < len(rec.blob_pidx):
                blob = kvcache.slice_page_blob(blob, 0, npg)
            self.cache = self._inject(self.cache,
                                      jnp.asarray(pids, jnp.int32),
                                      self._stage_put(blob))
            self.cache = kvcache.set_page_row(
                self.cache, slot.index, self._page_rows[slot.index])
            self.cache = self._scatter_state(self.cache, slot.index, rec.state)
            self.cache["length"] = self.cache["length"].at[slot.index].set(C)
            self.park_hits += 1
        self.shared_prefix_tokens += C
        if C % self.page_size and slot.shared:
            # eagerly CoW-split the partial boundary page: the batched decode
            # step's masked rows still write (dropped only by *unmapped*
            # tables), so a shared page this slot will write into must go
            # private before the next decode/verify step, not lazily at
            # chunk time
            self._prepare_write_span(slot, C, 1)

    def _map_page(self, slot: Slot, page_idx: int) -> None:
        """Host-side mapping only — the caller pushes the updated row to the
        device once per chunk/step (one dispatch per row, not per page)."""
        pid = self.allocator.alloc(1)[0]
        self._page_rows[slot.index, page_idx] = pid
        slot.pages.append(pid)
        self._reserved -= 1

    def _release_slot(self, slot: Slot) -> None:
        """Release a DRAINED slot's page references (owned pages free when
        their last reference dies; shared pages just drop one count) and any
        unused reservation; unmap its device page-table row so residual
        decode traffic is dropped."""
        slot.to(SlotState.EMPTY)
        if not (self.kv_mode == "paged" and self._has_kv):
            self.slots[slot.index] = Slot(index=slot.index)
            return
        self._reserved -= slot.need - len(slot.pages)
        if slot.pages or slot.shared:
            self.allocator.release(slot.pages + slot.shared)
        self._page_rows[slot.index, :] = -1
        self.cache = kvcache.set_page_row(
            self.cache, slot.index, self._page_rows[slot.index])
        self.slots[slot.index] = Slot(index=slot.index)

    # -- session parking (cross-request KV retention) ------------------------

    def _publish_index(self, row: np.ndarray, history: np.ndarray,
                       hashes=None) -> None:
        """Publish a finished sequence's full pages — generated span
        included — into the prefix index (content-addressed by token chain;
        the index takes one reference per adopted page).  Resident KV covers
        ``len(history) - 1`` tokens (the final sampled token was never
        consumed), and decode-written KV is bitwise prefill KV, so every
        full page under that span is exactly what a re-prefill of the same
        tokens would produce.  ``hashes`` reuses the request's cached prompt
        chain when it already covers the span (the chain property makes the
        prompt hashes a prefix of the history hashes)."""
        full = (len(history) - 1) // self.page_size
        if not full:
            return
        if hashes is None or len(hashes) < full:
            hashes = kvcache.page_hashes(history[: full * self.page_size],
                                         self.page_size)
        pids = [int(row[i]) for i in range(full)]
        self.prefix_index.publish(hashes[:full], pids, self.allocator)
        if self.index_journal:
            # persist the published entries: each full page's contents go to
            # the shared store under its chain hash, so a successor worker
            # can re-adopt this prefix after this worker dies
            self.index_journal_puts += self.prefix_index.journal(
                zip(hashes[:full], pids, strict=True), self.blob_store,
                lambda ids: jax.device_get(
                    self._extract(self.cache, jnp.asarray(ids, jnp.int32))))

    def _park_slot(self, slot: Slot, req: _Request, tokens: np.ndarray) -> None:
        """Park a DRAINED slot: ownership of every mapped page transfers to
        the session's journal record, full pages are published to the prefix
        index, and the slot enters PARKED with its device row unmapped (so
        its masked decode traffic can never touch the journal)."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        history = np.concatenate([prompt,
                                  np.asarray(tokens, np.int32).reshape(-1)])
        consumed = slot.len
        row = self._page_rows[slot.index].copy()
        self._reserved -= slot.need - len(slot.pages)
        if self._index_sharing:
            self._publish_index(row, history, hashes=req.hashes)
        old = self._parked.pop(req.session, None)
        if old is not None:
            self._drop_record(old)          # superseded journal
        self._parked[req.session] = ParkedSession(
            session=req.session, history=history, consumed=consumed,
            page_row=row, pages=slot.pages + slot.shared, slot=slot.index,
            parked_step=self.steps)
        self._page_rows[slot.index, :] = -1
        self.cache = kvcache.set_page_row(
            self.cache, slot.index, self._page_rows[slot.index])
        slot.to(SlotState.PARKED)
        slot.session = req.session
        slot.parked_step = self.steps
        slot.req = None
        slot.pages, slot.shared = [], []
        slot.need = 0
        self.parks += 1

    def _evict_parked_slot(self, rec: ParkedSession) -> None:
        """Reclaim a parked slot for a new admission: snapshot its rows to
        the host (lengths + recurrent state; the pages stay resident, owned
        by the record) and free the slot."""
        slot = self.slots[rec.slot]
        rec.state = jax.device_get(self._gather_state(self.cache, rec.slot))
        slot.to(SlotState.EMPTY)
        self.slots[rec.slot] = Slot(index=rec.slot)
        rec.slot = None
        self.park_evictions += 1

    def _offload_parked(self, rec: ParkedSession) -> None:
        """Pool pressure: push a parked journal's pages to the blob store
        (position-ordered, like a preemption) and release the references —
        the session's next request restores the blob instead of
        re-prefilling, paying a storage GET for prompt-length compute."""
        if rec.slot is not None:
            self._evict_parked_slot(rec)
        npg = -(-rec.consumed // self.page_size)
        phys = [int(rec.page_row[i]) for i in range(npg)]
        blob = jax.device_get(
            self._extract(self.cache, jnp.asarray(phys, jnp.int32)))
        key = f"park/{self.blob_ns}{rec.session}/s{self.steps}"
        self.blob_store.put(key, blob, kvcache.blob_nbytes(blob))
        rec.blob_key = key
        rec.blob_pidx = list(range(npg))
        self.allocator.release(rec.pages)
        rec.pages = []
        self.park_offloads += 1

    def _drop_record(self, rec: ParkedSession) -> None:
        """Forget a journal (superseded, diverged, expired, or reclaimed):
        release its page references and delete its blob; a still-resident
        slot goes back to EMPTY."""
        if rec.slot is not None:
            slot = self.slots[rec.slot]
            slot.to(SlotState.EMPTY)
            self.slots[rec.slot] = Slot(index=rec.slot)
        if rec.pages:
            self.allocator.release(rec.pages)
        if rec.blob_key:
            self.blob_store.delete(rec.blob_key)

    def _expire_parked(self) -> None:
        for session, rec in list(self._parked.items()):
            if self.steps - rec.parked_step > self.park_ttl_steps:
                self._drop_record(self._parked.pop(session))
                self.park_expirations += 1

    # -- fleet hooks (worker drain / cold start) -----------------------------

    def externalize_session(self, session: str) -> ParkedSession:
        """Fleet drain: detach one parked journal from this worker entirely.
        The record's pages are pushed to the (shared) blob store if still
        resident, its slot is reclaimed, and the record — now pure host data
        plus a blob key — is popped and returned for the controller to hand
        to a successor worker.  After this the worker holds no reference to
        the session."""
        rec = self._parked.pop(session)
        if rec.pages:
            self._offload_parked(rec)
        return rec

    def adopt_parked(self, rec: ParkedSession) -> None:
        """Fleet routing: install an externalized (blob-resident) journal so
        the next admission for its session restores from the shared store
        instead of re-prefilling.  The record must hold no pool references —
        those died with the worker that wrote it."""
        if rec.pages or rec.slot is not None:
            raise ValueError(
                f"adopting session {rec.session!r} with live pool state "
                "(pages/slot are worker-local and do not transfer)")
        rec.parked_step = self.steps
        self._parked[rec.session] = rec

    def adopt_index_journal(self) -> int:
        """Worker cold start: re-adopt journaled prefix-index entries from
        the shared blob store into this fresh pool (allocate, scatter, adopt
        — the alloc-time reference transfers to the index).  Bounded so
        adoption always leaves at least one slot's worst case uncommitted;
        index pages are reclaimable cache either way, so a skipped entry
        only costs a re-prefill."""
        if not self.index_journal:
            return 0

        def install(pid, blob):
            self.cache = self._inject(self.cache,
                                      jnp.asarray([pid], jnp.int32),
                                      self._stage_put(blob))

        n = self.prefix_index.rebuild(
            self.blob_store, self.allocator,
            budget=lambda: self._uncommitted() - self.max_pages,
            install=install)
        self.index_adopted += n
        return n

    def _reclaim_pool(self, need: int, keep: Optional[ParkedSession] = None,
                      pinned: Sequence[int] = ()) -> None:
        """Pool-gated admission: reclaim shareable capacity cheapest-first —
        drop LRU prefix-index references (free if nobody else maps the
        page), then offload parked journals to the blob store, oldest
        first.  ``keep`` is the record the admission itself consumes and
        ``pinned`` the index pages its plan is about to map."""
        self.prefix_index.evict(self.allocator, self._reserved + need,
                                pinned=pinned)
        if self._uncommitted() >= need:
            return
        for rec in sorted((r for r in self._parked.values()
                           if r.pages and r is not keep),
                          key=lambda r: r.parked_step):
            if self._uncommitted() >= need:
                break
            self._offload_parked(rec)

    # -- preemption / restore (storage-backed slot reclamation) -----------------

    def _preempt_for(self, need: int) -> bool:
        """Free at least ``need - uncommitted`` pages by preempting ACTIVE
        victims; all-or-nothing (a partial eviction would pay the offload
        transfer without unblocking the admission)."""
        if self.preempt_policy != "pressure":
            return False
        deficit = need - self._uncommitted()
        victims = [s for s in self.slots
                   if s.state is SlotState.ACTIVE and s.pages
                   and s.age(self.steps) >= self.idle_preempt_steps]
        # idleness-driven ranking: the longest-resident slot first (the
        # mostly-idle long-runner), then the one pinning the most pages
        victims.sort(key=lambda s: (s.age(self.steps), len(s.pages)),
                     reverse=True)
        chosen, freed = [], 0
        for v in victims:
            if freed >= deficit:
                break
            chosen.append(v)
            freed += v.need   # eviction releases pages AND reservation
        if freed < deficit:
            return False
        for v in chosen:
            self._preempt(v)
        return True

    def preempt(self, index: int) -> None:
        """Preempt one ACTIVE slot now (the policy calls this; exposed so
        tests and drivers can force a preemption point)."""
        self._preempt(self.slots[index])

    def _preempt(self, slot: Slot) -> None:
        slot.to(SlotState.PREEMPTED)
        row = self._page_rows[slot.index]
        pidx = [i for i in range(self.max_pages) if row[i] >= 0]
        phys = [int(row[i]) for i in pidx]
        # extract in logical order and stage to host: the blob is position-
        # ordered no matter how scrambled the physical table was
        blob = jax.device_get(
            self._extract(self.cache, jnp.asarray(phys, jnp.int32)))
        nbytes = kvcache.blob_nbytes(blob)
        key = f"kv/{self.blob_ns}{slot.req.request_id}/p{slot.preempts}"
        self.blob_store.put(key, blob, nbytes)
        slot.blob_key = key
        slot.blob_pidx = pidx
        slot.restore_i = 0
        slot.preempts += 1
        # release the slot's whole pool commitment: page references dropped
        # (owned pages free; shared prefix pages keep their other holders),
        # unmapped growth back to the uncommitted margin.  The restore era
        # owns every page it injects — the blob covers shared prefix pages
        # too — so the reservation grows back to the full worst case.
        self._reserved -= slot.need - len(slot.pages)
        self.allocator.release(slot.pages + slot.shared)
        slot.pages = []
        slot.shared = []
        slot.need = self._pages_needed(slot.req)
        self._page_rows[slot.index, :] = -1
        self.cache = kvcache.set_page_row(
            self.cache, slot.index, self._page_rows[slot.index])
        self._preempted_order.append(slot.index)
        self.preemptions += 1
        self.offload_pages += len(phys)

    def _start_restores(self) -> None:
        """Fund restores FIFO in preemption order: a later blob must not
        overtake an earlier one (its session would see out-of-order work)."""
        for idx in list(self._preempted_order):
            slot = self.slots[idx]
            if self._uncommitted() < slot.need:
                # retention must never starve a restore: index references
                # and parked journals are reclaimable cache, a preempted
                # request is real work.  Without this the drain livelocks
                # once retention holds the whole pool (nothing else calls
                # the reclaim path when the pending queue is empty).
                self._reclaim_pool(slot.need)
            if self._uncommitted() < slot.need:
                break
            slot.to(SlotState.RESTORING)
            self._reserved += slot.need
            slot.blob = self.blob_store.get(slot.blob_key)
            self._preempted_order.remove(idx)
            self.restores += 1

    def _run_restore_chunk(self, slot: Slot) -> None:
        """Inject one chunk of a restoring slot's blob: allocate fresh
        physical pages, scatter the blob slice into them, re-map the page
        table.  The final chunk reactivates the slot — it rejoins the decode
        batch the same step, like an admission whose last chunk landed."""
        n = len(slot.blob_pidx)
        hi = min(slot.restore_i + (self._restore_chunk_pages or n), n)
        phys = []
        for j in range(slot.restore_i, hi):
            pid = self.allocator.alloc(1)[0]
            self._reserved -= 1
            slot.pages.append(pid)
            self._page_rows[slot.index, slot.blob_pidx[j]] = pid
            phys.append(pid)
        piece = kvcache.slice_page_blob(slot.blob, slot.restore_i, hi)
        self.cache = self._inject(self.cache, jnp.asarray(phys, jnp.int32),
                                  self._stage_put(piece))
        self.cache = kvcache.set_page_row(
            self.cache, slot.index, self._page_rows[slot.index])
        self.restored_pages += hi - slot.restore_i
        slot.restore_i = hi
        self.restore_chunks += 1
        if hi == n:
            self.blob_store.delete(slot.blob_key)
            slot.blob = None
            slot.blob_key = None
            slot.blob_pidx = []
            slot.to(SlotState.ACTIVE)
            slot.active_since = self.steps

    def drain_offload_ops(self) -> list:
        """Storage ops since the last drain — the frontend bills these under
        the calibrated obj_read/obj_write latency + Table-4 cost models."""
        return self.blob_store.drain_ops()

    def _prepare_write_span(self, slot: Slot, pos0: int, count: int) -> None:
        """Make the pages under ``[pos0, pos0 + count)`` writable for this
        slot: map unmapped pages (alloc-on-write, within the reservation)
        and copy-on-write split any mapped page that another reference
        still reads — the writer gets a private copy on a fresh page and
        remaps only its own table row, so the prefix index / parked journal
        / sibling slot keeps reading the original bytes."""
        changed = False
        hi = min((pos0 + count - 1) // self.page_size, self.max_pages - 1)
        for pidx in range(pos0 // self.page_size, hi + 1):
            pid = int(self._page_rows[slot.index, pidx])
            if pid < 0:
                if len(slot.pages) < slot.need:
                    self._map_page(slot, pidx)
                    changed = True
                # else: reservation exhausted — the dangling final write
                # past it scatters out of bounds and is dropped
            elif self.allocator.refcount(pid) > 1:
                new = self.allocator.alloc(1)[0]
                if pid in slot.shared:
                    # the split of a shared prefix page was part of this
                    # admission's reservation (need counts every writable page)
                    self._reserved -= 1
                    slot.shared.remove(pid)
                else:
                    # an owned page some external holder (index/journal) still
                    # references: swap it out, reservation-neutral
                    slot.pages.remove(pid)
                slot.pages.append(new)
                self.cache = self._copy_pages(
                    self.cache, jnp.asarray([pid], jnp.int32),
                    jnp.asarray([new], jnp.int32))
                self.allocator.release([pid])
                self._page_rows[slot.index, pidx] = new
                self.cow_splits += 1
                changed = True
        if changed:
            self.cache = kvcache.set_page_row(
                self.cache, slot.index, self._page_rows[slot.index])

    def _run_chunk(self, slot: Slot) -> None:
        """One prefill chunk for one admitting slot (alloc-on-write: map the
        pages the chunk's span touches — CoW-splitting any shared boundary
        page — then a B=1 forward against the shared pool).  The final
        chunk's logits seed the slot's first token."""
        chunk = slot.chunks[slot.chunk_i]
        C = len(chunk)
        pos0 = slot.len
        if self._has_kv:
            self._prepare_write_span(slot, pos0, C)
        logits, self.cache = self._chunk(
            self.params, self.cache, jnp.asarray(chunk)[None], slot.index)
        slot.len += C
        slot.chunk_i += 1
        self.prefill_tokens += C
        self.prefill_chunks += 1
        if slot.chunk_i == len(slot.chunks):
            tok = self._sample(logits[:, -1])
            self.last_tokens = self.last_tokens.at[slot.index].set(tok[0])
            self.out_buf = self.out_buf.at[slot.index, 0].set(tok[0])
            self.out_pos = self.out_pos.at[slot.index].set(1)
            slot.to(SlotState.ACTIVE)
            slot.active_since = self.steps
            slot.n_out = 1
            slot.chunks = None
            self.admitted += 1
            if self._spec:
                # one host sync per admission: the draft starts from scratch
                # on the full canonical stream (prompt + first sampled token)
                slot.spec_last = int(tok[0])
                slot.spec_pending = [int(t) for t in
                                     np.asarray(slot.req.prompt,
                                                np.int32).reshape(-1)]
                slot.spec_pending.append(slot.spec_last)
                slot.draft_len = 0
                self.draft_cache["length"] = (
                    self.draft_cache["length"].at[slot.index].set(0))

    # -- decode loop ---------------------------------------------------------------

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        """Host-side sampling: advances the scheduler's PRNG state, then
        defers to the pure helper.  Never called from traced code — the
        jitted step takes its subkey as an argument instead."""
        key = None
        if self.temperature > 0.0:
            self._key, key = jax.random.split(self._key)
        return self._sample_pure(logits, key)

    def _sample_pure(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        """Trace-safe sampling: no host state touched, key passed in."""
        if self.temperature <= 0.0:
            return sampling.greedy(logits)
        return sampling.temperature_sample(key, logits, self.temperature,
                                           self.top_k)

    def _step_impl(self, params, cache, last_tokens, out_buf, out_pos, active, key):
        """Jitted: decode one token per *active* slot, sample, append to the
        output ring.  Pure device program — nothing returns to the host.

        ``active`` (n_slots,) bool masks freed, mid-admission, and preempted
        slots out of the token write, the output-ring advance, and every
        per-slot cache row: without the mask a stale slot keeps advancing its
        length and evolving its recurrent state, which corrupts the pool
        pages (and the admission-in-progress) that position now belongs to.
        """
        from ..dist import sharding as shd

        with _policy_scope(self._policy):
            logits, new_cache = self.model.decode_step(params, cache,
                                                       last_tokens[:, None])
            new_cache = kvcache.mask_slot_rows(new_cache, cache, active)
            new_cache = shd.constrain_tree(new_cache, self.cache_specs,
                                           getattr(self._policy, "mesh", None))
            toks = self._sample_pure(logits[:, -1], key)
            toks = jnp.where(active, toks, last_tokens)
            b = jnp.arange(self.n_slots, dtype=jnp.int32)
            # inactive rows scatter out of bounds -> dropped
            col = jnp.where(active, out_pos % self.max_seq, self.max_seq)
            out_buf = out_buf.at[b, col].set(toks)
        return new_cache, toks, out_buf, out_pos + active.astype(jnp.int32)

    def _spec_round(self, active: List[int]) -> None:
        """One draft-and-verify round over the ACTIVE slots: the draft
        proposes ``spec_k`` tokens per slot, the target scores all of them
        in one chunked step against the shared paged pool, and each slot
        emits the accepted prefix plus the target's bonus/correction token
        (1..spec_k+1 tokens per round, token-for-token what S=1 decode
        would emit).

        Host/device discipline: one device->host sync per round (the
        accepted counts + emitted tokens).  Draft catch-up chunks replay the
        canonical tokens the draft has not consumed — the whole prompt on a
        fresh admission, 1-2 tokens per round thereafter — and rejected
        proposals rewind the draft row's length, so the draft cache tracks
        exactly the canonical stream.

        Rollback on rejection: KV pages need no copy — the verify chunk's
        over-run positions sit past the rewound length (invalid to every
        read) and the next round's chunk overwrites them before they can
        become visible; pages mapped or CoW-split for the span stay with
        the slot (refcount/free-list state is untouched by a reject).
        Hybrid recurrent rows DID advance through rejected tokens, so a
        partial accept restores the pre-verify row snapshot and replays the
        accepted span through the chunk path — bitwise the same KV and
        recurrent state, by the chunk-prefix property."""
        k = self.spec_k
        spec = [self.slots[i] for i in active]
        mask = np.zeros((self.n_slots,), bool)
        mask[active] = True
        mask_dev = jnp.asarray(mask)
        # 1) draft catch-up on the canonical stream: ONE batched masked
        #    dispatch over every slot's pending span (back-padded to the
        #    round's widest; each row advances by its own count).  Replaces
        #    the per-slot B=1 chunks — a round's catch-up no longer costs
        #    one dispatch per active slot.
        W = max((len(st.spec_pending) for st in spec), default=1)
        tok_rows = np.zeros((self.n_slots, W), np.int32)
        cnt_rows = np.ones((self.n_slots,), np.int32)
        for st in spec:
            n = len(st.spec_pending)
            tok_rows[st.index, :n] = st.spec_pending
            cnt_rows[st.index] = n
        self.draft_cache, draft_last = self._draft_catchup(
            self.draft_params, self.draft_cache, jnp.asarray(tok_rows),
            jnp.asarray(cnt_rows), mask_dev)
        for st in spec:
            st.draft_len += len(st.spec_pending)
            st.spec_pending = []
        # 2) k-1 batched draft steps finish the proposal window
        cols = [draft_last]
        for _ in range(k - 1):
            self.draft_cache, draft_last = self._draft_step(
                self.draft_params, self.draft_cache, draft_last, mask_dev)
            cols.append(draft_last)
        drafts = jnp.stack(cols, axis=1)                     # (n_slots, k)
        # 3) make the verify span writable (alloc-on-write + CoW split,
        #    same as decode growth but k+1 positions at once) and clamp
        #    each slot's acceptance so it cannot overrun its max_new budget
        k_eff = np.zeros((self.n_slots,), np.int32)
        for st in spec:
            self._prepare_write_span(st, st.len, k + 1)
            k_eff[st.index] = min(k, st.req.max_new - st.n_out - 1)
        verify_tokens = jnp.concatenate(
            [self.last_tokens[:, None], drafts], axis=1)     # (n_slots, k+1)
        hybrid = self.model.cfg.family == "hybrid"
        pre_cache = self.cache if hybrid else None
        (self.cache, y, a_vec, self.out_buf, self.out_pos,
         self.last_tokens) = self._verify(
            self.params, self.cache, verify_tokens, mask_dev,
            jnp.asarray(k_eff), self.out_buf, self.out_pos, self.last_tokens)
        y_h, a_h = jax.device_get((y, a_vec))     # the round's one host sync
        for st in spec:
            i = st.index
            a = int(a_h[i])
            if hybrid and a < k:
                # recurrent rows consumed all k+1 verify tokens; restore the
                # pre-verify snapshot and replay the canonical span (the
                # pending token + the a accepted drafts) through the chunk
                # path.  KV under the replay is rewritten bitwise-identically
                # (chunk-prefix property), so pages need no rollback.
                state = self._gather_state(pre_cache, i)
                self.cache = self._scatter_state(self.cache, i, state)
                replay = [st.spec_last] + [int(t) for t in y_h[i, :a]]
                _, self.cache = self._chunk(
                    self.params, self.cache,
                    jnp.asarray(replay, jnp.int32)[None], i)
            emitted = a + 1
            st.n_out += emitted
            st.len += emitted
            st.spec_last = int(y_h[i, a])
            adv = min(a, k - 1)       # drafts d1..d_adv proved canonical
            st.draft_len += adv
            # canonical tokens the draft has not consumed: y[adv..a]
            st.spec_pending = [int(t) for t in y_h[i, adv:a + 1]]
            self.spec_accepted += a
            self.spec_emitted += emitted
            self.decode_tokens += emitted
        # rejected proposals rewind the draft rows to the canonical length
        idx = jnp.asarray([st.index for st in spec], jnp.int32)
        vals = jnp.asarray([st.draft_len for st in spec], jnp.int32)
        self.draft_cache["length"] = self.draft_cache["length"].at[idx].set(vals)
        self.spec_proposed += k * len(spec)
        self.spec_rounds += 1

    def step(self) -> List[CompletedRequest]:
        """One scheduler tick: at most one prefill chunk (round-robin over
        admitting slots) and one restore chunk (round-robin over restoring
        slots), then one batched decode step — or, with speculation on, one
        draft-and-verify round — over the active slots; returns the requests
        that completed this step (their slots are refilled from the pending
        list before returning)."""
        self._fill_slots()
        admitting = [s for s in self.slots if s.state is SlotState.ADMITTING]
        if admitting:
            pick = admitting[self._chunk_rr % len(admitting)]
            self._chunk_rr += 1
            self._run_chunk(pick)
        restoring = [s for s in self.slots if s.state is SlotState.RESTORING]
        if restoring:
            pick = restoring[self._restore_rr % len(restoring)]
            self._restore_rr += 1
            self._run_restore_chunk(pick)
        active = [s.index for s in self.slots if s.decoding]
        if not active:
            return []
        if self._spec:
            self._spec_round(active)
        else:
            if self.kv_mode == "paged" and self._has_kv:
                # alloc-on-write for decode growth: make the page this step's
                # token write lands in writable — map it if unmapped (within
                # the reservation; the final step's dangling write past it is
                # dropped by the unmapped table), CoW-split it if shared
                for i in active:
                    st = self.slots[i]
                    self._prepare_write_span(st, st.len, 1)
            mask = np.zeros((self.n_slots,), bool)
            mask[active] = True
            self._key, sub = jax.random.split(self._key)
            self.cache, self.last_tokens, self.out_buf, self.out_pos = \
                self._decode(
                    self.params, self.cache, self.last_tokens, self.out_buf,
                    self.out_pos, jnp.asarray(mask), sub)
            self.decode_tokens += len(active)
            for i in active:
                st = self.slots[i]
                st.n_out += 1
                if self.kv_mode == "paged":
                    st.len += 1
        self.steps += 1
        self.slot_steps += len(active)
        if self.kv_mode == "paged" and self._has_kv:
            self.page_step_sum += self.allocator.in_use
        finished: List[CompletedRequest] = []
        for i in active:
            st = self.slots[i]
            if st.n_out >= st.req.max_new:
                req = st.req
                st.to(SlotState.DRAINED)
                tokens = np.asarray(self.out_buf[i, : req.max_new])
                finished.append(CompletedRequest(
                    session=req.session, request_id=req.request_id,
                    tokens=tokens,
                    admitted_step=st.admitted_step, finished_step=self.steps,
                    submitted_step=st.submitted_step, preempts=st.preempts,
                    reused_tokens=st.reused))
                if self.park_sessions:
                    self._park_slot(st, req, tokens)
                else:
                    if self._index_sharing:
                        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
                        self._publish_index(
                            self._page_rows[st.index],
                            np.concatenate([prompt, tokens.astype(np.int32)]),
                            hashes=req.hashes)
                    self._release_slot(st)
                self._active_sessions.discard(req.session)
                self.completed += 1
        if finished:
            self._fill_slots()
        return finished

    def reset(self, *, clear_blob_store: bool = True) -> None:
        """Abort all in-flight work (crash recovery: the queue layer
        redelivers; completed requests are deduped by the frontend).  The
        pool returns to fully free, every page-table row to unmapped, the
        blob store is emptied, and the prefix index and parked-session table
        are cleared — a redelivered admission replays from its prompt, never
        from an orphaned blob or another life's shared pages.

        ``clear_blob_store=False`` is the *fleet* recycle path: when this
        scheduler is one disposable worker over a store shared with its
        siblings, wiping the store would destroy other workers' spills and
        every externalized session journal / index entry — exactly the
        durable state scale-to-zero exists to keep.  The fleet controller
        garbage-collects a dead worker's namespaced keys itself."""
        self.slots = [s.force_empty() for s in self.slots]
        self.pending = []
        self._active_sessions.clear()
        self._preempted_order = []
        # replay determinism: the post-reset schedule must be a pure
        # function of the submitted work, not of the previous life's
        # round-robin phase or sampling-key position
        self._chunk_rr = 0
        self._restore_rr = 0
        self._key = jax.random.key(self._seed)
        # allocator.reset() below wipes every reference wholesale, so the
        # index and parked table just forget their entries
        self.prefix_index.clear()
        self._parked.clear()
        self.last_tokens = jnp.zeros((self.n_slots,), jnp.int32)
        self.out_buf = jnp.zeros((self.n_slots, self.max_seq), jnp.int32)
        self.out_pos = jnp.zeros((self.n_slots,), jnp.int32)
        if clear_blob_store:
            self.blob_store.clear()
        if self.kv_mode == "paged":
            self.allocator.reset()
            self._reserved = 0
            self._page_rows[:] = -1
            for slot in range(self.n_slots):
                self.cache = kvcache.cache_clear_slot(self.cache, slot)
        if self._spec:
            # draft rows replay from scratch at the next admission; zero
            # lengths so stale ring lanes are invalid until overwritten
            self.draft_cache["length"] = jnp.zeros_like(
                self.draft_cache["length"])

    # -- invariant audit (the differential harness calls this every step) ----------

    def audit(self) -> None:
        """Raise AssertionError if any allocator / refcount / reservation
        invariant is violated.  Checks: ``free + in_use == n_pages``; every
        mapped page has refcount >= 1; the refcount total equals the
        references actually held (slot owned + slot shared + parked journals
        + prefix index); no page is owned by two slots; every page-table row
        maps exactly the pages its slot holds; the reservation ledger equals
        the outstanding worst-case growth; parked records and PARKED slots
        point at each other consistently."""
        if not (self.kv_mode == "paged" and self._has_kv):
            return
        a = self.allocator
        a.check()
        refs = 0
        owned_seen: set = set()
        for s in self.slots:
            refs += len(s.pages) + len(s.shared)
            for p in s.pages:
                assert p not in owned_seen, f"page {p} owned by two slots"
                owned_seen.add(p)
        for rec in self._parked.values():
            refs += len(rec.pages)
        refs += len(self.prefix_index)
        assert refs == a.total_refs, (
            f"refcount drift: holders sum to {refs}, allocator says "
            f"{a.total_refs}")
        for s in self.slots:
            row = self._page_rows[s.index]
            mapped = {int(p) for p in row if p >= 0}
            held = set(s.pages) | set(s.shared)
            assert mapped == held, (
                f"slot {s.index} ({s.state.value}): row maps {mapped}, "
                f"holds {held}")
            for p in mapped:
                assert a.refcount(p) >= 1, f"slot {s.index} maps freed page {p}"
        reserved = sum(
            s.need - len(s.pages) for s in self.slots
            if s.state in (SlotState.ADMITTING, SlotState.ACTIVE,
                           SlotState.RESTORING))
        assert reserved == self._reserved, (
            f"reservation ledger drift: slots imply {reserved}, "
            f"ledger says {self._reserved}")
        assert self._uncommitted() >= 0, (
            f"over-committed pool: {self._reserved} reserved, "
            f"{a.free_count} free")
        for session, rec in self._parked.items():
            if rec.slot is not None:
                s = self.slots[rec.slot]
                assert s.state is SlotState.PARKED and s.session == session, (
                    f"parked record {session} points at slot {rec.slot} "
                    f"in state {s.state.value} (session {s.session})")
            assert bool(rec.blob_key) != bool(rec.pages) or not rec.pages, (
                f"parked record {session} is both resident and offloaded")
        parked_sessions = {s.session for s in self.slots if s.parked}
        for sess in parked_sessions:
            assert sess in self._parked and self._parked[sess].slot is not None, (
                f"PARKED slot for session {sess} has no resident record")

    # -- reporting ------------------------------------------------------------------

    def occupancy(self) -> float:
        """Mean active slots per decode step (the batching lever)."""
        return self.slot_steps / self.steps if self.steps else 0.0

    def pool_occupancy(self) -> float:
        """Mean fraction of the pool in use per decode step."""
        if not (self.kv_mode == "paged" and self._has_kv and self.steps
                and self.n_pages):
            return 0.0
        return self.page_step_sum / (self.steps * self.n_pages)

    def kv_memory_stats(self) -> Dict[str, float]:
        """KV bytes: allocated pool/ring footprint and the live high-water
        mark (what the pool actually touched — the paged-vs-ring lever)."""
        per_token = kvcache.kv_bytes_per_token(self.cache)
        if self.kv_mode == "paged":
            return {
                "kv_bytes_per_token": per_token,
                "kv_pool_bytes": per_token * self.n_pages * self.page_size,
                "kv_high_water_bytes":
                    per_token * self.allocator.high_water * self.page_size,
                "kv_pages": self.n_pages,
                "kv_pages_high_water": self.allocator.high_water,
                "kv_pages_in_use": self.allocator.in_use,
                "kv_pool_occupancy": round(self.pool_occupancy(), 3),
            }
        ring_tokens = 0
        if self._has_kv:
            cache_len = getattr(self.model, "cache_len", None)
            ring_tokens = (cache_len(self.max_seq) if cache_len else self.max_seq)
        return {
            "kv_bytes_per_token": per_token,
            "kv_pool_bytes": per_token * self.n_slots * ring_tokens,
            "kv_high_water_bytes": per_token * self.n_slots * ring_tokens,
        }

    def offload_stats(self) -> Dict[str, float]:
        """Offload traffic gauges: preempt/restore counts, page counts, and
        the byte flows to/from the blob store (bytes_out = offloaded,
        bytes_in = restored)."""
        bs = self.blob_store
        return {
            "preemptions": self.preemptions,
            "restores": self.restores,
            "restore_chunks": self.restore_chunks,
            "offload_pages": self.offload_pages,
            "restored_pages": self.restored_pages,
            "offload_puts": bs.puts,
            "offload_gets": bs.gets,
            "offload_bytes": bs.bytes_out,
            "restore_bytes": bs.bytes_in,
            "offload_stored_bytes": bs.bytes_stored,
            "offload_stored_high_water_bytes": bs.high_water_bytes,
        }

    def sharing_stats(self) -> Dict[str, float]:
        """Prefix-sharing / parking gauges: prompt tokens served from
        resident pages instead of re-prefilled, hit/miss counts, CoW
        splits, and the parked-retention flows."""
        return {
            "shared_prefix_tokens": self.shared_prefix_tokens,
            "park_hits": self.park_hits,
            "park_misses": self.park_misses,
            "index_hits": self.index_hits,
            "cow_splits": self.cow_splits,
            "parks": self.parks,
            "park_evictions": self.park_evictions,
            "park_offloads": self.park_offloads,
            "park_expirations": self.park_expirations,
            "parked_sessions": len(self._parked),
            "index_pages": len(self.prefix_index),
            "index_journal_puts": self.index_journal_puts,
            "index_adopted": self.index_adopted,
        }

    def spec_stats(self) -> Dict[str, float]:
        """Speculation gauges: acceptance rate (accepted / proposed drafts)
        and verify steps per emitted token (1.0 = no speedup; 1/(k+1) =
        every draft accepted) — the cost lever is that one verify round
        prices like one decode step but emits up to k+1 tokens."""
        return {
            "spec_k": self.spec_k,
            "spec_rounds": self.spec_rounds,
            "spec_proposed": self.spec_proposed,
            "spec_accepted": self.spec_accepted,
            "spec_emitted": self.spec_emitted,
            "spec_acceptance_rate": round(
                self.spec_accepted / self.spec_proposed, 4)
                if self.spec_proposed else 0.0,
            "spec_steps_per_token": round(
                self.spec_rounds / self.spec_emitted, 4)
                if self.spec_emitted else 0.0,
        }

    def stats(self) -> Dict[str, float]:
        out = {
            "steps": self.steps,
            "occupancy": round(self.occupancy(), 3),
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "admitted": self.admitted,
            "completed": self.completed,
            "kv_mode": self.kv_mode,
            "attn_backend": self.attn_backend,
        }
        if self.kv_mode == "paged":
            out["prefill_chunks"] = self.prefill_chunks
        if self.offload:
            out.update(self.offload_stats())
        if self.prefix_sharing or self.park_sessions:
            out.update(self.sharing_stats())
        if self._spec:
            out.update(self.spec_stats())
        return out
