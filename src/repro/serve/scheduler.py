"""Slot-based continuous-batching decode scheduler over a paged KV pool.

A fixed-width decode batch (``n_slots``) steps one token per active slot per
call; free slots are re-admitted from a shared cross-session queue of pending
requests.  Every slot runs the explicit lifecycle in
:mod:`repro.serve.lifecycle`::

    EMPTY -> ADMITTING -> ACTIVE -> (PREEMPTED -> RESTORING -> ACTIVE)* -> DRAINED

Two KV layouts:

* ``kv_mode='paged'`` (default): one shared ``(n_pages, page_size, Hkv, D)``
  pool per layer plus a per-slot page table
  (:func:`repro.models.kvcache.paged_cache`).  Pages are handed out by a
  host-side free list (:class:`repro.models.kvcache.PageAllocator`) —
  mapped on first write, freed on completion — so KV memory scales with
  *live tokens*, not ``n_slots * max_seq``.  Admission is **chunked**: the
  prompt is split into ``prefill_chunk``-sized pieces and one chunk runs per
  :meth:`step` call (a B=1 forward against the shared pool, interleaved with
  the batch's decode step), so a long-prompt admission never stalls the
  other slots for more than one chunk.  Admission is reservation-gated: a
  request is only admitted when the pool's uncommitted pages cover its worst
  case, so lazy mapping can never deadlock mid-decode.

* ``kv_mode='ring'``: the PR 2 baseline — per-slot rings sized ``max_seq``
  and monolithic prefill-on-admit.

**Storage-backed preemption** (``offload=True``, paged mode): the FaaSKeeper
move — durable state belongs in cloud storage, compute is ephemeral and
reclaimable — applied to the KV pool.  When a pending request is pool-gated
(an admission stall), the preemption policy picks victim slots among the
ACTIVE ones (oldest resident first — the idleness signal — then most pages
pinned; ``idle_preempt_steps`` sets the minimum residency so fresh slots are
never thrashed), extracts each victim's pages through its page table into a
position-ordered blob (:func:`kvcache.gather_pages`), PUTs it to the
:class:`repro.core.storage.PageBlobStore`, and frees the pages *and* the
victim's whole reservation back to the pool.  The victim parks in PREEMPTED:
its slot row (recurrent state, lengths, output ring) stays frozen under the
decode mask, but it pins zero pool capacity.  When pool pressure clears (no
pending request is pool-gated and the uncommitted margin covers the
victim's worst case again), the slot funds a restore: the blob is fetched
and injected **chunk by chunk, interleaved with decode exactly like prefill
chunks** (:func:`kvcache.scatter_pages` onto freshly allocated pages, the
page table re-mapped), and the slot resumes ACTIVE — token-for-token
identical to a never-preempted run, because the gather/scatter pair is an
exact inverse through the page table and the masked rows never advanced.
Restores are FIFO in preemption order and, once funded, run to completion
(RESTORING slots are never re-preempted), so offload cannot deadlock or
livelock the pool.  Storage traffic is journaled on the blob store and
billed by the serving frontend under the calibrated object-store models.

Either way the batched decode step masks non-ACTIVE slots out of the token
write, the output ring advance, and every per-slot cache row
(``kvcache.mask_slot_rows``): a freed, mid-admission, or preempted slot's
stale state cannot advance, and its dangling pool writes are dropped by the
unmapped page table.

Per-session FIFO is preserved structurally: a session's next request is only
admitted after its predecessor completes (the ``_active_sessions`` gate), and
the pending list is scanned in arrival order.

``mesh`` applies :func:`repro.dist.sharding.cache_shardings` to the live
decode cache; with offload enabled the staging-buffer specs resolve through
:func:`repro.dist.sharding.offload_stage_shardings` into ``stage_specs``.

Supported families: ``dense``, ``moe``, ``ssm``, ``hybrid`` (decoder-only
LMs; the enc-dec families keep the whole-batch serving path).  SSM keeps its
ring-free O(1) state — no pool, so nothing to offload, but admission still
chunks.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.storage import PageBlobStore
from ..models import kvcache
from . import sampling
from .engine import make_chunk_step, make_offload_steps
from .lifecycle import Slot, SlotState

CONTINUOUS_FAMILIES = ("dense", "moe", "ssm", "hybrid")

PREEMPT_POLICIES = ("none", "pressure")


def supports_continuous(cfg) -> bool:
    return getattr(cfg, "family", None) in CONTINUOUS_FAMILIES


@dataclasses.dataclass
class _Request:
    session: str
    request_id: str
    prompt: Any                 # (P,) int tokens
    max_new: int
    submit_step: int = 0


@dataclasses.dataclass
class CompletedRequest:
    session: str
    request_id: str
    tokens: np.ndarray          # (max_new,) generated tokens
    admitted_step: int
    finished_step: int
    submitted_step: int = 0     # admission stall = admitted - submitted
    preempts: int = 0           # times this request was preempted mid-decode


class DecodeScheduler:
    """Continuous batching over a shared paged pool (or per-slot rings)."""

    def __init__(self, model, params, *, n_slots: int = 4, max_seq: int = 64,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 mesh=None, kv_mode: str = "paged", page_size: int = 16,
                 kv_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 offload: bool = False,
                 preempt_policy: Optional[str] = None,
                 idle_preempt_steps: int = 0,
                 blob_store: Optional[PageBlobStore] = None):
        if not supports_continuous(model.cfg):
            raise ValueError(
                f"family {model.cfg.family!r} has no per-slot decode path; "
                f"continuous batching supports {CONTINUOUS_FAMILIES}")
        if kv_mode not in ("paged", "ring"):
            raise ValueError(f"kv_mode must be 'paged' or 'ring', got {kv_mode!r}")
        if preempt_policy is None:
            preempt_policy = "pressure" if offload else "none"
        if preempt_policy not in PREEMPT_POLICIES:
            raise ValueError(f"preempt_policy must be one of {PREEMPT_POLICIES}, "
                             f"got {preempt_policy!r}")
        if offload and kv_mode != "paged":
            raise ValueError("KV offload needs the paged pool (kv_mode='paged'); "
                             "per-slot rings have no page granularity to evict")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.temperature = temperature
        self.top_k = top_k
        self.kv_mode = kv_mode
        self._key = jax.random.key(seed)
        self._has_kv = model.cfg.family != "ssm"   # SSM state is ring-free
        self.offload = bool(offload) and kv_mode == "paged" and self._has_kv
        self.preempt_policy = preempt_policy if self.offload else "none"
        self.idle_preempt_steps = idle_preempt_steps

        if kv_mode == "paged":
            self.page_size = page_size
            self.max_pages = -(-max_seq // page_size)
            self.n_pages = (kv_pages if kv_pages is not None
                            else n_slots * self.max_pages)
            if self._has_kv and self.n_pages < self.max_pages:
                raise ValueError(
                    f"kv_pages={self.n_pages} cannot hold even one slot's "
                    f"max_pages={self.max_pages}")
            self.prefill_chunk = prefill_chunk   # None -> whole prompt, one chunk
            self.allocator = kvcache.PageAllocator(
                self.n_pages if self._has_kv else 0)
            # host mirror of the device page table + pages committed to
            # admitted-but-not-yet-mapped growth (the admission gate)
            self._page_rows = np.full((n_slots, self.max_pages), -1, np.int32)
            self._reserved = 0
            self.cache = kvcache.paged_cache(
                model, n_slots, page_size=page_size, n_pages=self.n_pages,
                max_pages=self.max_pages)
            self._chunk = jax.jit(make_chunk_step(model))
        else:
            self.cache = kvcache.batched_cache(model, n_slots, max_seq)
            self._prefill = jax.jit(
                lambda p, toks: model.prefill(p, toks, seq_len=max_seq))

        # -- offload plumbing ------------------------------------------------
        self.blob_store = blob_store if blob_store is not None else PageBlobStore()
        self._extract, self._inject = make_offload_steps()
        # restore chunking mirrors prefill chunking: a restore step moves
        # about one prefill chunk's worth of tokens (>= 1 page)
        self._restore_chunk_pages = (
            max(1, self.prefill_chunk // self.page_size)
            if kv_mode == "paged" and self.prefill_chunk else None)
        self._preempted_order: List[int] = []   # slot indices, FIFO restores
        self.preemptions = 0
        self.restores = 0
        self.restore_chunks = 0
        self.offload_pages = 0
        self.restored_pages = 0

        self.cache_specs = None
        self.stage_specs = None
        if mesh is not None:
            from ..dist.sharding import cache_shardings, offload_stage_shardings

            shardings = cache_shardings(self.cache, mesh)
            self.cache_specs = jax.tree_util.tree_map(
                lambda s: s.spec, shardings)
            if self.offload:
                stage = jax.eval_shape(
                    lambda c: kvcache.gather_pages(c, jnp.zeros((1,), jnp.int32)),
                    self.cache)
                self.stage_specs = jax.tree_util.tree_map(
                    lambda s: s.spec, offload_stage_shardings(stage, mesh))
            if isinstance(mesh, jax.sharding.Mesh):   # concrete: place the cache
                self.cache = jax.device_put(self.cache, shardings)

        self._decode = jax.jit(self._step_impl)

        self.slots: List[Slot] = [Slot(index=i) for i in range(n_slots)]
        self.last_tokens = jnp.zeros((n_slots,), jnp.int32)
        # device-side per-slot output ring: tokens accumulate on device and
        # are pulled to host once per *completion*, not once per step — a
        # decode step is a single async dispatch with no host sync
        self.out_buf = jnp.zeros((n_slots, max_seq), jnp.int32)
        self.out_pos = jnp.zeros((n_slots,), jnp.int32)
        self.pending: List[_Request] = []
        self._active_sessions: set = set()
        self._chunk_rr = 0            # round-robin over admitting slots
        self._restore_rr = 0          # round-robin over restoring slots
        # -- occupancy / throughput accounting --------------------------------
        self.steps = 0
        self.slot_steps = 0           # sum over steps of active slots
        self.page_step_sum = 0        # sum over steps of pages in use
        self.prefill_tokens = 0
        self.prefill_chunks = 0
        self.decode_tokens = 0
        self.admitted = 0
        self.completed = 0

    # -- admission ----------------------------------------------------------------

    def submit(self, session: str, request_id: str, prompt, max_new: int) -> None:
        """Enqueue a request; admitted into a free slot as soon as its
        session has no in-flight predecessor (per-session FIFO gate) and —
        in paged mode — the pool's uncommitted pages cover its worst case
        (or the preemption policy can evict enough to make them).

        ``max_new`` is clamped to what the slot can hold without silent
        corruption: the output ring caps it at ``max_seq``, and on a
        full-attention KV layout (no sliding window — detected via
        ``cache_len``) generation past ``max_seq - len(prompt)`` would wrap
        the ring / run off the page table, so the budget stops there; a
        prompt that leaves no decode room at all is rejected outright
        (clamping would silently drop its leading tokens).  Windowed rings
        wrap by design; the paged table is linear, so windowed families are
        bounded by its ``max_pages * page_size`` span instead.  SSM states
        never bound the budget beyond the output ring.
        """
        prompt = np.asarray(prompt)
        P = int(prompt.shape[-1])
        limit = self.max_seq
        cache_len = getattr(self.model, "cache_len", None)
        has_full_ring = (self._has_kv
                         and cache_len is not None
                         and cache_len(self.max_seq + 1) > self.max_seq)
        if has_full_ring:
            room = self.max_seq - P
            if room <= 0:
                raise ValueError(
                    f"request {request_id!r}: prompt of {P} "
                    f"tokens leaves no decode room in the max_seq={self.max_seq} "
                    "full-attention ring; size max_seq >= prompt + max_new")
            limit = min(limit, room)
        elif self.kv_mode == "paged" and self._has_kv:
            # windowed attention wraps a ring but cannot wrap the linear
            # page table: bound the budget by the table's span
            room = self.max_pages * self.page_size - P
            if room <= 0:
                raise ValueError(
                    f"request {request_id!r}: prompt of {P} tokens overruns "
                    f"the {self.max_pages}x{self.page_size} page table")
            limit = min(limit, room)
        max_new = max(1, min(max_new, limit))
        self.pending.append(_Request(session, request_id, prompt, max_new,
                                     submit_step=self.steps))
        self._fill_slots()

    def busy(self) -> bool:
        return any(s.occupied for s in self.slots) or bool(self.pending)

    def free_slots(self) -> int:
        return sum(1 for s in self.slots if s.empty)

    def active_slots(self) -> int:
        """Slots decoding+sampling this step (admitting/preempted excluded)."""
        return sum(1 for s in self.slots if s.decoding)

    def admitting_slots(self) -> int:
        return sum(1 for s in self.slots if s.state is SlotState.ADMITTING)

    def preempted_slots(self) -> int:
        return sum(1 for s in self.slots
                   if s.state in (SlotState.PREEMPTED, SlotState.RESTORING))

    def wants_more(self) -> bool:
        """Whether claiming more queued work could improve occupancy.

        Any free slot justifies claiming deeper: a FIFO queue can hold a long
        run of one session's (gated) requests in front of another session's
        admissible one, so the lookahead must not be capped — held-back
        requests wait in ``pending`` in arrival order and are requeued on a
        crash, so over-claiming never loses or reorders work."""
        return self.free_slots() > 0

    def _pages_needed(self, req: _Request) -> int:
        """Worst-case page count: prompt + all decode writes (the completing
        step samples its last token from a write at P + max_new - 2)."""
        if not (self.kv_mode == "paged" and self._has_kv):
            return 0
        tokens = int(np.asarray(req.prompt).shape[-1]) + req.max_new - 1
        return -(-tokens // self.page_size)

    def _uncommitted(self) -> int:
        """Pool pages not yet promised to anyone (the admission currency)."""
        return self.allocator.free_count - self._reserved

    def _fill_slots(self) -> None:
        held: List[_Request] = []
        held_sessions: set = set()    # a held request gates its whole session:
        # a page-starved r0 must not be overtaken by its session's smaller r1
        pool_starved = False
        for req in self.pending:
            slot = next((s for s in self.slots if s.empty), None)
            if slot is None:
                held.append(req)
                held_sessions.add(req.session)
                continue
            if req.session in self._active_sessions or req.session in held_sessions:
                held.append(req)      # FIFO gate: predecessor decoding or held
                held_sessions.add(req.session)
                continue
            need = self._pages_needed(req)
            if need and self._uncommitted() < need:
                # pool gate: try the preemption policy before holding
                if not self._preempt_for(need):
                    pool_starved = True
                    held.append(req)
                    held_sessions.add(req.session)
                    continue
            self._admit(slot, req, need)
        self.pending = held
        # restores only start when pool pressure has cleared: no pending
        # request is pool-gated, and the uncommitted margin funds the
        # victim's whole worst case (prevents preempt<->restore thrash)
        if not pool_starved:
            self._start_restores()

    def _admit(self, slot: Slot, req: _Request, need: int = 0) -> None:
        if self.kv_mode == "paged":
            self._admit_paged(slot, req, need)
            return
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]      # (1, P)
        logits, one = self._prefill(self.params, prompt)
        tok = self._sample(logits[:, -1])                      # (1,)
        self.cache = kvcache.cache_insert_slot(self.cache, one, slot.index)
        self.last_tokens = self.last_tokens.at[slot.index].set(tok[0])
        self.out_buf = self.out_buf.at[slot.index, 0].set(tok[0])
        self.out_pos = self.out_pos.at[slot.index].set(1)
        slot.to(SlotState.ADMITTING).to(SlotState.ACTIVE)  # monolithic prefill
        slot.req = req
        slot.n_out = 1
        slot.admitted_step = self.steps
        slot.submitted_step = req.submit_step
        slot.active_since = self.steps
        self._active_sessions.add(req.session)
        self.prefill_tokens += int(prompt.shape[1])
        self.admitted += 1

    def _admit_paged(self, slot: Slot, req: _Request, need: int) -> None:
        """Begin a chunked admission: clear the slot's rows (fresh length,
        recurrent state, unmapped page-table row) and stage the prompt's
        chunks; one chunk runs per step() until the last lands."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        chunk = self.prefill_chunk or len(prompt)
        chunks = [prompt[i:i + chunk] for i in range(0, len(prompt), chunk)]
        self.cache = kvcache.cache_clear_slot(self.cache, slot.index)
        self._page_rows[slot.index, :] = -1
        self._reserved += need
        slot.to(SlotState.ADMITTING)
        slot.req = req
        slot.chunks = chunks
        slot.chunk_i = 0
        slot.len = 0                  # host mirror of the slot's live length
        slot.pages = []
        slot.need = need
        slot.admitted_step = self.steps
        slot.submitted_step = req.submit_step
        self._active_sessions.add(req.session)

    def _map_page(self, slot: Slot, page_idx: int) -> None:
        """Host-side mapping only — the caller pushes the updated row to the
        device once per chunk/step (one dispatch per row, not per page)."""
        pid = self.allocator.alloc(1)[0]
        self._page_rows[slot.index, page_idx] = pid
        slot.pages.append(pid)
        self._reserved -= 1

    def _release_slot(self, slot: Slot) -> None:
        """Free a DRAINED slot's pages and any unused reservation; unmap its
        device page-table row so residual decode traffic is dropped."""
        slot.to(SlotState.EMPTY)
        if not (self.kv_mode == "paged" and self._has_kv):
            self.slots[slot.index] = Slot(index=slot.index)
            return
        self._reserved -= slot.need - len(slot.pages)
        if slot.pages:
            self.allocator.free(slot.pages)
        self._page_rows[slot.index, :] = -1
        self.cache = kvcache.set_page_row(
            self.cache, slot.index, self._page_rows[slot.index])
        self.slots[slot.index] = Slot(index=slot.index)

    # -- preemption / restore (storage-backed slot reclamation) -----------------

    def _preempt_for(self, need: int) -> bool:
        """Free at least ``need - uncommitted`` pages by preempting ACTIVE
        victims; all-or-nothing (a partial eviction would pay the offload
        transfer without unblocking the admission)."""
        if self.preempt_policy != "pressure":
            return False
        deficit = need - self._uncommitted()
        victims = [s for s in self.slots
                   if s.state is SlotState.ACTIVE and s.pages
                   and s.age(self.steps) >= self.idle_preempt_steps]
        # idleness-driven ranking: the longest-resident slot first (the
        # mostly-idle long-runner), then the one pinning the most pages
        victims.sort(key=lambda s: (s.age(self.steps), len(s.pages)),
                     reverse=True)
        chosen, freed = [], 0
        for v in victims:
            if freed >= deficit:
                break
            chosen.append(v)
            freed += v.need   # eviction releases pages AND reservation
        if freed < deficit:
            return False
        for v in chosen:
            self._preempt(v)
        return True

    def preempt(self, index: int) -> None:
        """Preempt one ACTIVE slot now (the policy calls this; exposed so
        tests and drivers can force a preemption point)."""
        self._preempt(self.slots[index])

    def _preempt(self, slot: Slot) -> None:
        slot.to(SlotState.PREEMPTED)
        row = self._page_rows[slot.index]
        pidx = [i for i in range(self.max_pages) if row[i] >= 0]
        phys = [int(row[i]) for i in pidx]
        # extract in logical order and stage to host: the blob is position-
        # ordered no matter how scrambled the physical table was
        blob = jax.device_get(
            self._extract(self.cache, jnp.asarray(phys, jnp.int32)))
        nbytes = kvcache.blob_nbytes(blob)
        key = f"kv/{slot.req.request_id}/p{slot.preempts}"
        self.blob_store.put(key, blob, nbytes)
        slot.blob_key = key
        slot.blob_pidx = pidx
        slot.restore_i = 0
        slot.preempts += 1
        # release the slot's whole pool commitment: mapped pages back to the
        # free list, unmapped growth back to the uncommitted margin
        self._reserved -= slot.need - len(slot.pages)
        self.allocator.free(slot.pages)
        slot.pages = []
        self._page_rows[slot.index, :] = -1
        self.cache = kvcache.set_page_row(
            self.cache, slot.index, self._page_rows[slot.index])
        self._preempted_order.append(slot.index)
        self.preemptions += 1
        self.offload_pages += len(phys)

    def _start_restores(self) -> None:
        """Fund restores FIFO in preemption order: a later blob must not
        overtake an earlier one (its session would see out-of-order work)."""
        for idx in list(self._preempted_order):
            slot = self.slots[idx]
            if self._uncommitted() < slot.need:
                break
            slot.to(SlotState.RESTORING)
            self._reserved += slot.need
            slot.blob = self.blob_store.get(slot.blob_key)
            self._preempted_order.remove(idx)
            self.restores += 1

    def _run_restore_chunk(self, slot: Slot) -> None:
        """Inject one chunk of a restoring slot's blob: allocate fresh
        physical pages, scatter the blob slice into them, re-map the page
        table.  The final chunk reactivates the slot — it rejoins the decode
        batch the same step, like an admission whose last chunk landed."""
        n = len(slot.blob_pidx)
        hi = min(slot.restore_i + (self._restore_chunk_pages or n), n)
        phys = []
        for j in range(slot.restore_i, hi):
            pid = self.allocator.alloc(1)[0]
            self._reserved -= 1
            slot.pages.append(pid)
            self._page_rows[slot.index, slot.blob_pidx[j]] = pid
            phys.append(pid)
        piece = kvcache.slice_page_blob(slot.blob, slot.restore_i, hi)
        self.cache = self._inject(self.cache, jnp.asarray(phys, jnp.int32),
                                  piece)
        self.cache = kvcache.set_page_row(
            self.cache, slot.index, self._page_rows[slot.index])
        self.restored_pages += hi - slot.restore_i
        slot.restore_i = hi
        self.restore_chunks += 1
        if hi == n:
            self.blob_store.delete(slot.blob_key)
            slot.blob = None
            slot.blob_key = None
            slot.blob_pidx = []
            slot.to(SlotState.ACTIVE)
            slot.active_since = self.steps

    def drain_offload_ops(self) -> list:
        """Storage ops since the last drain — the frontend bills these under
        the calibrated obj_read/obj_write latency + Table-4 cost models."""
        return self.blob_store.drain_ops()

    def _run_chunk(self, slot: Slot) -> None:
        """One prefill chunk for one admitting slot (alloc-on-write: map the
        pages the chunk's span touches, then a B=1 forward against the shared
        pool).  The final chunk's logits seed the slot's first token."""
        chunk = slot.chunks[slot.chunk_i]
        C = len(chunk)
        pos0 = slot.len
        if self._has_kv:
            mapped = False
            for pidx in range(pos0 // self.page_size,
                              (pos0 + C - 1) // self.page_size + 1):
                if self._page_rows[slot.index, pidx] < 0:
                    self._map_page(slot, pidx)
                    mapped = True
            if mapped:
                self.cache = kvcache.set_page_row(
                    self.cache, slot.index, self._page_rows[slot.index])
        logits, self.cache = self._chunk(
            self.params, self.cache, jnp.asarray(chunk)[None], slot.index)
        slot.len += C
        slot.chunk_i += 1
        self.prefill_tokens += C
        self.prefill_chunks += 1
        if slot.chunk_i == len(slot.chunks):
            tok = self._sample(logits[:, -1])
            self.last_tokens = self.last_tokens.at[slot.index].set(tok[0])
            self.out_buf = self.out_buf.at[slot.index, 0].set(tok[0])
            self.out_pos = self.out_pos.at[slot.index].set(1)
            slot.to(SlotState.ACTIVE)
            slot.active_since = self.steps
            slot.n_out = 1
            slot.chunks = None
            self.admitted += 1

    # -- decode loop ---------------------------------------------------------------

    def _sample(self, logits: jnp.ndarray, key=None) -> jnp.ndarray:
        if self.temperature <= 0.0:
            return sampling.greedy(logits)
        if key is None:
            self._key, key = jax.random.split(self._key)
        return sampling.temperature_sample(key, logits, self.temperature,
                                           self.top_k)

    def _step_impl(self, params, cache, last_tokens, out_buf, out_pos, active, key):
        """Jitted: decode one token per *active* slot, sample, append to the
        output ring.  Pure device program — nothing returns to the host.

        ``active`` (n_slots,) bool masks freed, mid-admission, and preempted
        slots out of the token write, the output-ring advance, and every
        per-slot cache row: without the mask a stale slot keeps advancing its
        length and evolving its recurrent state, which corrupts the pool
        pages (and the admission-in-progress) that position now belongs to.
        """
        logits, new_cache = self.model.decode_step(params, cache, last_tokens[:, None])
        new_cache = kvcache.mask_slot_rows(new_cache, cache, active)
        toks = self._sample(logits[:, -1], key)
        toks = jnp.where(active, toks, last_tokens)
        b = jnp.arange(self.n_slots, dtype=jnp.int32)
        # inactive rows scatter out of bounds -> dropped
        col = jnp.where(active, out_pos % self.max_seq, self.max_seq)
        out_buf = out_buf.at[b, col].set(toks)
        return new_cache, toks, out_buf, out_pos + active.astype(jnp.int32)

    def step(self) -> List[CompletedRequest]:
        """One scheduler tick: at most one prefill chunk (round-robin over
        admitting slots) and one restore chunk (round-robin over restoring
        slots), then one batched decode step over the active slots; returns
        the requests that completed this step (their slots are refilled from
        the pending list before returning)."""
        self._fill_slots()
        admitting = [s for s in self.slots if s.state is SlotState.ADMITTING]
        if admitting:
            pick = admitting[self._chunk_rr % len(admitting)]
            self._chunk_rr += 1
            self._run_chunk(pick)
        restoring = [s for s in self.slots if s.state is SlotState.RESTORING]
        if restoring:
            pick = restoring[self._restore_rr % len(restoring)]
            self._restore_rr += 1
            self._run_restore_chunk(pick)
        active = [s.index for s in self.slots if s.decoding]
        if not active:
            return []
        if self.kv_mode == "paged" and self._has_kv:
            # alloc-on-write for decode growth: map the page this step's
            # token write lands in (within the slot's reservation; the final
            # step's dangling write past it is dropped by the unmapped table)
            for i in active:
                st = self.slots[i]
                if len(st.pages) < st.need:
                    pidx = st.len // self.page_size
                    if pidx < self.max_pages and self._page_rows[i, pidx] < 0:
                        self._map_page(st, pidx)
                        self.cache = kvcache.set_page_row(
                            self.cache, i, self._page_rows[i])
        mask = np.zeros((self.n_slots,), bool)
        mask[active] = True
        self._key, sub = jax.random.split(self._key)
        self.cache, self.last_tokens, self.out_buf, self.out_pos = self._decode(
            self.params, self.cache, self.last_tokens, self.out_buf,
            self.out_pos, jnp.asarray(mask), sub)
        self.steps += 1
        self.slot_steps += len(active)
        self.decode_tokens += len(active)
        if self.kv_mode == "paged" and self._has_kv:
            self.page_step_sum += self.allocator.in_use
        finished: List[CompletedRequest] = []
        for i in active:
            st = self.slots[i]
            st.n_out += 1
            if self.kv_mode == "paged":
                st.len += 1
            if st.n_out >= st.req.max_new:
                req = st.req
                st.to(SlotState.DRAINED)
                finished.append(CompletedRequest(
                    session=req.session, request_id=req.request_id,
                    tokens=np.asarray(self.out_buf[i, : req.max_new]),
                    admitted_step=st.admitted_step, finished_step=self.steps,
                    submitted_step=st.submitted_step, preempts=st.preempts))
                self._release_slot(st)
                self._active_sessions.discard(req.session)
                self.completed += 1
        if finished:
            self._fill_slots()
        return finished

    def reset(self) -> None:
        """Abort all in-flight work (crash recovery: the queue layer
        redelivers; completed requests are deduped by the frontend).  The
        pool returns to fully free, every page-table row to unmapped, and
        the blob store is emptied — a redelivered admission replays from its
        prompt, never from an orphaned blob."""
        self.slots = [s.force_empty() for s in self.slots]
        self.pending = []
        self._active_sessions.clear()
        self._preempted_order = []
        self.last_tokens = jnp.zeros((self.n_slots,), jnp.int32)
        self.out_buf = jnp.zeros((self.n_slots, self.max_seq), jnp.int32)
        self.out_pos = jnp.zeros((self.n_slots,), jnp.int32)
        self.blob_store.clear()
        if self.kv_mode == "paged":
            self.allocator.reset()
            self._reserved = 0
            self._page_rows[:] = -1
            for slot in range(self.n_slots):
                self.cache = kvcache.cache_clear_slot(self.cache, slot)

    # -- reporting ------------------------------------------------------------------

    def occupancy(self) -> float:
        """Mean active slots per decode step (the batching lever)."""
        return self.slot_steps / self.steps if self.steps else 0.0

    def pool_occupancy(self) -> float:
        """Mean fraction of the pool in use per decode step."""
        if not (self.kv_mode == "paged" and self._has_kv and self.steps
                and self.n_pages):
            return 0.0
        return self.page_step_sum / (self.steps * self.n_pages)

    def kv_memory_stats(self) -> Dict[str, float]:
        """KV bytes: allocated pool/ring footprint and the live high-water
        mark (what the pool actually touched — the paged-vs-ring lever)."""
        per_token = kvcache.kv_bytes_per_token(self.cache)
        if self.kv_mode == "paged":
            return {
                "kv_bytes_per_token": per_token,
                "kv_pool_bytes": per_token * self.n_pages * self.page_size,
                "kv_high_water_bytes":
                    per_token * self.allocator.high_water * self.page_size,
                "kv_pages": self.n_pages,
                "kv_pages_high_water": self.allocator.high_water,
                "kv_pages_in_use": self.allocator.in_use,
                "kv_pool_occupancy": round(self.pool_occupancy(), 3),
            }
        ring_tokens = 0
        if self._has_kv:
            cache_len = getattr(self.model, "cache_len", None)
            ring_tokens = (cache_len(self.max_seq) if cache_len else self.max_seq)
        return {
            "kv_bytes_per_token": per_token,
            "kv_pool_bytes": per_token * self.n_slots * ring_tokens,
            "kv_high_water_bytes": per_token * self.n_slots * ring_tokens,
        }

    def offload_stats(self) -> Dict[str, float]:
        """Offload traffic gauges: preempt/restore counts, page counts, and
        the byte flows to/from the blob store (bytes_out = offloaded,
        bytes_in = restored)."""
        bs = self.blob_store
        return {
            "preemptions": self.preemptions,
            "restores": self.restores,
            "restore_chunks": self.restore_chunks,
            "offload_pages": self.offload_pages,
            "restored_pages": self.restored_pages,
            "offload_puts": bs.puts,
            "offload_gets": bs.gets,
            "offload_bytes": bs.bytes_out,
            "restore_bytes": bs.bytes_in,
            "offload_stored_bytes": bs.bytes_stored,
            "offload_stored_high_water_bytes": bs.high_water_bytes,
        }

    def stats(self) -> Dict[str, float]:
        out = {
            "steps": self.steps,
            "occupancy": round(self.occupancy(), 3),
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "admitted": self.admitted,
            "completed": self.completed,
            "kv_mode": self.kv_mode,
        }
        if self.kv_mode == "paged":
            out["prefill_chunks"] = self.prefill_chunks
        if self.offload:
            out.update(self.offload_stats())
        return out
