"""Slot-based continuous-batching decode scheduler.

A fixed-width decode batch (``n_slots``) steps one token per active slot per
call; free slots are re-admitted from a shared cross-session queue of pending
requests.  Admission prefILLs the request into a B=1, full-ring cache
(``prefill(..., seq_len=max_seq)``) and scatters it into the slot row of the
live batched cache (``models/kvcache.cache_insert_slot``), so sequences at
different positions share one ring — the per-slot ``(B,)`` ``length`` vector
is what the model decode paths consume via ``kvcache.decode_positions``.

Per-session FIFO is preserved structurally: a session's next request is only
admitted after its predecessor completes (the ``_active_sessions`` gate), and
the pending list is scanned in arrival order.

``mesh`` applies :func:`repro.dist.sharding.cache_shardings` to the live
decode cache: on a concrete mesh the cache is ``device_put`` onto the
resolved shardings (the 16x16 decode path); on an abstract mesh the resolved
specs are recorded in ``cache_specs`` for inspection/lowering.

Supported families: ``dense``, ``moe``, ``ssm``, ``hybrid`` (decoder-only
LMs; the enc-dec families keep the whole-batch serving path).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import kvcache
from . import sampling

CONTINUOUS_FAMILIES = ("dense", "moe", "ssm", "hybrid")


def supports_continuous(cfg) -> bool:
    return getattr(cfg, "family", None) in CONTINUOUS_FAMILIES


@dataclasses.dataclass
class _Request:
    session: str
    request_id: str
    prompt: Any                 # (P,) int tokens
    max_new: int


@dataclasses.dataclass
class CompletedRequest:
    session: str
    request_id: str
    tokens: np.ndarray          # (max_new,) generated tokens
    admitted_step: int
    finished_step: int


class DecodeScheduler:
    """Continuous batching over a shared per-slot ring cache."""

    def __init__(self, model, params, *, n_slots: int = 4, max_seq: int = 64,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 mesh=None):
        if not supports_continuous(model.cfg):
            raise ValueError(
                f"family {model.cfg.family!r} has no per-slot decode path; "
                f"continuous batching supports {CONTINUOUS_FAMILIES}")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.temperature = temperature
        self.top_k = top_k
        self._key = jax.random.key(seed)

        self.cache = kvcache.batched_cache(model, n_slots, max_seq)
        self.cache_specs = None
        if mesh is not None:
            from ..dist.sharding import cache_shardings

            shardings = cache_shardings(self.cache, mesh)
            self.cache_specs = jax.tree_util.tree_map(
                lambda s: s.spec, shardings)
            if isinstance(mesh, jax.sharding.Mesh):   # concrete: place the cache
                self.cache = jax.device_put(self.cache, shardings)

        self._decode = jax.jit(self._step_impl)
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, toks, seq_len=max_seq))

        self.slots: List[Optional[Dict]] = [None] * n_slots
        self.last_tokens = jnp.zeros((n_slots,), jnp.int32)
        # device-side per-slot output ring: tokens accumulate on device and
        # are pulled to host once per *completion*, not once per step — a
        # decode step is a single async dispatch with no host sync
        self.out_buf = jnp.zeros((n_slots, max_seq), jnp.int32)
        self.out_pos = jnp.zeros((n_slots,), jnp.int32)
        self.pending: List[_Request] = []
        self._active_sessions: set = set()
        # -- occupancy / throughput accounting --------------------------------
        self.steps = 0
        self.slot_steps = 0           # sum over steps of active slots
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self.admitted = 0
        self.completed = 0

    # -- admission ----------------------------------------------------------------

    def submit(self, session: str, request_id: str, prompt, max_new: int) -> None:
        """Enqueue a request; admitted into a free slot as soon as its
        session has no in-flight predecessor (per-session FIFO gate).

        ``max_new`` is clamped to what the slot can hold without silent
        corruption: the output ring caps it at ``max_seq``, and on a
        full-attention KV ring (no sliding window — detected via
        ``cache_len``) generation past ``max_seq - len(prompt)`` would wrap
        the ring and evict prompt keys mid-decode, so the budget stops
        there; a prompt that leaves no decode room at all is rejected
        outright (clamping would silently drop its leading tokens).
        Windowed and ring-free (SSM) families wrap by design.
        """
        prompt = np.asarray(prompt)
        limit = self.max_seq
        cache_len = getattr(self.model, "cache_len", None)
        has_full_ring = (self.model.cfg.family != "ssm"   # SSM: no KV ring
                         and cache_len is not None
                         and cache_len(self.max_seq + 1) > self.max_seq)
        if has_full_ring:
            room = self.max_seq - int(prompt.shape[-1])
            if room <= 0:
                raise ValueError(
                    f"request {request_id!r}: prompt of {int(prompt.shape[-1])} "
                    f"tokens leaves no decode room in the max_seq={self.max_seq} "
                    "full-attention ring; size max_seq >= prompt + max_new")
            limit = min(limit, room)
        max_new = max(1, min(max_new, limit))
        self.pending.append(_Request(session, request_id, prompt, max_new))
        self._fill_slots()

    def busy(self) -> bool:
        return any(s is not None for s in self.slots) or bool(self.pending)

    def free_slots(self) -> int:
        return sum(1 for s in self.slots if s is None)

    def wants_more(self) -> bool:
        """Whether claiming more queued work could improve occupancy.

        Any free slot justifies claiming deeper: a FIFO queue can hold a long
        run of one session's (gated) requests in front of another session's
        admissible one, so the lookahead must not be capped — held-back
        requests wait in ``pending`` in arrival order and are requeued on a
        crash, so over-claiming never loses or reorders work."""
        return self.free_slots() > 0

    def _fill_slots(self) -> None:
        if not self.pending:
            return
        held: List[_Request] = []
        for req in self.pending:
            slot = next((i for i, s in enumerate(self.slots) if s is None), None)
            if slot is None:
                held.append(req)
                continue
            if req.session in self._active_sessions:
                held.append(req)      # FIFO gate: predecessor still decoding
                continue
            self._admit(slot, req)
        self.pending = held

    def _admit(self, slot: int, req: _Request) -> None:
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]      # (1, P)
        logits, one = self._prefill(self.params, prompt)
        tok = self._sample(logits[:, -1])                      # (1,)
        self.cache = kvcache.cache_insert_slot(self.cache, one, slot)
        self.last_tokens = self.last_tokens.at[slot].set(tok[0])
        self.out_buf = self.out_buf.at[slot, 0].set(tok[0])
        self.out_pos = self.out_pos.at[slot].set(1)
        self.slots[slot] = {
            "req": req,
            "n_out": 1,
            "admitted_step": self.steps,
        }
        self._active_sessions.add(req.session)
        self.prefill_tokens += int(prompt.shape[1])
        self.admitted += 1

    # -- decode loop ---------------------------------------------------------------

    def _sample(self, logits: jnp.ndarray, key=None) -> jnp.ndarray:
        if self.temperature <= 0.0:
            return sampling.greedy(logits)
        if key is None:
            self._key, key = jax.random.split(self._key)
        return sampling.temperature_sample(key, logits, self.temperature,
                                           self.top_k)

    def _step_impl(self, params, cache, last_tokens, out_buf, out_pos, key):
        """Jitted: decode one token per slot, sample, append to the output
        ring.  Pure device program — nothing returns to the host."""
        logits, cache = self.model.decode_step(params, cache, last_tokens[:, None])
        toks = self._sample(logits[:, -1], key)
        b = jnp.arange(self.n_slots, dtype=jnp.int32)
        out_buf = out_buf.at[b, out_pos % self.max_seq].set(toks)
        return cache, toks, out_buf, out_pos + 1

    def step(self) -> List[CompletedRequest]:
        """One batched decode step over the whole slot array; returns the
        requests that completed this step (their slots are refilled from the
        pending list before returning)."""
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            self._fill_slots()
            return []
        self._key, sub = jax.random.split(self._key)
        self.cache, self.last_tokens, self.out_buf, self.out_pos = self._decode(
            self.params, self.cache, self.last_tokens, self.out_buf,
            self.out_pos, sub)
        self.steps += 1
        self.slot_steps += len(active)
        self.decode_tokens += len(active)
        finished: List[CompletedRequest] = []
        for i in active:
            st = self.slots[i]
            st["n_out"] += 1
            if st["n_out"] >= st["req"].max_new:
                req = st["req"]
                finished.append(CompletedRequest(
                    session=req.session, request_id=req.request_id,
                    tokens=np.asarray(self.out_buf[i, : req.max_new]),
                    admitted_step=st["admitted_step"], finished_step=self.steps))
                self.slots[i] = None
                self._active_sessions.discard(req.session)
                self.completed += 1
        if finished:
            self._fill_slots()
        return finished

    def reset(self) -> None:
        """Abort all in-flight work (crash recovery: the queue layer
        redelivers; completed requests are deduped by the frontend)."""
        self.slots = [None] * self.n_slots
        self.pending = []
        self._active_sessions.clear()
        self.last_tokens = jnp.zeros((self.n_slots,), jnp.int32)
        self.out_buf = jnp.zeros((self.n_slots, self.max_seq), jnp.int32)
        self.out_pos = jnp.zeros((self.n_slots,), jnp.int32)

    # -- reporting ------------------------------------------------------------------

    def occupancy(self) -> float:
        """Mean active slots per decode step (the batching lever)."""
        return self.slot_steps / self.steps if self.steps else 0.0

    def stats(self) -> Dict[str, float]:
        return {
            "steps": self.steps,
            "occupancy": round(self.occupancy(), 3),
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "admitted": self.admitted,
            "completed": self.completed,
        }
