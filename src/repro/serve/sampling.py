"""Token sampling utilities."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(key, logits: jnp.ndarray, temperature: float = 1.0,
                       top_k: int = 0) -> jnp.ndarray:
    """Temperature + top-k sampling over the last axis.

    Top-k restricts the support to *exactly* ``k`` candidates: masking by
    value (``lg < kth``) would keep every logit tied with the k-th one, so we
    sample an index into ``jax.lax.top_k``'s result and map it back through
    the returned indices (ties broken deterministically, like the sort).
    ``top_k >= vocab`` degrades to plain temperature sampling; ``top_k <= 0``
    (0 or the common -1 sentinel) disables top-k entirely.
    """
    lg = logits.astype(jnp.float32) / max(temperature, 1e-6)
    if top_k > 0:
        k = min(int(top_k), lg.shape[-1])
        vals, idx = jax.lax.top_k(lg, k)
        choice = jax.random.categorical(key, vals, axis=-1)
        return jnp.take_along_axis(
            idx, choice[..., None], axis=-1)[..., 0].astype(jnp.int32)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
