from .store import CheckpointStore, restore_pytree, save_pytree

__all__ = ["CheckpointStore", "restore_pytree", "save_pytree"]
