"""Sharded checkpoint store with FaaSKeeper-coordinated commits.

Layout (mirrors the paper's split between bulk user data and control data):

    <root>/step_<n>/<leaf-path>.npy     bulk tensors   ("S3 object store")
    manifest: committed through coord.ckpt_coord as a FaaSKeeper transaction
              ("DynamoDB system store") — the manifest *is* the commit point.

A checkpoint is visible iff its manifest transaction committed; a crash
mid-save leaves dangling .npy files that the next save's garbage pass prunes
(paper §4.5 heartbeat/cleanup analogue).  ``save_async`` overlaps serialization
with the next training step (background thread; device->host copy happens
synchronously first, as on real fleets).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
        out.append(("/".join(parts), leaf))
    return out


def save_pytree(tree: Any, directory: str) -> Dict[str, Any]:
    os.makedirs(directory, exist_ok=True)
    manifest = {"leaves": []}
    for path, leaf in _leaf_paths(tree):
        arr = np.asarray(leaf)
        fn = path.replace("/", "__") + ".npy"
        np.save(os.path.join(directory, fn), arr)
        manifest["leaves"].append(
            {"path": path, "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    return manifest


def restore_pytree(template: Any, directory: str) -> Any:
    flat, treedef = jax.tree_util.tree_flatten(template)
    named = _leaf_paths(template)
    leaves = []
    for (path, leaf) in named:
        fn = os.path.join(directory, path.replace("/", "__") + ".npy")
        arr = np.load(fn)
        leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return treedef.unflatten(leaves)


class CheckpointStore:
    """Filesystem bulk store + pluggable manifest committer.

    ``committer(step, manifest) -> None`` is called after the bulk write; the
    default records to a local JSON log, the coord/ layer swaps in the
    FaaSKeeper transactional commit.
    """

    def __init__(self, root: str, committer: Optional[Callable] = None,
                 latest_resolver: Optional[Callable] = None, keep: int = 3):
        self.root = root
        self.keep = keep
        self._committer = committer or self._local_commit
        self._latest_resolver = latest_resolver or self._local_latest
        self._threads: List[threading.Thread] = []
        # async saves serialize on this lock: the committer talks to the
        # (single-threaded) control plane, and manifests must commit in order
        self._save_lock = threading.Lock()
        os.makedirs(root, exist_ok=True)

    # -- local (non-coordinated) manifest fallback ------------------------------

    def _log_path(self) -> str:
        return os.path.join(self.root, "manifest_log.json")

    def _local_commit(self, step: int, manifest: Dict) -> None:
        log = []
        if os.path.exists(self._log_path()):
            with open(self._log_path()) as f:
                log = json.load(f)
        log.append({"step": step, "manifest": manifest})
        tmp = self._log_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(log, f)
        os.replace(tmp, self._log_path())

    def _local_latest(self) -> Optional[int]:
        if not os.path.exists(self._log_path()):
            return None
        with open(self._log_path()) as f:
            log = json.load(f)
        return log[-1]["step"] if log else None

    # -- public API ---------------------------------------------------------------

    def step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def save(self, step: int, tree: Any) -> None:
        host_tree = jax.device_get(tree)
        self._save_host(step, host_tree)

    def save_async(self, step: int, tree: Any) -> threading.Thread:
        host_tree = jax.device_get(tree)  # sync device->host; disk I/O async
        t = threading.Thread(target=self._save_host, args=(step, host_tree), daemon=True)
        t.start()
        self._threads.append(t)
        return t

    def _save_host(self, step: int, host_tree: Any) -> None:
        with self._save_lock:
            self._gc_dangling()
            manifest = save_pytree(host_tree, self.step_dir(step))
            manifest["step"] = step
            self._committer(step, manifest)
            self._gc_old()

    def wait(self) -> None:
        for t in self._threads:
            t.join()
        self._threads.clear()

    def latest_step(self) -> Optional[int]:
        return self._latest_resolver()

    def restore(self, template: Any, step: Optional[int] = None) -> Tuple[Any, int]:
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no committed checkpoint")
        return restore_pytree(template, self.step_dir(step)), step

    # -- garbage collection ----------------------------------------------------------

    def _committed_steps(self) -> List[int]:
        latest = self._latest_resolver()
        if latest is None:
            return []
        steps = []
        if os.path.exists(self._log_path()):
            with open(self._log_path()) as f:
                steps = [e["step"] for e in json.load(f)]
        return steps or [latest]

    def _gc_dangling(self) -> None:
        committed = set(self._committed_steps())
        for d in os.listdir(self.root):
            if d.startswith("step_"):
                s = int(d.split("_")[1])
                if s not in committed and committed and s < max(committed):
                    shutil.rmtree(os.path.join(self.root, d), ignore_errors=True)

    def _gc_old(self) -> None:
        committed = sorted(self._committed_steps())
        for s in committed[: -self.keep] if self.keep else []:
            shutil.rmtree(self.step_dir(s), ignore_errors=True)
