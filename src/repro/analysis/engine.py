"""Core of the static-analysis suite: findings, suppression pragmas, the
module loader, repo-invariant context, and the rule driver.

Design notes
------------
* Everything is AST-level — no target module is ever imported, so the
  analyzer can run on broken or heavyweight code (and on test fixtures
  that would not import at all).
* Repo invariants (the ``SlotState`` transition table, the mesh-axis
  registry) are parsed out of the defining modules' ASTs at startup, so
  the passes track the source of truth instead of a copied constant.
* Suppression is per-line and per-rule: ``# repro: allow(<rule>) -- <reason>``
  on the flagged line, or alone on the line directly above it.  A pragma
  without a reason does not suppress — it is itself reported, so every
  waiver in the tree carries a justification.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

PRAGMA_RE = re.compile(
    r"#\s*repro:\s*allow\(\s*(?P<rules>[\w\-*,\s]+?)\s*\)"
    r"(?:\s*--\s*(?P<reason>\S.*?))?\s*$")

PRAGMA_RULE = "pragma"          # meta-rule id for malformed pragmas
PARSE_RULE = "parse-error"      # meta-rule id for unparsable files


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: rule id + location + message (stable sort order)."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    reason: Optional[str] = None    # pragma justification when suppressed

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def sort_key(self):
        return (self.path, self.line, self.col, self.rule)


@dataclasses.dataclass
class _Pragma:
    rules: Set[str]
    reason: Optional[str]
    line: int
    own_line: bool      # comment-only line: also covers the next line
    used: bool = False


class Module:
    """A parsed source file plus its pragma table and parent links."""

    def __init__(self, path: Path, source: str, rel: Optional[str] = None):
        self.path = path
        self.rel = rel or str(path)
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                child._repro_parent = parent  # type: ignore[attr-defined]
        self.pragmas: Dict[int, _Pragma] = self._scan_pragmas()

    @property
    def dotted_name(self) -> Optional[str]:
        """``repro.serve.scheduler`` for files under a ``repro`` package."""
        parts = list(self.path.parts)
        if "repro" not in parts:
            return None
        i = parts.index("repro")
        tail = parts[i:]
        tail[-1] = tail[-1].rsplit(".", 1)[0]
        if tail[-1] == "__init__":
            tail.pop()
        return ".".join(tail)

    def _scan_pragmas(self) -> Dict[int, _Pragma]:
        out: Dict[int, _Pragma] = {}
        for lineno, text in enumerate(self.lines, start=1):
            if "repro:" not in text:
                continue
            m = PRAGMA_RE.search(text)
            if m is None:
                continue
            rules = {r.strip() for r in m.group("rules").split(",") if r.strip()}
            own = text.lstrip().startswith("#")
            out[lineno] = _Pragma(rules=rules, reason=m.group("reason"),
                                  line=lineno, own_line=own)
        return out

    def pragma_for(self, rule: str, line: int) -> Optional[_Pragma]:
        """The pragma suppressing ``rule`` at ``line``, if any (and valid)."""
        for cand_line in (line, line - 1):
            p = self.pragmas.get(cand_line)
            if p is None or (cand_line != line and not p.own_line):
                continue
            if (rule in p.rules or "*" in p.rules) and p.reason:
                return p
        return None

    def parents(self, node: ast.AST) -> Iterable[ast.AST]:
        while True:
            node = getattr(node, "_repro_parent", None)
            if node is None:
                return
            yield node


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_aliases(tree: ast.Module) -> Dict[str, str]:
    """Map local name -> fully qualified module/object it refers to."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            base = ("." * node.level) + node.module
            for a in node.names:
                out[a.asname or a.name] = f"{base}.{a.name}"
    return out


class RepoContext:
    """Repo invariants the passes consult, parsed from the defining modules.

    ``transitions``/``states`` come from ``serve/lifecycle.py``'s
    ``TRANSITIONS`` / ``SlotState``; ``mesh_axes`` from ``dist/sharding.py``'s
    ``MESH_AXES``.  Tests may construct one directly with literals.
    """

    def __init__(self, *,
                 states: Optional[Set[str]] = None,
                 transitions: Optional[Dict[str, Set[str]]] = None,
                 mesh_axes: Optional[Set[str]] = None,
                 lifecycle_path: Optional[Path] = None,
                 sharding_path: Optional[Path] = None):
        pkg = Path(__file__).resolve().parents[1]
        self.lifecycle_path = lifecycle_path or pkg / "serve" / "lifecycle.py"
        self.sharding_path = sharding_path or pkg / "dist" / "sharding.py"
        if states is None or transitions is None:
            states_p, transitions_p = _parse_lifecycle(self.lifecycle_path)
            states = states if states is not None else states_p
            transitions = transitions if transitions is not None else transitions_p
        self.states = states
        self.transitions = transitions
        if mesh_axes is None:
            mesh_axes = _parse_mesh_axes(self.sharding_path)
        self.mesh_axes = mesh_axes

    def is_edge(self, src: str, dst: str) -> bool:
        return dst in self.transitions.get(src, set())

    @property
    def destinations(self) -> Set[str]:
        out: Set[str] = set()
        for dsts in self.transitions.values():
            out |= dsts
        return out


def _parse_lifecycle(path: Path) -> Tuple[Set[str], Dict[str, Set[str]]]:
    states: Set[str] = set()
    transitions: Dict[str, Set[str]] = {}
    try:
        tree = ast.parse(path.read_text())
    except (OSError, SyntaxError):
        return states, transitions
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "SlotState":
            for stmt in node.body:
                if isinstance(stmt, ast.Assign):
                    for tgt in stmt.targets:
                        if isinstance(tgt, ast.Name):
                            states.add(tgt.id)
        tgt = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt, value = node.targets[0], node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            tgt, value = node.target, node.value
        if (tgt is not None and isinstance(tgt, ast.Name)
                and tgt.id == "TRANSITIONS" and isinstance(value, ast.Dict)):
            for k, v in zip(value.keys, value.values, strict=True):
                src = _slotstate_member(k)
                if src is None:
                    continue
                dsts = set()
                if isinstance(v, (ast.Tuple, ast.List, ast.Set)):
                    for el in v.elts:
                        d = _slotstate_member(el)
                        if d is not None:
                            dsts.add(d)
                transitions[src] = dsts
    return states, transitions


def _slotstate_member(node: Optional[ast.AST]) -> Optional[str]:
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "SlotState"):
        return node.attr
    return None


def _parse_mesh_axes(path: Path) -> Set[str]:
    try:
        tree = ast.parse(path.read_text())
    except (OSError, SyntaxError):
        return set()
    for node in ast.walk(tree):
        targets: list = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        else:
            continue
        for tgt in targets:
            if (isinstance(tgt, ast.Name) and tgt.id == "MESH_AXES"
                    and isinstance(node.value, (ast.Tuple, ast.List))):
                return {el.value for el in node.value.elts
                        if isinstance(el, ast.Constant)
                        and isinstance(el.value, str)}
    return set()


class Rule:
    """One analysis pass.  Subclasses set ``id``/``summary`` and implement
    ``check``; ``prepare`` (optional) sees the whole module set first, for
    cross-module facts like jit roots spelled as ``module.function``."""

    id: str = "<abstract>"
    summary: str = ""

    def prepare(self, modules: Sequence[Module], ctx: RepoContext) -> None:
        pass

    def check(self, module: Module, ctx: RepoContext) -> List[Finding]:
        raise NotImplementedError


def default_rules() -> List[Rule]:
    from .rules import build_rules
    return build_rules()


@dataclasses.dataclass
class Report:
    findings: List[Finding]         # active (unsuppressed)
    suppressed: List[Finding]       # waived by a valid pragma
    files: List[str]
    rules: List[Rule]

    @property
    def ok(self) -> bool:
        return not self.findings


def iter_py_files(paths: Sequence[Path]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        if p.is_dir():
            out.extend(sorted(q for q in p.rglob("*.py")
                              if "__pycache__" not in q.parts))
        elif p.suffix == ".py":
            out.append(p)
    seen: Set[Path] = set()
    uniq = []
    for p in out:
        r = p.resolve()
        if r not in seen:
            seen.add(r)
            uniq.append(p)
    return uniq


def analyze(paths: Sequence, *, rules: Optional[Sequence[Rule]] = None,
            ctx: Optional[RepoContext] = None) -> Report:
    """Run ``rules`` over every ``.py`` under ``paths``."""
    rules = list(rules) if rules is not None else default_rules()
    ctx = ctx or RepoContext()
    files = iter_py_files([Path(p) for p in paths])
    modules: List[Module] = []
    findings: List[Finding] = []
    for f in files:
        try:
            modules.append(Module(f, f.read_text()))
        except SyntaxError as e:
            findings.append(Finding(PARSE_RULE, str(f), e.lineno or 1,
                                    e.offset or 0, f"cannot parse: {e.msg}"))
        except UnicodeDecodeError:
            findings.append(Finding(PARSE_RULE, str(f), 1, 0,
                                    "cannot decode as utf-8"))
    for rule in rules:
        rule.prepare(modules, ctx)
    for mod in modules:
        for rule in rules:
            findings.extend(rule.check(mod, ctx))
        findings.extend(_malformed_pragmas(mod))
    active: List[Finding] = []
    suppressed: List[Finding] = []
    by_module = {m.rel: m for m in modules}
    for f in sorted(findings, key=Finding.sort_key):
        mod = by_module.get(f.path)
        pragma = mod.pragma_for(f.rule, f.line) if mod else None
        if pragma is not None:
            pragma.used = True
            suppressed.append(dataclasses.replace(f, reason=pragma.reason))
        else:
            active.append(f)
    # a pragma that suppressed nothing is stale — flag it so waivers don't
    # outlive the code they excused (the meta-finding is itself waivable)
    for mod in modules:
        for p in mod.pragmas.values():
            if p.reason and not p.used and not (p.rules & {PRAGMA_RULE}):
                f = Finding(PRAGMA_RULE, mod.rel, p.line, 0,
                            "stale pragma: suppresses nothing on this line")
                if mod.pragma_for(PRAGMA_RULE, p.line):
                    suppressed.append(dataclasses.replace(
                        f, reason=mod.pragma_for(PRAGMA_RULE, p.line).reason))
                else:
                    active.append(f)
    active.sort(key=Finding.sort_key)
    return Report(findings=active, suppressed=suppressed,
                  files=[m.rel for m in modules], rules=rules)


def _malformed_pragmas(mod: Module) -> List[Finding]:
    out = []
    for p in mod.pragmas.values():
        if not p.reason:
            out.append(Finding(
                PRAGMA_RULE, mod.rel, p.line, 0,
                "suppression pragma needs a justification: "
                "# repro: allow(<rule>) -- <reason>"))
    return out


def render_text(report: Report, *, verbose: bool = False) -> str:
    lines = [f.render() for f in report.findings]
    if verbose and report.suppressed:
        lines.append("-- suppressed --")
        lines.extend(f"{f.render()}  (allowed: {f.reason})"
                     for f in report.suppressed)
    lines.append(
        f"{len(report.files)} file(s), {len(report.findings)} finding(s), "
        f"{len(report.suppressed)} suppressed")
    return "\n".join(lines)


def render_json(report: Report) -> str:
    def enc(f: Finding) -> dict:
        d = {"rule": f.rule, "path": f.path, "line": f.line, "col": f.col,
             "message": f.message}
        if f.reason is not None:
            d["reason"] = f.reason
        return d

    doc = {
        "version": 1,
        "tool": "repro.analysis",
        "rules": [{"id": r.id, "summary": r.summary} for r in report.rules],
        "files_scanned": len(report.files),
        "findings": [enc(f) for f in report.findings],
        "suppressed": [enc(f) for f in report.suppressed],
        "ok": report.ok,
    }
    return json.dumps(doc, indent=2, sort_keys=True)
