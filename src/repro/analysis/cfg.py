"""A small statement-level control-flow graph for intra-function path
queries (the allocator-discipline pass asks "can this alloc reach the
function exit without passing a release/ownership transfer?").

Statements are the nodes; edges are split into *normal* successors and
*exceptional* successors (try-body statement -> handler entry).  The
split matters: an ``alloc()`` call that raises allocated nothing, so the
leak query must not follow the exception edge out of the alloc statement
itself, but must follow it out of every later statement.

Loops are treated as may-exit (the back edge and the fall-through edge
both exist, even for ``while True``); ``finally`` bodies are threaded
between a block and its continuation.  This is deliberately conservative
in the direction that surfaces *more* paths, which is the safe bias for
a leak checker.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

EXIT = "<exit>"


class CFG:
    def __init__(self, func: ast.AST):
        self.func = func
        self.succ: Dict[int, List[object]] = {}
        self.exc: Dict[int, List[object]] = {}
        body = getattr(func, "body", [])
        self._loops: List[dict] = []
        self._handlers: List[List[ast.AST]] = []
        self._finals: List[object] = []
        self._build_seq(body, EXIT)

    # -- construction ----------------------------------------------------

    def _entry(self, stmts: List[ast.stmt], follow: object) -> object:
        return stmts[0] if stmts else follow

    def _build_seq(self, stmts: List[ast.stmt], follow: object) -> None:
        for i, stmt in enumerate(stmts):
            nxt = self._entry(stmts[i + 1:], follow)
            self._build_stmt(stmt, nxt)

    def _add(self, table: Dict[int, List[object]], node: ast.AST,
             dst: object) -> None:
        table.setdefault(id(node), []).append(dst)

    def _build_stmt(self, stmt: ast.stmt, follow: object) -> None:
        if isinstance(stmt, (ast.Return, ast.Raise)):
            # raises unwind to the innermost enclosing handler if any;
            # returns (and unhandled raises) pass through the innermost
            # finally on their way out of the function
            if isinstance(stmt, ast.Raise) and self._handlers and self._handlers[-1]:
                for h in self._handlers[-1]:
                    self._add(self.succ, stmt, h)
            elif self._finals:
                self._add(self.succ, stmt, self._finals[-1])
            else:
                self._add(self.succ, stmt, EXIT)
        elif isinstance(stmt, ast.Break):
            self._add(self.succ, stmt, self._loops[-1]["break"]
                      if self._loops else EXIT)
        elif isinstance(stmt, ast.Continue):
            self._add(self.succ, stmt, self._loops[-1]["continue"]
                      if self._loops else EXIT)
        elif isinstance(stmt, ast.If):
            self._add(self.succ, stmt, self._entry(stmt.body, follow))
            self._add(self.succ, stmt, self._entry(stmt.orelse, follow))
            self._build_seq(stmt.body, follow)
            self._build_seq(stmt.orelse, follow)
        elif isinstance(stmt, (ast.While, ast.For, ast.AsyncFor)):
            self._add(self.succ, stmt, self._entry(stmt.body, stmt))
            self._add(self.succ, stmt,
                      self._entry(stmt.orelse, follow) if stmt.orelse
                      else follow)
            self._loops.append({"break": follow, "continue": stmt})
            self._build_seq(stmt.body, stmt)
            self._loops.pop()
            self._build_seq(stmt.orelse, follow)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            self._add(self.succ, stmt, self._entry(stmt.body, follow))
            self._build_seq(stmt.body, follow)
        elif isinstance(stmt, (ast.Try, getattr(ast, "TryStar", ast.Try))):
            after = follow
            if stmt.finalbody:
                after = self._entry(stmt.finalbody, follow)
                self._build_seq(stmt.finalbody, follow)
                self._finals.append(after)
            handler_entries = [self._entry(h.body, after)
                               for h in stmt.handlers if h.body]
            body_follow = (self._entry(stmt.orelse, after) if stmt.orelse
                           else after)
            self._add(self.succ, stmt, self._entry(stmt.body, body_follow))
            self._handlers.append(handler_entries)
            self._build_seq(stmt.body, body_follow)
            # every try-body statement may transfer to any handler
            for s in stmt.body:
                for node in self._stmts_in(s):
                    for h in handler_entries:
                        self._add(self.exc, node, h)
                    if stmt.finalbody and not handler_entries:
                        self._add(self.exc, node, after)
            self._handlers.pop()
            for h in stmt.handlers:
                self._build_seq(h.body, after)
            self._build_seq(stmt.orelse, after)
            if stmt.finalbody:
                self._finals.pop()
        else:
            self._add(self.succ, stmt, follow)

    def _stmts_in(self, stmt: ast.stmt) -> Iterable[ast.stmt]:
        yield stmt
        for child in ast.walk(stmt):
            if isinstance(child, ast.stmt) and child is not stmt:
                # don't descend into nested function/class bodies
                if not isinstance(child, (ast.FunctionDef,
                                          ast.AsyncFunctionDef, ast.ClassDef)):
                    yield child

    # -- queries ---------------------------------------------------------

    def escaping_path(self, start: ast.stmt, consumers: Set[int],
                      *, follow_start_exc: bool = False) -> Optional[object]:
        """If some path from ``start`` reaches the function exit without
        passing through a consumer statement, return the last node on it
        (EXIT, or the Return/Raise that left).  None if every path is
        covered.  ``start`` itself is never counted as a consumer and its
        exception edge is skipped unless ``follow_start_exc``."""
        seen: Set[int] = set()
        stack: List[object] = [start]
        prev: Dict[int, object] = {}
        while stack:
            node = stack.pop()
            if node is EXIT:
                p = prev.get(id(EXIT))
                return p if p is not None else EXIT
            if id(node) in seen:
                continue
            seen.add(id(node))
            if node is not start and id(node) in consumers:
                continue
            edges = list(self.succ.get(id(node), []))
            if node is not start or follow_start_exc:
                edges += self.exc.get(id(node), [])
            for nxt in edges:
                if id(nxt) not in seen or nxt is EXIT:
                    prev[id(nxt) if nxt is not EXIT else id(EXIT)] = node
                    stack.append(nxt)
        return None
