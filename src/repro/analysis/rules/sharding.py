"""sharding-registry: every literal ``PartitionSpec`` axis name (and every
literal mesh ``axis_names`` tuple) must name an axis in
``dist.sharding.MESH_AXES``.

A typo'd axis name in a ``P(...)`` does not fail at construction — it
fails at ``device_put``/``jit`` time on whatever mesh happens to be
active, usually far from the spec that introduced it (and the 1x1 smoke
mesh in CI can mask it entirely when the misspelled axis ends up
unsharded).  The registry is parsed from ``dist/sharding.py``'s AST, so
the pass follows the source of truth.

``jax.shard_map`` call sites get the same axis-name check on their
``in_specs``/``out_specs`` (bare axis strings included — those bypass the
``P(...)`` constructor entirely), plus a replication-check finding: a
shard_map without an explicit ``check_vma=``/``check_rep=`` keyword is
flagged.  The paged-gather and stationary-MoE bodies produce per-shard
partials that are *not* replicated across ``model``; the default check
rejects them at trace time on some jax pins and silently passes on
others, so every body must declare its stance (``check_vma=False``).
"""

from __future__ import annotations

import ast
from typing import List, Set

from ..engine import Finding, Module, RepoContext, Rule, dotted, import_aliases

RULE_ID = "sharding-registry"

_PSPEC_FQNS = {"jax.sharding.PartitionSpec",
               "jax.experimental.pjit.PartitionSpec"}
_MESH_CTORS = {"make_mesh", "Mesh", "AbstractMesh"}


def _pspec_aliases(module: Module) -> Set[str]:
    """Local names bound to PartitionSpec (imports plus `P2 = P` renames)."""
    aliases = {name for name, fq in import_aliases(module.tree).items()
               if fq in _PSPEC_FQNS or fq.endswith(".PartitionSpec")}
    changed = True
    while changed:
        changed = False
        for node in ast.walk(module.tree):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Name)
                    and node.value.id in aliases
                    and node.targets[0].id not in aliases):
                aliases.add(node.targets[0].id)
                changed = True
    return aliases


class ShardingRegistryRule(Rule):
    id = RULE_ID
    summary = ("every literal PartitionSpec / mesh axis name must exist in "
               "dist.sharding.MESH_AXES")

    def check(self, module: Module, ctx: RepoContext) -> List[Finding]:
        if not ctx.mesh_axes:
            return []
        out: List[Finding] = []
        aliases = _pspec_aliases(module)
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d is None:
                continue
            name = d.split(".")[-1]
            if d in aliases or name == "PartitionSpec":
                for s in _literal_strs(list(node.args)
                                       + [k.value for k in node.keywords]):
                    if s.value not in ctx.mesh_axes:
                        out.append(self._finding(module, s, "PartitionSpec"))
            elif name in _MESH_CTORS:
                for arg in self._axis_args(node, name):
                    for s in _literal_strs([arg]):
                        if s.value not in ctx.mesh_axes:
                            out.append(self._finding(module, s, name))
            elif name == "shard_map":
                out.extend(self._check_shard_map(module, node, ctx))
        return out

    def _check_shard_map(self, module: Module, node: ast.Call,
                         ctx: RepoContext) -> List[Finding]:
        out: List[Finding] = []
        kwnames = {kw.arg for kw in node.keywords}
        for kw in node.keywords:
            if kw.arg in ("in_specs", "out_specs"):
                # P(...) literals inside the spec are already covered by the
                # PartitionSpec branch (the nested Call is its own AST node);
                # only bare axis strings outside any call are new here
                for s in _shallow_strs(kw.value):
                    if s.value not in ctx.mesh_axes:
                        out.append(self._finding(
                            module, s, f"shard_map {kw.arg}"))
        if not kwnames & {"check_vma", "check_rep"}:
            out.append(Finding(
                RULE_ID, module.rel, node.lineno, node.col_offset,
                "shard_map call without an explicit check_vma/check_rep "
                "keyword — per-shard partial bodies (paged gather, "
                "stationary MoE) must declare replication checking "
                "(check_vma=False)"))
        return out

    def _axis_args(self, call: ast.Call, ctor: str) -> List[ast.AST]:
        out = []
        for kw in call.keywords:
            if kw.arg in ("axis_names", "names"):
                out.append(kw.value)
        if not out and len(call.args) >= 2:
            out.append(call.args[1])
        return out

    def _finding(self, module: Module, node: ast.Constant,
                 where: str) -> Finding:
        return Finding(
            RULE_ID, module.rel, node.lineno, node.col_offset,
            f"axis name '{node.value}' in {where} is not in "
            "dist.sharding.MESH_AXES — typo, or register the new axis there")


def _literal_strs(nodes: List[ast.AST]) -> List[ast.Constant]:
    out: List[ast.Constant] = []
    for root in nodes:
        if root is None:
            continue
        for n in ast.walk(root):
            if isinstance(n, ast.Constant) and isinstance(n.value, str):
                out.append(n)
    return out


def _shallow_strs(root: ast.AST) -> List[ast.Constant]:
    """Literal strings under ``root`` that are NOT nested inside a Call
    (nested calls — ``P("model")`` — are independently visited by the
    outer walk, so descending would double-report)."""
    out: List[ast.Constant] = []
    stack = [root]
    while stack:
        n = stack.pop()
        if isinstance(n, ast.Call):
            continue
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            out.append(n)
        else:
            stack.extend(ast.iter_child_nodes(n))
    return out


__all__ = ["ShardingRegistryRule", "RULE_ID"]
