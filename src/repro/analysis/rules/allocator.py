"""allocator-discipline: every ``PageAllocator.alloc``/``share`` must be
followed, on *every* CFG path to the function exit, by a release or an
ownership transfer (recording the pages in a slot/table/attribute,
returning them, or handing them to a callee).

The runtime ``audit()`` catches a leaked page only when the ledger is
next validated — typically steps after the leak, in a different request's
stack.  Statically, a leak is simply an escaping CFG path, and the most
common shape is the exception path: ``alloc`` succeeds, a later statement
in the ``try`` raises, the handler returns without releasing.

``free()`` calls on an allocator are flagged unconditionally: on a
refcounted pool only ``release`` (drop one reference) is safe against
CoW-shared pages; ``free`` reads as an unconditional drop even where it
aliases ``release`` today.
"""

from __future__ import annotations

import ast
from typing import List, Optional

from ..cfg import CFG, EXIT
from ..engine import Finding, Module, RepoContext, Rule, dotted

RULE_ID = "allocator-discipline"

# builtin callees that only *read* their argument: passing the tracked
# pages to these is not an ownership transfer
_READERS = {"len", "range", "enumerate", "sorted", "reversed", "min", "max",
            "sum", "any", "all", "zip", "iter", "next", "repr", "str",
            "print", "bool", "id", "isinstance", "frozenset"}


def _is_allocator(recv: Optional[str]) -> bool:
    if recv is None:
        return False
    last = recv.split(".")[-1]
    return last.endswith("allocator") or last == "pool_allocator"


class AllocatorDisciplineRule(Rule):
    id = RULE_ID
    summary = ("alloc/share results must reach a release or ownership "
               "transfer on every CFG path (no exception-path page leaks); "
               "never free() a refcounted page")

    def check(self, module: Module, ctx: RepoContext) -> List[Finding]:
        findings: List[Finding] = []
        for fn in ast.walk(module.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                findings.extend(self._check_function(module, fn))
        return findings

    def _check_function(self, module: Module,
                        fn: ast.AST) -> List[Finding]:
        out: List[Finding] = []
        allocs = []     # (stmt, var or None, call node, kind)
        stmts = [n for n in ast.walk(fn) if isinstance(n, ast.stmt)
                 and _owner_function(module, n) is fn]
        for call in ast.walk(fn):
            if not isinstance(call, ast.Call):
                continue
            if not isinstance(call.func, ast.Attribute):
                continue
            recv = dotted(call.func.value)
            if not _is_allocator(recv):
                continue
            stmt = _owner_stmt(module, call)
            if stmt is None or _owner_function(module, stmt) is not fn:
                continue
            kind = call.func.attr
            if kind == "free":
                out.append(Finding(
                    RULE_ID, module.rel, call.lineno, call.col_offset,
                    f"`{recv}.free(...)`: use release() — free() reads "
                    "as an unconditional drop and is unsafe on "
                    "CoW-shared refcounted pages"))
                continue
            if kind not in ("alloc", "share"):
                continue
            var = _tracked_var(stmt, call, kind)
            if var == "<consumed>":
                continue
            allocs.append((stmt, var, call, kind))
        if not allocs:
            return out
        cfg = CFG(fn)
        for stmt, var, call, kind in allocs:
            if var is None:
                out.append(Finding(
                    RULE_ID, module.rel, call.lineno, call.col_offset,
                    f"{kind}() result is dropped (or bound to a pattern the "
                    "analyzer cannot track): pages leak immediately"))
                continue
            consumers = {id(s) for s in stmts if s is not stmt
                         and _consumes(s, var)}
            esc = cfg.escaping_path(stmt, consumers)
            if esc is not None:
                where = ("function exit" if esc is EXIT or not hasattr(esc, "lineno")
                         else f"the exit at line {esc.lineno}")
                via = (" via an exception path"
                       if _escapes_through_handler(esc, stmt) else "")
                out.append(Finding(
                    RULE_ID, module.rel, call.lineno, call.col_offset,
                    f"pages from {kind}() into `{var}` can reach {where}"
                    f"{via} without release()/ownership transfer"))
        return out


def _owner_function(module: Module, node: ast.AST) -> Optional[ast.AST]:
    for p in module.parents(node):
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return p
    return None


def _owner_stmt(module: Module, node: ast.AST) -> Optional[ast.stmt]:
    """Nearest enclosing statement (the CFG node a call anchors to)."""
    if isinstance(node, ast.stmt):
        return node
    for p in module.parents(node):
        if isinstance(p, ast.stmt):
            return p
    return None


def _tracked_var(stmt: ast.stmt, call: ast.Call, kind: str) -> Optional[str]:
    """Which local name holds the allocated pages after ``stmt``.

    Returns "<consumed>" when the call result (or shared arg) is consumed
    in the same statement, a name to track, or None when untrackable.
    """
    if kind == "share":
        # share() bumps refcounts on pages the caller names: attribute- or
        # call-rooted args are already-recorded state; a bare Name (or a
        # literal list of Names) is a fresh reference that must be recorded
        names: List[str] = []
        for arg in call.args:
            if isinstance(arg, ast.Starred):
                arg = arg.value
            if isinstance(arg, ast.Name):
                names.append(arg.id)
            elif isinstance(arg, (ast.List, ast.Tuple)):
                names.extend(el.id for el in arg.elts
                             if isinstance(el, ast.Name))
            else:
                return "<consumed>"
        return names[0] if names else "<consumed>"
    # alloc(): find where the call's value lands in this statement
    if isinstance(stmt, ast.Expr) and stmt.value is call:
        return None                      # bare expression: value dropped
    if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
        tgt = stmt.targets[0]
        value = stmt.value
        # x = alloc(..)  |  x = alloc(..)[0]  — track x when x is a Name;
        # attribute/subscript targets are themselves the ownership record
        if _contains(value, call):
            if isinstance(tgt, ast.Name):
                return tgt.id
            if isinstance(tgt, (ast.Attribute, ast.Subscript)):
                return "<consumed>"
            return None                  # tuple-unpack etc: untrackable
    if isinstance(stmt, (ast.Return, ast.AnnAssign, ast.AugAssign)):
        return "<consumed>" if isinstance(stmt, ast.Return) else None
    # alloc() nested directly inside a consuming call, e.g.
    # slot.pages.append(alloc(1)[0]) or extend(alloc(n))
    for node in ast.walk(stmt):
        if (isinstance(node, ast.Call) and node is not call
                and any(_contains(a, call) for a in node.args)):
            return "<consumed>"
    return None


def _contains(root: ast.AST, needle: ast.AST) -> bool:
    return any(n is needle for n in ast.walk(root))


def _mentions(root: ast.AST, var: str) -> bool:
    return any(isinstance(n, ast.Name) and n.id == var
               for n in ast.walk(root))


def _consumes(stmt: ast.stmt, var: str) -> bool:
    """Does this statement release or take ownership of ``var``?"""
    if isinstance(stmt, (ast.Return, ast.Raise)):
        return stmt.value is not None and _mentions(stmt.value, var)
    if isinstance(stmt, ast.Assign):
        if _mentions(stmt.value, var):
            # recording into an attribute / subscript / another binding
            # all count: the pages now live somewhere the caller owns
            return True
        return False
    if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
        call = stmt.value
        args = [*call.args, *(kw.value for kw in call.keywords)]
        if not any(_mentions(a, var) for a in args):
            return False
        d = dotted(call.func)
        if d is None:
            return True
        if d in _READERS:
            return False
        # release()/free() consume; so do container mutators recording the
        # pages (slot.pages.append(pid)) and arbitrary callee handoffs
        return True
    if isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        value = stmt.value
        return value is not None and _mentions(value, var)
    return False


def _escapes_through_handler(esc_node: ast.AST, start: ast.stmt) -> bool:
    """Best-effort tag: did the escaping path plausibly leave through an
    except handler?  (The CFG query returns only the last node.)"""
    for p in _parents_of(esc_node):
        if isinstance(p, ast.ExceptHandler):
            return True
    return False


def _parents_of(node: ast.AST):
    while True:
        node = getattr(node, "_repro_parent", None)
        if node is None:
            return
        yield node
