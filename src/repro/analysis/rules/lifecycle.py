"""lifecycle: ``Slot.state`` changes only through ``to()`` /
``force_empty()``, and every transition the code spells out must be an
edge of ``lifecycle.TRANSITIONS``.

The transition table is the contract the whole preemption/parking
machinery (and its tests) lean on: a direct ``slot.state = SlotState.X``
write bypasses the runtime check silently, and a ``to()`` call along an
illegal edge only explodes when that path actually runs.  This pass
parses the enum and table out of ``serve/lifecycle.py`` and checks, at
lint time:

* no ``<expr>.state = SlotState.X`` assignment outside the defining module;
* every ``SlotState.X`` reference names a real member;
* chained ``slot.to(A).to(B)`` implies edge ``A -> B``;
* a ``to(X)`` guarded by ``if slot.state is SlotState.Y`` implies ``Y -> X``;
* any other ``to(X)`` target must at least be a destination of *some* edge;
* ``force_empty()`` is called only from ``reset()`` (the documented escape
  hatch for whole-scheduler teardown).
"""

from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from ..engine import Finding, Module, RepoContext, Rule, dotted

RULE_ID = "lifecycle"


class LifecycleRule(Rule):
    id = RULE_ID
    summary = ("Slot.state written only via to()/force_empty(); spelled-out "
               "transitions must be edges of lifecycle.TRANSITIONS")

    def check(self, module: Module, ctx: RepoContext) -> List[Finding]:
        if not ctx.states:
            return []      # no lifecycle module found: nothing to enforce
        try:
            if module.path.resolve() == ctx.lifecycle_path.resolve():
                return []  # the defining module owns the raw writes
        except OSError:
            pass
        out: List[Finding] = []
        uses_lifecycle = any(isinstance(n, ast.Name) and n.id == "SlotState"
                             for n in ast.walk(module.tree))
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if (isinstance(tgt, ast.Attribute) and tgt.attr == "state"
                            and _slotstate_member(node.value) is not None):
                        out.append(Finding(
                            RULE_ID, module.rel, node.lineno, node.col_offset,
                            "direct `.state = SlotState...` write bypasses "
                            "the transition table: use Slot.to()"))
            elif isinstance(node, ast.Attribute) and uses_lifecycle:
                member = _slotstate_member(node)
                if member is not None and member not in ctx.states:
                    out.append(Finding(
                        RULE_ID, module.rel, node.lineno, node.col_offset,
                        f"unknown slot state `SlotState.{member}`"))
            elif isinstance(node, ast.Call):
                out.extend(self._check_call(module, ctx, node))
        return out

    def _check_call(self, module: Module, ctx: RepoContext,
                    call: ast.Call) -> List[Finding]:
        out: List[Finding] = []
        if not isinstance(call.func, ast.Attribute):
            return out
        attr = call.func.attr
        if attr == "force_empty":
            owner = _enclosing_function(module, call)
            if owner is not None and owner.name not in ("reset", "force_empty"):
                out.append(Finding(
                    RULE_ID, module.rel, call.lineno, call.col_offset,
                    f"force_empty() outside reset() (in `{owner.name}`): it "
                    "skips the transition table; drive DRAINED -> EMPTY "
                    "through to()"))
            return out
        if attr != "to" or len(call.args) != 1:
            return out
        dst = _slotstate_member(call.args[0])
        if dst is None:
            return out
        if dst not in ctx.states:
            return out     # already reported as unknown member above
        src, how = self._infer_source(module, call)
        if src is not None:
            if not ctx.is_edge(src, dst):
                out.append(Finding(
                    RULE_ID, module.rel, call.lineno, call.col_offset,
                    f"transition {src} -> {dst} ({how}) is not an edge of "
                    "lifecycle.TRANSITIONS"))
        elif dst not in ctx.destinations:
            out.append(Finding(
                RULE_ID, module.rel, call.lineno, call.col_offset,
                f"to(SlotState.{dst}): no edge in lifecycle.TRANSITIONS "
                "ends in this state"))
        return out

    def _infer_source(self, module: Module,
                      call: ast.Call) -> Tuple[Optional[str], str]:
        """Best-effort source state for a ``.to(X)`` call."""
        recv = call.func.value
        # chained: slot.to(A).to(B) — receiver is itself a to() call
        if (isinstance(recv, ast.Call) and isinstance(recv.func, ast.Attribute)
                and recv.func.attr == "to" and len(recv.args) == 1):
            src = _slotstate_member(recv.args[0])
            if src is not None:
                return src, "chained to()"
        base = dotted(recv)
        if base is None:
            return None, ""
        node: ast.AST = call
        for parent in module.parents(call):
            if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef)):
                break
            if isinstance(parent, ast.If) and _in_body(parent, node):
                src = _guard_state(parent.test, base)
                if src is not None:
                    return src, f"guarded by `{base}.state is SlotState.{src}`"
            node = parent
        return None, ""


def _slotstate_member(node: Optional[ast.AST]) -> Optional[str]:
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "SlotState"):
        return node.attr
    return None


def _enclosing_function(module: Module, node: ast.AST):
    for p in module.parents(node):
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return p
    return None


def _in_body(if_node: ast.If, child: ast.AST) -> bool:
    return any(child is s for s in if_node.body)


def _guard_state(test: ast.AST, base: str) -> Optional[str]:
    """``<base>.state is SlotState.Y`` (or ==) in a guard expression."""
    for node in ast.walk(test):
        if not isinstance(node, ast.Compare) or len(node.ops) != 1:
            continue
        if not isinstance(node.ops[0], (ast.Is, ast.Eq)):
            continue
        left = node.left
        if (isinstance(left, ast.Attribute) and left.attr == "state"
                and dotted(left.value) == base):
            return _slotstate_member(node.comparators[0])
    return None
