"""kernel-rules: Pallas kernel hygiene.

Three checks, scoped to modules that call ``pallas_call`` (or live under
a ``kernels/`` package):

* **fp32 accumulation** — VMEM scratch accumulators must be
  ``jnp.float32`` (the online-softmax running state and matmul
  accumulators lose exactness in bf16, which is precisely the parity bug
  class the kernel CI tier pins), and matmul operands must not be raw
  ``*_ref[...]`` loads (cast with ``.astype(jnp.float32)`` first).
* **no hardcoded ``interpret=``** — a literal ``interpret=True`` in a
  ``pallas_call`` silently pins the slow interpreter (or, ``False``,
  breaks CPU CI); the flag must route through
  ``kernels/runtime.resolve_interpret`` so the environment decides.
* **page-table masking** — a kernel that indexes through a page table
  (``pt``/``page_table``/``*_table`` names) must carry a ``>= 0`` (or
  ``< 0``) validity compare or a ``maximum(..., 0)`` clamp in the same
  function: unmapped table entries are ``-1``, and an unmasked load from
  page ``-1`` wraps to the last page and reads another request's KV.
"""

from __future__ import annotations

import ast
import re
from typing import List, Optional

from ..engine import Finding, Module, RepoContext, Rule, dotted

RULE_ID = "kernel-rules"

_TABLE_NAME = re.compile(r"(^|_)(pt|page_table|table)(_ref)?$")


def _is_kernel_module(module: Module) -> bool:
    if "kernels" in module.path.parts:
        return True
    return any(isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
               and n.func.attr == "pallas_call"
               for n in ast.walk(module.tree))


class KernelRules(Rule):
    id = RULE_ID
    summary = ("Pallas kernels: fp32 VMEM accumulators and matmul inputs, "
               "interpret= via runtime.resolve_interpret, page-table loads "
               "masked against -1")

    def check(self, module: Module, ctx: RepoContext) -> List[Finding]:
        if not _is_kernel_module(module):
            return []
        out: List[Finding] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d and d.split(".")[-1] == "pallas_call":
                out.extend(self._check_pallas_call(module, node))
            if d and d.split(".")[-1] == "VMEM":
                out.extend(self._check_vmem(module, node))
        for fn in ast.walk(module.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.extend(self._check_table_masking(module, fn))
                out.extend(self._check_matmul_operands(module, fn))
        return out

    def _check_pallas_call(self, module: Module,
                           call: ast.Call) -> List[Finding]:
        out = []
        for kw in call.keywords:
            if kw.arg != "interpret":
                continue
            if isinstance(kw.value, ast.Constant) and isinstance(
                    kw.value.value, bool):
                out.append(Finding(
                    RULE_ID, module.rel, kw.value.lineno, kw.value.col_offset,
                    f"hardcoded interpret={kw.value.value} in pallas_call: "
                    "route through kernels/runtime.resolve_interpret() so "
                    "the environment picks interpret vs Mosaic"))
        return out

    def _check_vmem(self, module: Module, call: ast.Call) -> List[Finding]:
        if len(call.args) < 2:
            return []
        dt = call.args[1]
        name = dotted(dt)
        if name is not None and not name.endswith("float32"):
            return [Finding(
                RULE_ID, module.rel, dt.lineno, dt.col_offset,
                f"VMEM scratch dtype `{name}`: kernel accumulators (running "
                "max / normalizer / acc) must be jnp.float32")]
        return []

    # -- matmul operand casting -------------------------------------------

    def _check_matmul_operands(self, module: Module,
                               fn: ast.AST) -> List[Finding]:
        out = []
        for node in ast.walk(fn):
            operands: List[ast.AST] = []
            where = None
            if isinstance(node, ast.Call):
                d = dotted(node.func)
                if d and d.split(".")[-1] in ("dot_general", "dot"):
                    operands = list(node.args[:2])
                    where = node
            elif isinstance(node, ast.BinOp) and isinstance(node.op,
                                                            ast.MatMult):
                operands = [node.left, node.right]
                where = node
            for op in operands:
                if _is_raw_ref_load(op):
                    out.append(Finding(
                        RULE_ID, module.rel, op.lineno, op.col_offset,
                        "matmul operand is a raw ref load: cast with "
                        ".astype(jnp.float32) so the MXU accumulates in "
                        "fp32, matching the VMEM scratch"))
        return out

    # -- page-table mask post-domination ----------------------------------

    def _check_table_masking(self, module: Module,
                             fn: ast.AST) -> List[Finding]:
        loads = []
        guarded = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Subscript):
                base = dotted(node.value)
                if base is not None and _TABLE_NAME.search(base.split(".")[-1]):
                    if isinstance(node.ctx, ast.Load):
                        loads.append((node, base))
            if _is_table_guard(node):
                guarded = True
        if loads and not guarded:
            return [Finding(
                RULE_ID, module.rel, n.lineno, n.col_offset,
                f"page-table load `{base}[...]` in `{fn.name}` has no "
                "`>= 0` mask or `maximum(..., 0)` clamp on its path: "
                "-1 (unmapped) entries wrap around and read another "
                "slot's pages") for n, base in loads]
        return []


def _is_raw_ref_load(node: ast.AST) -> bool:
    return (isinstance(node, ast.Subscript)
            and isinstance(node.value, ast.Name)
            and node.value.id.endswith("_ref")
            and isinstance(node.ctx, ast.Load))


def _is_table_guard(node: ast.AST) -> bool:
    """A `-1`-mask idiom: `pt... >= 0`, `pt... < 0`, or a
    `maximum(pt..., 0)` clamp."""
    if isinstance(node, ast.Compare) and len(node.ops) == 1:
        comp = node.comparators[0]
        if (isinstance(node.ops[0], (ast.GtE, ast.Lt))
                and isinstance(comp, ast.Constant) and comp.value == 0
                and _mentions_table(node.left)):
            return True
    if isinstance(node, ast.Call):
        d = dotted(node.func)
        if (d and d.split(".")[-1] == "maximum" and len(node.args) == 2
                and isinstance(node.args[1], ast.Constant)
                and node.args[1].value == 0
                and _mentions_table(node.args[0])):
            return True
    return False


def _mentions_table(node: ast.AST) -> bool:
    for n in ast.walk(node):
        name: Optional[str] = None
        if isinstance(n, ast.Name):
            name = n.id
        elif isinstance(n, ast.Attribute):
            name = n.attr
        if name is not None and _TABLE_NAME.search(name):
            return True
    return False
