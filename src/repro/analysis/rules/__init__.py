"""Rule registry: the five repo-specific passes, in stable order."""

from __future__ import annotations

from typing import List

from ..engine import Rule
from .allocator import AllocatorDisciplineRule
from .jit_purity import JitPurityRule
from .kernel import KernelRules
from .lifecycle import LifecycleRule
from .sharding import ShardingRegistryRule


def build_rules() -> List[Rule]:
    return [
        JitPurityRule(),
        AllocatorDisciplineRule(),
        LifecycleRule(),
        KernelRules(),
        ShardingRegistryRule(),
    ]
