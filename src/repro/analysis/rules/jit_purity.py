"""jit-purity: functions reachable from ``jax.jit`` / ``pl.pallas_call`` /
``make_*`` step factories must stay host-pure.

A traced function runs *once* per compilation, not once per call, so any
host effect inside it is a latent bug: ``self.*`` writes happen at trace
time and then never again; Python RNG / clock reads bake a constant into
the compiled program; mutable default arguments alias state across
traces.  The pass roots the call graph at every jit/pallas entry point it
can see (including dotted ``module.fn`` arguments, resolved through the
importing module's aliases) and walks same-module calls and ``self.``
method calls to a fixpoint.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..engine import Finding, Module, RepoContext, Rule, dotted, import_aliases

RULE_ID = "jit-purity"

# host-effect call roots (matched against the *resolved* import alias)
_IMPURE_MODULES = {"random", "time", "secrets", "uuid"}
_IMPURE_DOTTED_PREFIXES = ("numpy.random", "os.urandom", "os.environ")
_IMPURE_BUILTINS = {"open", "input"}
_MUTATORS = {"append", "extend", "insert", "remove", "pop", "clear", "add",
             "discard", "update", "setdefault", "popitem", "sort", "reverse",
             "appendleft", "popleft", "write"}


def _func_key(node: ast.AST) -> Optional[Tuple[Optional[str], str]]:
    """(class name or None, function name) for a def node."""
    if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return None
    parent = getattr(node, "_repro_parent", None)
    cls = parent.name if isinstance(parent, ast.ClassDef) else None
    return (cls, node.name)


class _ModuleIndex:
    def __init__(self, mod: Module):
        self.mod = mod
        self.aliases = import_aliases(mod.tree)
        # (class, name) -> def node; also name -> [def nodes] for bare calls
        self.defs: Dict[Tuple[Optional[str], str], ast.AST] = {}
        self.by_name: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(mod.tree):
            key = _func_key(node)
            if key is not None:
                self.defs[key] = node
                self.by_name.setdefault(key[1], []).append(node)

    def resolve(self, name: str) -> str:
        """Local alias -> fully qualified dotted path (best effort)."""
        head, _, tail = name.partition(".")
        base = self.aliases.get(head, head)
        return f"{base}.{tail}" if tail else base


class JitPurityRule(Rule):
    id = RULE_ID
    summary = ("functions reachable from jax.jit / pallas_call / make_* step "
               "factories must not mutate host state, use Python RNG/clock/IO, "
               "or carry mutable defaults")

    def __init__(self):
        self._cross_roots: Set[str] = set()   # fully qualified "pkg.mod.fn"

    # -- phase 1: collect dotted jit roots across the whole module set ----

    def prepare(self, modules: Sequence[Module], ctx: RepoContext) -> None:
        self._cross_roots = set()
        for mod in modules:
            idx = _ModuleIndex(mod)
            for call in ast.walk(mod.tree):
                if not isinstance(call, ast.Call):
                    continue
                for target in _jit_arguments(call, idx):
                    d = dotted(target)
                    if d and "." in d:
                        resolved = idx.resolve(d)
                        if resolved.startswith("."):   # relative import
                            resolved = _absolutize(mod, resolved)
                        self._cross_roots.add(resolved)

    # -- phase 2: per-module reachability + purity checks -----------------

    def check(self, module: Module, ctx: RepoContext) -> List[Finding]:
        idx = _ModuleIndex(module)
        roots = self._local_roots(module, idx)
        reachable = self._closure(roots, idx)
        findings: List[Finding] = []
        for fn in reachable:
            findings.extend(self._check_function(fn, idx))
        return findings

    def _local_roots(self, module: Module, idx: _ModuleIndex) -> List[ast.AST]:
        roots: List[ast.AST] = []
        mod_dotted = module.dotted_name

        def add_name(name: str):
            roots.extend(idx.by_name.get(name, []))

        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call):
                for target in _jit_arguments(node, idx):
                    d = dotted(target)
                    if d is None:
                        continue
                    if "." not in d:
                        add_name(d)
                    elif d.startswith("self."):
                        add_name(d.split(".", 1)[1])
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if _is_jit_expr(dec, idx):
                        roots.append(node)
                # step factories: the inner functions a make_* factory
                # defines are the traced bodies, whoever jits them later
                if node.name.startswith("make_"):
                    for inner in node.body:
                        if isinstance(inner, (ast.FunctionDef,
                                              ast.AsyncFunctionDef)):
                            roots.append(inner)
                if mod_dotted and f"{mod_dotted}.{node.name}" in self._cross_roots:
                    roots.append(node)
        return roots

    def _closure(self, roots: List[ast.AST], idx: _ModuleIndex) -> List[ast.AST]:
        seen: Set[int] = set()
        order: List[ast.AST] = []
        stack = list(roots)
        while stack:
            fn = stack.pop()
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            order.append(fn)
            for node in _walk_function(fn):
                if not isinstance(node, ast.Call):
                    continue
                d = dotted(node.func)
                if d is None:
                    continue
                if "." not in d:
                    stack.extend(idx.by_name.get(d, []))
                elif d.startswith("self.") and d.count(".") == 1:
                    stack.extend(idx.by_name.get(d.split(".", 1)[1], []))
        return order

    def _check_function(self, fn: ast.AST, idx: _ModuleIndex) -> List[Finding]:
        out: List[Finding] = []
        rel = idx.mod.rel

        def flag(node, msg):
            out.append(Finding(RULE_ID, rel, node.lineno,
                               getattr(node, "col_offset", 0),
                               f"in jit-reachable `{fn.name}`: {msg}"))

        for default in (list(fn.args.defaults)
                        + [d for d in fn.args.kw_defaults if d is not None]):
            if isinstance(default, (ast.List, ast.Dict, ast.Set)) or (
                    isinstance(default, ast.Call)
                    and dotted(default.func) in {"list", "dict", "set"}):
                flag(default, "mutable default argument (shared across traces)")
        for node in _walk_function(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                flat: List[ast.AST] = []
                for tgt in targets:
                    if isinstance(tgt, (ast.Tuple, ast.List)):
                        flat.extend(tgt.elts)
                    else:
                        flat.append(tgt)
                for tgt in flat:
                    base = tgt
                    while isinstance(base, (ast.Attribute, ast.Subscript)):
                        base = base.value
                    if (isinstance(tgt, (ast.Attribute, ast.Subscript))
                            and isinstance(base, ast.Name)
                            and base.id == "self"):
                        flag(tgt, "writes host state through `self` "
                                  "(runs at trace time only)")
            elif isinstance(node, ast.Global):
                flag(node, "writes module globals from traced code")
            elif isinstance(node, ast.Call):
                d = dotted(node.func)
                if d is None:
                    continue
                if d in _IMPURE_BUILTINS:
                    flag(node, f"host IO call `{d}()` inside traced code")
                    continue
                resolved = idx.resolve(d)
                head = resolved.split(".")[0]
                if head in _IMPURE_MODULES or any(
                        resolved.startswith(p) for p in _IMPURE_DOTTED_PREFIXES):
                    flag(node, f"impure host call `{d}` (resolves to "
                               f"`{resolved}`): traced once, then frozen")
                elif (d.startswith("self.") and d.count(".") >= 2
                        and d.rsplit(".", 1)[1] in _MUTATORS):
                    flag(node, f"mutates host container `{d.rsplit('.', 1)[0]}`")
        return out


def _walk_function(fn: ast.AST):
    """Walk a function body without descending into nested defs/classes
    (nested defs are pulled into the closure separately if called)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef, ast.Lambda)):
            stack.extend(ast.iter_child_nodes(node))


def _is_jit_expr(node: ast.AST, idx: _ModuleIndex) -> bool:
    """Is this expression `jax.jit` / `jit` / `functools.partial(jax.jit, ..)`?"""
    d = dotted(node)
    if d is not None:
        return idx.resolve(d) in {"jax.jit", "jax.named_call", "jax.jit.jit"}
    if isinstance(node, ast.Call):
        fd = dotted(node.func)
        if fd and idx.resolve(fd) in {"functools.partial", "partial"}:
            return bool(node.args) and _is_jit_expr(node.args[0], idx)
        if fd and idx.resolve(fd) == "jax.jit":
            return True
    return False


def _jit_arguments(call: ast.Call, idx: _ModuleIndex) -> List[ast.AST]:
    """The function-valued argument(s) a jit/pallas_call invocation traces."""
    d = dotted(call.func)
    if d is None:
        return []
    resolved = idx.resolve(d)
    traced: List[ast.AST] = []
    if resolved in {"jax.jit"} or d in {"jit", "jax.jit"}:
        if call.args:
            traced.append(call.args[0])
    elif resolved.endswith("pallas_call") or d.endswith("pallas_call"):
        if call.args:
            traced.append(call.args[0])
    out: List[ast.AST] = []
    for t in traced:
        if (isinstance(t, ast.Call) and dotted(t.func)
                and idx.resolve(dotted(t.func)) in {"functools.partial",
                                                    "partial"} and t.args):
            out.append(t.args[0])
        else:
            out.append(t)
    return out


def _absolutize(mod: Module, relative: str) -> str:
    """Resolve a `from ..models import kvcache`-style alias against the
    importing module's dotted path."""
    pkg = mod.dotted_name
    if pkg is None:
        return relative.lstrip(".")
    parts = pkg.split(".")[:-1]
    level = len(relative) - len(relative.lstrip("."))
    tail = relative.lstrip(".")
    base = parts[: len(parts) - (level - 1)] if level > 1 else parts
    return ".".join(base + ([tail] if tail else []))
