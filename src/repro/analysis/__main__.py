"""CLI: ``python -m repro.analysis [paths] [--json] [--rules a,b]``.

Exit code 0 when every finding is suppressed (or none exist), 1 when
unsuppressed findings remain, 2 on usage errors.  ``--json`` emits the
machine-readable report the CI job archives as an artifact.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .engine import analyze, default_rules, render_json, render_text


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-aware static analysis (jit purity, allocator "
                    "discipline, slot lifecycle, Pallas kernel hygiene, "
                    "sharding axis registry)")
    parser.add_argument("paths", nargs="*", default=None,
                        help="files or directories to scan (default: src/)")
    parser.add_argument("--json", action="store_true",
                        help="emit the JSON report instead of text")
    parser.add_argument("--rules", default=None,
                        help="comma-separated rule ids to run (default: all)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule table and exit")
    parser.add_argument("--verbose", action="store_true",
                        help="also show suppressed findings (text mode)")
    args = parser.parse_args(argv)

    rules = default_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.id}: {r.summary}")
        return 0
    if args.rules:
        wanted = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = wanted - {r.id for r in rules}
        if unknown:
            print(f"unknown rule(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2
        rules = [r for r in rules if r.id in wanted]

    paths = args.paths or (["src"] if Path("src").is_dir() else ["."])
    missing = [p for p in paths if not Path(p).exists()]
    if missing:
        print(f"no such path: {', '.join(missing)}", file=sys.stderr)
        return 2
    report = analyze(paths, rules=rules)
    if args.json:
        print(render_json(report))
    else:
        print(render_text(report, verbose=args.verbose))
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
