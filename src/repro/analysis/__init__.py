"""Repo-aware static analysis for the repro codebase.

The runtime guards (``PageAllocator`` refcount audits, ``Slot.to``'s
transition table, the randomized scheduler differential harness) catch
invariant violations long after the commit that introduced them.  This
package moves those checks to lint time: an AST/CFG engine plus five
passes that understand *this repo's* invariants — jit purity, allocator
discipline, slot-lifecycle writes, Pallas kernel hygiene, and sharding
axis names.

Run ``python -m repro.analysis [paths]``; suppress an intentional finding
with ``# repro: allow(<rule>) -- <reason>`` on (or directly above) the
flagged line.
"""

from .engine import (Finding, Module, RepoContext, Report, Rule, analyze,
                     default_rules, render_json, render_text)

__all__ = [
    "Finding",
    "Module",
    "RepoContext",
    "Report",
    "Rule",
    "analyze",
    "default_rules",
    "render_json",
    "render_text",
]
