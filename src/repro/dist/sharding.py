"""Named-rule sharding registry: the single place state placement is decided.

FaaSKeeper's core lesson — a coordination service only scales when state
placement is explicit and cheap to reason about — applied to the data plane:
every tensor class (weights, optimizer moments, activations, batches, decode
caches) resolves its placement through a *named rule*, and the model code
never mentions mesh axes.

Three layers:

* **Mesh vocabulary** — :class:`MeshRules` maps a mesh's axis names onto the
  two logical roles: ``dp`` (the data-parallel axes, ``("data",)`` single-pod
  or ``("pod", "data")`` multi-pod, always a tuple so hierarchical DP is one
  PartitionSpec entry) and ``model`` (the tensor-parallel axis).

* **Storage rules** — :func:`param_shardings` / :func:`batch_shardings` /
  :func:`cache_shardings` walk abstract pytrees and assign
  ``NamedSharding``s.  Parameter placement goes through the
  :data:`PARAM_RULES` registry: ordered ``(match, spec)`` pairs keyed on the
  pytree path, with a shape-driven ``auto`` fallback that shards the largest
  divisible dim on ``model`` and the next on ``dp`` (FSDP x TP).  Every rule
  is divisibility-guarded: an axis that does not evenly divide a dim is
  dropped rather than failing, so the same rules resolve on a 1x1 CPU smoke
  mesh, the 16x16 production pod, and the 2x16x16 multi-pod mesh.

* **Activation policy** — :class:`ShardingPolicy` carries a dict of named
  activation PartitionSpecs; :func:`activation_sharding` installs it for the
  current trace and :func:`constrain` (the only hook model code calls) looks
  the rule name up, fits it to the tensor's rank/shape, and applies
  ``jax.lax.with_sharding_constraint``.  With no policy installed
  ``constrain`` is the identity, so eager smoke tests and benchmarks run the
  exact same model code with zero sharding machinery.

Adding a rule for a new architecture: give the weight a distinctive pytree
key and append a ``ParamRule`` before ``auto`` in :data:`PARAM_RULES`
(storage), and/or add a named entry to :meth:`ShardingPolicy.default`'s spec
table plus a ``constrain(x, "<name>")`` call at the use site (compute
layout).  Rules are pure functions of abstract shapes + mesh — unit-test
them with ``AbstractMesh``, no devices needed.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
from contextvars import ContextVar
from typing import Any, Callable, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any


# ---------------------------------------------------------------------------
# Mesh vocabulary
# ---------------------------------------------------------------------------


# The full mesh-axis vocabulary.  Every axis name a PartitionSpec (or a
# mesh constructor) may spell out literally lives here: ``pod`` (inter-pod
# hierarchical DP, multi-pod only), ``data`` (intra-pod DP), ``model``
# (tensor parallel).  The static-analysis sharding pass parses this tuple
# from the AST and flags any literal axis name outside it — register a new
# axis here before using it in a spec.
MESH_AXES: Tuple[str, ...] = ("pod", "data", "model")


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Logical axis roles for a (possibly abstract) mesh."""

    axis_names: Tuple[str, ...]
    dp: Tuple[str, ...]      # data-parallel axes (hierarchical on multi-pod)
    model: str               # tensor-parallel axis

    @classmethod
    def for_mesh(cls, mesh) -> "MeshRules":
        names = tuple(mesh.axis_names)
        if "model" in names:
            model = "model"
        else:
            model = names[-1]
        dp = tuple(a for a in names if a != model)
        return cls(axis_names=names, dp=dp, model=model)

    def dp_size(self, mesh) -> int:
        return int(math.prod(mesh.shape[a] for a in self.dp)) if self.dp else 1

    def model_size(self, mesh) -> int:
        return int(mesh.shape[self.model])


def _axes_size(entry, mesh) -> int:
    axes = entry if isinstance(entry, tuple) else (entry,)
    return int(math.prod(mesh.shape[a] for a in axes))


def _fit_spec(spec: P, shape: Tuple[int, ...], mesh) -> P:
    """Divisibility guard: drop any spec entry whose axes do not evenly
    divide the corresponding dim (rules stay total over shapes/meshes)."""
    entries = [*spec] + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries, strict=True):
        if entry is None:
            out.append(None)
        else:
            out.append(entry if dim % _axes_size(entry, mesh) == 0 else None)
    return P(*out)


# ---------------------------------------------------------------------------
# Parameter storage rules (the registry)
# ---------------------------------------------------------------------------

# Top-level pytree keys whose children carry a leading scan-over-layers dim
# that storage rules must skip.
STACKED_PREFIXES = ("layers", "blocks", "enc_layers")


@dataclasses.dataclass(frozen=True)
class ParamRule:
    """One named storage rule: ``match`` on the pytree path decides
    applicability, ``spec`` produces the (unfitted) PartitionSpec."""

    name: str
    match: Callable[[Tuple[str, ...], Tuple[int, ...]], bool]
    spec: Callable[[Tuple[str, ...], Tuple[int, ...], MeshRules, Any], P]


def _nspec(ndim: int, at: Dict[int, Any]) -> P:
    """PartitionSpec with entries at the given (possibly negative) dims."""
    entries = [None] * ndim
    for pos, axes in at.items():
        entries[pos] = axes
    return P(*entries)


def _rule_head(keys, shape, rules, mesh) -> P:
    # (d_model, padded_vocab): vocab on model, contraction dim UNSHARDED —
    # sharding d would all-reduce the full logits tensor (the 40 GB/device
    # whisper incident pinned by tests/test_sharding.py).
    return _nspec(len(shape), {-1: rules.model})


def _rule_embed(keys, shape, rules, mesh) -> P:
    # (padded_vocab, d_model): rows on dp (ZeRO-style), d on model —
    # gather-friendly for embed lookups; lm_head re-shards the tied table
    # via the "head_weight" activation rule.
    return _nspec(len(shape), {-2: tuple(rules.dp) or None, -1: rules.model})


def _rule_expert_in(keys, shape, rules, mesh) -> P:
    # (..., E, D, F): experts on model (EP), D on dp (FSDP) — exactly the
    # storage layout the stationary-decode shard_map consumes.
    return _nspec(len(shape), {-3: rules.model, -2: tuple(rules.dp) or None})


def _rule_expert_out(keys, shape, rules, mesh) -> P:
    # (..., E, F, D): experts on model, output D on dp.
    return _nspec(len(shape), {-3: rules.model, -1: tuple(rules.dp) or None})


def _auto_spec(keys, shape, rules, mesh) -> P:
    """Fallback: greedy largest-divisible assignment (model first, then dp).

    Skips the leading scan dim for stacked trees.  Breaks size ties toward
    the trailing dim for ``model``, which lands matmul weights in the
    (dp, model) FSDP x TP layout.
    """
    sp = 1 if keys and keys[0] in STACKED_PREFIXES and len(shape) > 1 else 0
    entries: list = [None] * len(shape)
    candidates = sorted(range(sp, len(shape)),
                        key=lambda i: (shape[i], i), reverse=True)
    picked_model = None
    model_size = rules.model_size(mesh)
    for i in candidates:
        if shape[i] > 1 and shape[i] % model_size == 0:
            entries[i] = rules.model
            picked_model = i
            break
    if rules.dp:
        dp_size = rules.dp_size(mesh)
        for i in candidates:
            if i != picked_model and shape[i] > 1 and shape[i] % dp_size == 0:
                entries[i] = tuple(rules.dp)
                break
    return P(*entries)


PARAM_RULES: Tuple[ParamRule, ...] = (
    ParamRule("head",
              lambda keys, shape: keys[-1:] == ("head",) and len(shape) >= 2,
              _rule_head),
    ParamRule("embed",
              lambda keys, shape: keys[-1:] == ("embed",) and len(shape) >= 2,
              _rule_embed),
    ParamRule("expert_ffn_in",
              lambda keys, shape: "experts" in keys and len(shape) >= 3
              and keys[-1] in ("w_gate", "w_up"),
              _rule_expert_in),
    ParamRule("expert_ffn_out",
              lambda keys, shape: "experts" in keys and len(shape) >= 3
              and keys[-1] == "w_down",
              _rule_expert_out),
    ParamRule("auto", lambda keys, shape: len(shape) >= 2, _auto_spec),
)


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
                 for k in path)


def resolve_param_rule(keys: Tuple[str, ...], shape: Tuple[int, ...]
                       ) -> Optional[ParamRule]:
    """First registry rule matching this (path, shape); None -> replicate."""
    for rule in PARAM_RULES:
        if rule.match(keys, shape):
            return rule
    return None


def _resolve_param_spec(keys, shape, rules: MeshRules, mesh) -> P:
    rule = resolve_param_rule(keys, shape)
    if rule is None:
        return P()
    return _fit_spec(rule.spec(keys, shape, rules, mesh), shape, mesh)


def param_shardings(p_abs: PyTree, mesh) -> PyTree:
    """NamedShardings for a parameter pytree (abstract or concrete leaves).

    Guarantees every >=2-dim weight leaf is sharded on at least one axis
    whenever any of its dims divides an axis — the 110B/235B configs cannot
    afford replicated matrices in 16 GB HBM.
    """
    rules = MeshRules.for_mesh(mesh)

    def assign(path, leaf):
        spec = _resolve_param_spec(_path_keys(path), tuple(leaf.shape), rules, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, p_abs)


# ---------------------------------------------------------------------------
# Batch / cache storage rules
# ---------------------------------------------------------------------------


def batch_shardings(batch_abs: PyTree, mesh) -> PyTree:
    """Leading (global-batch) dim on the full dp tuple; replicated when the
    batch does not divide (e.g. the B=1 long-context cell)."""
    rules = MeshRules.for_mesh(mesh)
    dp_size = rules.dp_size(mesh)

    def assign(leaf):
        shape = tuple(leaf.shape)
        if rules.dp and shape and shape[0] % dp_size == 0:
            return NamedSharding(mesh, P(tuple(rules.dp), *([None] * (len(shape) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(assign, batch_abs)


# decode-cache kv-ring leaf keys; dims are indexed from the right so stacked
# (leading layer dim) and unstacked leaves share one rule
_CACHE_KV_KEYS = frozenset({"k", "v", "xk", "xv"})
# paged-pool leaf keys: (..., n_pages, page_size, H, D) shared across slots
_CACHE_POOL_KEYS = frozenset({"kp", "vp"})


def cache_shardings(cache_abs: PyTree, mesh) -> PyTree:
    """Decode-state placement.

    kv rings (..., B, T, H, D): batch on dp; heads on model when the head
    count divides, else fall back to the time dim (GQA archs with few kv
    heads — the divisibility guard the sharding tests pin).  Paged pools
    (..., n_pages, page_size, H, D) have no slot axis — every slot's page
    table indexes one shared pool, so the pool stays *replicated over dp*
    and shards its within-page lane dim on model (heads, then pages, as
    fallbacks — the paged-attention kernel slices per-(page, head) blocks
    by table index, which head- or page-sharded pools can only serve by
    all-gathering the pool);
    page tables (..., n_slots, max_pages) follow the slot batch onto dp.
    Refcounted prefix sharing / session parking never changes pool
    placement: a shared page is just extra table rows pointing at it, and a
    copy-on-write split lands on another page of the same pool — the lane
    shard stays on ``model`` throughout (pinned by the prefix-sharing spec
    test).
    SSM states shard their head dim, conv tails and RG-LRU states their
    channel dim.
    """
    rules = MeshRules.for_mesh(mesh)
    dp = tuple(rules.dp) or None

    def assign(path, leaf):
        keys = _path_keys(path)
        key = keys[-1] if keys else ""
        shape = tuple(leaf.shape)
        nd = len(shape)
        entries: list = [None] * nd

        def put(dim: int, axes) -> bool:
            i = nd + dim if dim < 0 else dim
            if 0 <= i < nd and axes is not None and shape[i] % _axes_size(axes, mesh) == 0:
                entries[i] = axes
                return True
            return False

        if key in _CACHE_POOL_KEYS and nd >= 4:  # (..., Np, ps, H, D) shared pool
            # within-page lane dim first, then heads, then pages.  The paged
            # decode kernel streams the pool one (page, head) block per grid
            # step, so a pool sharded across heads or pages turns every
            # block slice into a cross-shard read XLA answers by
            # all-gathering the whole pool each step (measured on the 16x16
            # decode_32k cells: 73 GB/device wire page-sharded, 65 GB
            # head-sharded, 93 MB lane-sharded).  Lane shards keep block
            # slicing local and partition the softmax like the ring cells'
            # seq-sharded attention; the gather path is layout-indifferent.
            put(-3, rules.model) or put(-2, rules.model) or put(-4, rules.model)
        elif key == "page_table" and nd >= 2:    # (..., n_slots, max_pages)
            put(-2, dp)
        elif key in _CACHE_KV_KEYS and nd >= 4:  # (..., B, T, H, D)
            put(-4, dp)
            put(-2, rules.model) or put(-3, rules.model)
        elif key == "ssm" and nd >= 4:           # (..., B, H, P, N)
            put(-4, dp)
            put(-3, rules.model)
        elif key == "conv" and nd >= 3:          # (..., B, K-1, C)
            put(-3, dp)
            put(-1, rules.model)
        elif key == "h" and nd >= 2:             # (..., B, W) rg-lru state
            put(-2, dp)
            put(-1, rules.model)
        elif key == "positions" and nd >= 2:     # (..., B, T)
            put(-2, dp)
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(assign, cache_abs)


def offload_stage_shardings(stage_abs: PyTree, mesh) -> PyTree:
    """Placement for KV offload staging buffers.

    A staging buffer is a gathered page chunk ``(..., n_chunk_pages,
    page_size, H, D)`` in flight between the shared pool and host memory
    (``kvcache.gather_pages`` / ``scatter_pages``).  Unlike the resident
    pool, the chunk is about to cross the device boundary, so the only
    useful partitioning is the one that matches the pool's own sharding —
    each shard DMAs its own pool slice and no reshuffle happens before the
    transfer.  That means the *same* fallback order as
    :func:`cache_shardings`' pool rule: within-page lane dim on ``model``
    first, then heads; everything else (including the gathered-page dim —
    chunks are a handful of pages, far too small to amortize a collective)
    stays replicated.
    """
    rules = MeshRules.for_mesh(mesh)

    def assign(path, leaf):
        keys = _path_keys(path)
        shape = tuple(leaf.shape)
        nd = len(shape)
        entries: list = [None] * nd
        if keys and keys[-1] in _CACHE_POOL_KEYS and nd >= 4:
            msize = _axes_size(rules.model, mesh)
            for dim in (nd - 3, nd - 2):    # (..., n, ps, H, D): lane, heads
                if shape[dim] % msize == 0:
                    entries[dim] = rules.model
                    break
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(assign, stage_abs)


# ---------------------------------------------------------------------------
# Activation policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Named activation-layout rules for one mesh, installed for a trace via
    :func:`activation_sharding` and consumed by :func:`constrain`."""

    mesh: Any
    specs: Dict[str, P]
    rules: MeshRules
    batch_shardable: bool = True
    attn_mode: str = "head"              # "head" | "seq"
    decode_stationary: bool = False      # stationary-weights MoE decode
    shard_map_pool: bool = False         # shard_map the fused paged gather

    @classmethod
    def default(cls, mesh, *, batch_shardable: bool = True,
                attn_mode: str = "head", decode_stationary: bool = False,
                shard_map_pool: bool = False,
                overrides: Optional[Dict[str, P]] = None) -> "ShardingPolicy":
        """The standard rule table.

        ``attn_mode="head"`` shards attention heads on ``model`` (needs the
        head counts to divide); ``"seq"`` falls back to sequence sharding for
        q with replicated kv (GQA/MQA archs whose kv heads don't divide).
        """
        rules = MeshRules.for_mesh(mesh)
        dp = tuple(rules.dp) if (batch_shardable and rules.dp) else None
        mdl = rules.model
        specs: Dict[str, P] = {
            # residual stream: Megatron-SP — sequence on model between blocks
            "activation": P(dp, mdl, None),
            # block entry: gather S, keep D whole for the TP projections
            "block_in": P(dp, None, None),
            "mlp_hidden": P(dp, None, mdl),
            "logits": P(dp, None, mdl),
            # matmul-layout (bf16, post-cast) weights: the ZeRO-3 dp-gather
            # moves the compute dtype, not the fp32 master
            "w_col": P(None, mdl),
            "w_row": P(mdl, None),
            # tied lm head: re-shard d-sharded table to vocab-sharded
            "head_weight": P(None, mdl),
            "ssm_heads": P(dp, None, mdl, None),
            "ssm_dt": P(dp, None, mdl),
            "lru_channels": P(dp, None, mdl),
        }
        if attn_mode == "head":
            specs["q_heads"] = P(dp, None, mdl, None)
            specs["kv_heads"] = P(dp, None, mdl, None)
            specs["attn_out"] = P(dp, None, mdl, None)
        else:
            specs["q_heads"] = P(dp, mdl, None, None)
            specs["kv_heads"] = P(dp, None, None, None)
            specs["attn_out"] = P(dp, mdl, None, None)
        if overrides:
            specs.update(overrides)
        return cls(mesh=mesh, specs=specs, rules=rules,
                   batch_shardable=batch_shardable, attn_mode=attn_mode,
                   decode_stationary=decode_stationary,
                   shard_map_pool=shard_map_pool)


_ACTIVE_POLICY: ContextVar[Optional[ShardingPolicy]] = ContextVar(
    "repro_dist_sharding_policy", default=None)


def current_policy() -> Optional[ShardingPolicy]:
    return _ACTIVE_POLICY.get()


@contextlib.contextmanager
def activation_sharding(policy: Optional[ShardingPolicy]):
    """Install ``policy`` for the enclosed trace (None -> force no policy)."""
    token = _ACTIVE_POLICY.set(policy)
    try:
        yield policy
    finally:
        _ACTIVE_POLICY.reset(token)


def constrain(x, rule_name: str):
    """Apply the active policy's named layout rule to ``x``.

    Identity when no policy is installed, when the policy has no such rule,
    or when no entry of the fitted spec survives the divisibility guard —
    model code can call this unconditionally.
    """
    policy = current_policy()
    if policy is None:
        return x
    spec = policy.specs.get(rule_name)
    if spec is None or len(spec) > x.ndim:
        return x
    fitted = _fit_spec(spec, tuple(x.shape), policy.mesh)
    if all(e is None for e in fitted):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(policy.mesh, fitted))


def constrain_tree(tree: PyTree, specs: Optional[PyTree], mesh=None):
    """Constrain every array leaf of ``tree`` to the matching leaf of a
    PartitionSpec pytree (e.g. the scheduler's ``cache_specs``).

    Identity when ``specs`` is None or no mesh is resolvable; leaves whose
    spec is the empty/replicated ``P()`` pass through untouched so the
    compiler keeps its freedom where the registry expressed no opinion.
    """
    if specs is None:
        return tree
    if mesh is None:
        policy = current_policy()
        mesh = policy.mesh if policy is not None else None
    if mesh is None:
        return tree

    def one(leaf, spec):
        if spec is None or all(e is None for e in spec):
            return leaf
        return jax.lax.with_sharding_constraint(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(one, tree, specs)
