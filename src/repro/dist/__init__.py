"""Distributed state placement: sharding rules, policies, and mesh roles.

``repro.dist.sharding`` is the only module that names mesh axes; everything
else (models, train/serve steps, the dry-run driver) talks to it through
named rules.  Importing the package installs the small jax version shims in
:mod:`repro.dist.compat` (no-ops on modern jax).
"""

from . import compat as _compat

_compat.install()

from . import sharding  # noqa: E402  (compat must install first)

__all__ = ["sharding"]
