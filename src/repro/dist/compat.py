"""Version shims for the pinned jax (0.4.x) so the sharding layer and its
call sites can be written against the current public API.

Two gaps matter here:

* ``AbstractMesh``: the modern constructor is ``AbstractMesh(axis_sizes,
  axis_names)``; 0.4.x only accepts ``AbstractMesh(shape_tuple)`` with
  ``((name, size), ...)`` pairs.  Rule resolution (and the sharding tests)
  build abstract meshes with the modern signature, so we install a subclass
  that accepts both.
* ``jax.shard_map``: promoted out of ``jax.experimental`` (and its
  ``check_rep`` flag renamed to ``check_vma``) after 0.4.x.  The MoE expert-
  parallel paths call ``jax.shard_map(..., check_vma=False)``.

Each shim is installed only when the running jax lacks the modern API, so an
interpreter upgrade makes this module a no-op.  ``install()`` is idempotent
and runs on ``import repro.dist``.
"""

from __future__ import annotations

import jax
import jax.sharding


def _install_abstract_mesh() -> None:
    native = jax.sharding.AbstractMesh
    try:  # modern signature already supported -> nothing to do
        native((1,), ("_probe",))
        return
    except TypeError:
        pass

    class AbstractMesh(native):  # type: ignore[misc,valid-type]
        """0.4.x AbstractMesh accepting the modern (sizes, names) call."""

        def __init__(self, *args, **kwargs):
            if (
                len(args) >= 2
                and isinstance(args[1], (tuple, list))
                and all(isinstance(n, str) for n in args[1])
            ):
                sizes, names = args[0], args[1]
                super().__init__(tuple(zip(names, sizes, strict=True)), *args[2:], **kwargs)
            else:
                super().__init__(*args, **kwargs)

    AbstractMesh.__name__ = "AbstractMesh"
    AbstractMesh.__qualname__ = "AbstractMesh"
    jax.sharding.AbstractMesh = AbstractMesh


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return

    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kwargs):
        kwargs.setdefault("check_rep", check_vma)
        return _legacy_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)

    jax.shard_map = shard_map


def install() -> None:
    _install_abstract_mesh()
    _install_shard_map()
