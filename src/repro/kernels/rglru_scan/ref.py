"""Pure-jnp oracle: sequential diagonal linear recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_rglru(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + b_t, h_0 = b_0 (zero initial state).

    a, b: (B, L, W) -> (B, L, W); fp32 math."""
    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    B, L, W = a.shape
    h0 = jnp.zeros((B, W), jnp.float32)
    _, hs = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(a.astype(jnp.float32), 1, 0),
         jnp.moveaxis(b.astype(jnp.float32), 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype)
