"""RG-LRU diagonal linear recurrence Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t over the sequence, per channel.

TPU adaptation: instead of a sequential per-token loop (VPU-bound) or a
log-depth associative scan (log L passes over HBM), each grid step processes
a (Q, bw) tile with the *closed form* over the block:

    P_i = prod_{j<=i} a_j  (via cumsum of logs — a in (0,1) so logs are safe)
    h_i = P_i * h0 + sum_{j<=i} (P_i / P_j) * b_j
        = T @ b + P * h0,   T[i,j] = exp(la_i - la_j) for i >= j

The (Q, Q) triangular kernel T turns the recurrence into one MXU matmul per
tile — the same quadratic-in-block trick SSD uses.  Carry h (bw,) lives in
VMEM scratch across the sequential L sweep.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..runtime import resolve_interpret


def _rglru_kernel(a_ref, b_ref, y_ref, h_ref, *, Q: int, bw: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0, ...].astype(jnp.float32)          # (Q, bw), in (0, 1)
    b = b_ref[0, ...].astype(jnp.float32)          # (Q, bw)

    la = jnp.cumsum(jnp.log(jnp.maximum(a, 1e-37)), axis=0)   # (Q, bw)
    # handle exact zeros in a: a==0 resets the state; the log-clamp floor
    # makes exp(la_i - la_j) underflow to 0 for spans crossing the reset.
    seg = la[:, None, :] - la[None, :, :]          # (Q, Q, bw)
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    T = jnp.where(tri[:, :, None], jnp.exp(seg), 0.0)
    h0 = h_ref[...]                                # (bw,)
    y = jnp.einsum("ijw,jw->iw", T, b) + jnp.exp(la) * h0[None, :]
    h_ref[...] = y[-1, :]
    y_ref[0, ...] = y.astype(y_ref.dtype)


def rglru_scan_kernel(a: jnp.ndarray, b: jnp.ndarray, *,
                      block_q: int = 128, block_w: int = 256,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """a, b: (B, L, W) -> h: (B, L, W).  L % block_q == 0, W % block_w == 0."""
    B, L, W = a.shape
    Q = min(block_q, L)
    bw = min(block_w, W)
    grid = (B, W // bw, L // Q)
    kernel = functools.partial(_rglru_kernel, Q=Q, bw=bw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, bw), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, Q, bw), lambda bi, wi, ci: (bi, ci, wi)),
        ],
        out_specs=pl.BlockSpec((1, Q, bw), lambda bi, wi, ci: (bi, ci, wi)),
        out_shape=jax.ShapeDtypeStruct((B, L, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(a, b)
