from .ops import rglru_scan
from .ref import reference_rglru

__all__ = ["rglru_scan", "reference_rglru"]
