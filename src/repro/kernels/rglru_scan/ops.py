"""jit'd wrapper for the RG-LRU scan kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import rglru_scan_kernel


@functools.partial(jax.jit, static_argnames=("block_q", "block_w", "interpret"))
def rglru_scan(a: jnp.ndarray, b: jnp.ndarray, *,
               block_q: int = 128, block_w: int = 256,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """a, b: (B, L, W) -> h (B, L, W); pads L and W to block multiples.

    Padding uses a=1, b=0 (identity recurrence) so results are unaffected.
    """
    B, L, W = a.shape
    pad_l = (-L) % block_q
    pad_w = (-W) % block_w
    if pad_l:
        a = jnp.pad(a, ((0, 0), (0, pad_l), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_l), (0, 0)))
    if pad_w:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad_w)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad_w)))
    y = rglru_scan_kernel(a, b, block_q=block_q, block_w=block_w,
                          interpret=interpret)
    return y[:, :L, :W]
