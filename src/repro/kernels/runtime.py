"""Shared kernel runtime knobs.

Pallas interpret mode resolution: the kernels default to whatever the
platform needs — compiled Mosaic on TPU, interpret (pure-JAX lowering) on
CPU/GPU — instead of a hardcoded ``interpret=True`` that would silently run
a TPU job through the interpreter.  ``REPRO_PALLAS_INTERPRET=0/1`` overrides
either way (e.g. forcing interpret on TPU to bisect a Mosaic miscompile, or
asserting compiled lowering in a unit test).
"""

from __future__ import annotations

import os
from typing import Optional

import jax

_ENV = "REPRO_PALLAS_INTERPRET"
_TRUE = {"1", "true", "yes", "on"}
_FALSE = {"0", "false", "no", "off"}


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve a kernel's ``interpret`` default.

    Explicit ``True``/``False`` wins; then the ``REPRO_PALLAS_INTERPRET``
    env var; then the platform — interpret everywhere except a real TPU
    backend, where the compiled Mosaic kernel is the point.
    """
    if interpret is not None:
        return interpret
    env = os.environ.get(_ENV, "").strip().lower()
    if env in _TRUE:
        return True
    if env in _FALSE:
        return False
    return jax.default_backend() != "tpu"
