from .ops import paged_attention
from .ref import reference_paged_attention

__all__ = ["paged_attention", "reference_paged_attention"]
