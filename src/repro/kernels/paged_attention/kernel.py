"""Paged-attention decode (S=1) Pallas TPU kernel.

The table-indirect analogue of ``kernels/flash_attention``: instead of
gathering a slot's pages into a contiguous (B, T, Hkv, D) tensor in HBM and
running dense attention over it, the kernel streams K/V **pages** straight
out of the shared ``(n_pages, page_size, Hkv, D)`` pool.  The per-slot page
table rides in as a *scalar-prefetch* operand
(:class:`~jax.experimental.pallas.tpu.PrefetchScalarGridSpec`), so the
k/v ``index_map`` can resolve logical kv block ``j`` of slot ``b`` to its
physical page ``page_table[b, j]`` before the grid step runs — the DMA
engine fetches pages by table lookup and the gathered cache never exists in
HBM.

Tiling: grid ``(B, Hkv, max_pages)`` with the kv-page index innermost
(sequential on TPU), one page per kv block.  The online-softmax running
max / normalizer / accumulator live in VMEM scratch across the page sweep,
exactly as in the flash kernel; the S=1 query block is the ``(G, D)`` head
group of one kv head, so GQA costs one grid axis instead of a materialized
``jnp.repeat``.

Masking: lane ``t`` of page ``j`` is attendable iff its page is mapped
(``page_table[b, j] >= 0``), ``t < lengths[b]`` (the slot's live length
bounds the scan), and — for sliding-window archs — ``t > q_pos[b] -
window``.  Unmapped blocks clamp their index_map to page 0 (a benign fetch,
fully masked in compute; on TPU revisiting an already-resident block index
skips the re-fetch).  Masked lanes are zeroed in ``p`` *after* the running
max update, so a fully-masked page contributes nothing even while the
running max is still ``NEG_INF`` — the flash kernel can lean on causal
ordering to dodge that corner; a scrambled page table cannot.

The kernel returns the **unnormalized** accumulator plus the running
``(m, l)`` softmax state instead of the normalized output: ops.py folds the
just-projected decode token in as a rank-1 fp32 update (the paged analogue
of ``layers.sdpa_append``), which needs ``m``/``l`` to splice one more
logit into the streamed softmax.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..runtime import resolve_interpret

NEG_INF = -1e30


def _paged_attn_kernel(len_ref, qpos_ref, pt_ref, base_ref, q_ref, k_ref,
                       v_ref, acc_out, m_out, l_out, acc_ref, m_ref, l_ref, *,
                       pos_stride: int, n_blocks: int, scale: float,
                       window: Optional[int]):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale              # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                # (ps, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)                # (ps, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, ps)

    t_pos = (j * pos_stride + base_ref[0]
             + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))
    mask = (t_pos < len_ref[b]) & (pt_ref[b, j] >= 0)
    if window is not None:
        mask &= t_pos > qpos_ref[b] - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    # zero masked lanes explicitly: while every page so far is masked the
    # running max is still NEG_INF and exp(s - m) == 1 there, which would
    # leak phantom weight into l/acc (scrambled tables hit this; the causal
    # flash sweep never does)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finish():
        acc_out[0, 0] = acc_ref[...]
        m_out[0, 0] = m_ref[...]
        l_out[0, 0] = l_ref[...]


def paged_attention_kernel(q: jnp.ndarray, kp: jnp.ndarray, vp: jnp.ndarray,
                           page_table: jnp.ndarray, lengths: jnp.ndarray,
                           q_pos: jnp.ndarray, *,
                           lane_base: Optional[jnp.ndarray] = None,
                           pos_stride: Optional[int] = None,
                           window: Optional[int] = None,
                           interpret: Optional[bool] = None
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """q: (B, Hkv, G, D); kp/vp: (n_pages, page_size, Hkv, D);
    page_table: (B, max_pages) int32, -1 = unmapped; lengths/q_pos: (B,).

    Returns ``(acc, m, l)`` — acc ``(B, Hkv, G, D)`` fp32 unnormalized
    accumulator, m/l ``(B, Hkv, G)`` running max / normalizer.  Rows with no
    attendable lane come out as ``(0, NEG_INF, 0)``; ops.py owns both the
    normalization and the new-token append.

    ``lane_base``/``pos_stride`` exist for the shard_map lane decomposition
    (ops.py): a pool lane-sharded on ``model`` hands each shard a
    ``(n_pages, ps_local, Hkv, D)`` slice holding contiguous lanes
    ``[lane_base, lane_base + ps_local)`` of every *global* page of size
    ``pos_stride``, so lane ``t`` of block ``j`` sits at global position
    ``j * pos_stride + lane_base + t``.  ``lane_base`` is a traced ``(1,)``
    int32 (a fourth scalar-prefetch operand — it depends on
    ``axis_index``); ``pos_stride`` is static.  The defaults (0, local page
    size) reproduce the unsharded positions bitwise.
    """
    B, Hkv, G, D = q.shape
    page_size = kp.shape[1]
    max_pages = page_table.shape[1]
    grid = (B, Hkv, max_pages)
    if pos_stride is None:
        pos_stride = page_size
    if lane_base is None:
        lane_base = jnp.zeros((1,), jnp.int32)

    kernel = functools.partial(
        _paged_attn_kernel, pos_stride=pos_stride, n_blocks=max_pages,
        scale=1.0 / math.sqrt(D), window=window)

    def q_map(b, h, j, lens, qp, pt, base):
        return (b, h, 0, 0)

    def kv_map(b, h, j, lens, qp, pt, base):
        # unmapped blocks clamp to page 0: a benign (masked) fetch, and on
        # TPU a revisited block index skips the DMA entirely
        return (jnp.maximum(pt[b, j], 0), 0, h, 0)

    def o_map(b, h, j, lens, qp, pt, base):
        return (b, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), q_map),
            pl.BlockSpec((1, page_size, 1, D), kv_map),
            pl.BlockSpec((1, page_size, 1, D), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, D), q_map),
            pl.BlockSpec((1, 1, G), o_map),
            pl.BlockSpec((1, 1, G), o_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),   # acc
            pltpu.VMEM((G,), jnp.float32),     # running max
            pltpu.VMEM((G,), jnp.float32),     # running normalizer
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(jnp.asarray(lengths, jnp.int32), jnp.asarray(q_pos, jnp.int32),
      jnp.asarray(page_table, jnp.int32), jnp.asarray(lane_base, jnp.int32),
      q, kp, vp)
