"""Pure-jnp oracle: the HBM gather path the kernel replaces.

Gathers the slot's pages in logical order (clipped indices for unmapped
rows, masked invalid — byte-for-byte the ``kvcache._paged_kv_view``
construction) and runs dense fp32 softmax attention, optionally with the
appended new token.  This *is* the reference the tentpole gates
lane-exactness against: the kernel must match it on every mapped lane.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def reference_paged_attention(q: jnp.ndarray, kp: jnp.ndarray,
                              vp: jnp.ndarray, page_table: jnp.ndarray,
                              lengths: jnp.ndarray, *,
                              q_pos: Optional[jnp.ndarray] = None,
                              k_new: Optional[jnp.ndarray] = None,
                              v_new: Optional[jnp.ndarray] = None,
                              window: Optional[int] = None) -> jnp.ndarray:
    """Same signature/semantics as :func:`..ops.paged_attention`."""
    B, S, H, D = q.shape
    assert S == 1
    n_pages, page_size, Hkv, _ = kp.shape
    G = H // Hkv
    max_pages = page_table.shape[1]
    T = max_pages * page_size
    lengths = jnp.asarray(lengths, jnp.int32)
    if lengths.ndim == 0:
        lengths = jnp.broadcast_to(lengths, (B,))
    q_pos = lengths if q_pos is None else jnp.asarray(q_pos, jnp.int32)
    if q_pos.ndim == 0:
        q_pos = jnp.broadcast_to(q_pos, (B,))

    pid = jnp.clip(page_table, 0, n_pages - 1)
    k = kp[pid].reshape(B, T, Hkv, D).astype(jnp.float32)
    v = vp[pid].reshape(B, T, Hkv, D).astype(jnp.float32)
    kv_pos = jnp.arange(T, dtype=jnp.int32)[None]                     # (1, T)
    valid = jnp.repeat(page_table >= 0, page_size, axis=-1)
    valid &= kv_pos < lengths[:, None]
    if window is not None:
        valid &= kv_pos > (q_pos - window)[:, None]

    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k) / math.sqrt(D)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    if k_new is not None:
        kn = k_new.astype(kp.dtype).reshape(B, Hkv, D).astype(jnp.float32)
        vn = v_new.astype(vp.dtype).reshape(B, Hkv, D).astype(jnp.float32)
        s_new = jnp.einsum("bhgd,bhd->bhg", qg, kn) / math.sqrt(D)
        s = jnp.concatenate([s, s_new[..., None]], axis=-1)
        v = jnp.concatenate([v, vn[:, None]], axis=1)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p, v)
    return out.reshape(B, 1, H, D).astype(q.dtype)
