"""jit'd wrapper: model layout (B,1,H,D) + pool layout -> kernel + append.

Two call modes, matching how the decode paths use the gathered view today:

* **append** (``k_new``/``v_new`` given): attention over the *pre-update*
  pool plus an explicit rank-1 term for the just-projected token — the
  paged-kernel analogue of :func:`repro.models.layers.sdpa_append`.  The
  kernel streams the pool pages; the one extra logit is spliced into the
  streamed softmax here in fp32 via the kernel's ``(m, l)`` state.
* **post-update** (no ``k_new``): the token was already written into the
  pool (hybrid local-attention layers do this); the kernel's accumulator is
  simply normalized.  ``lengths`` then counts the new token too.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .kernel import paged_attention_kernel


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention(q: jnp.ndarray, kp: jnp.ndarray, vp: jnp.ndarray,
                    page_table: jnp.ndarray, lengths: jnp.ndarray, *,
                    q_pos: Optional[jnp.ndarray] = None,
                    k_new: Optional[jnp.ndarray] = None,
                    v_new: Optional[jnp.ndarray] = None,
                    window: Optional[int] = None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: (B, 1, H, D); kp/vp: (n_pages, page_size, Hkv, D);
    page_table: (B, max_pages); lengths: (B,) attendable pool tokens.

    ``q_pos`` (B,) is the query's absolute position (defaults to
    ``lengths`` — the append case, where the query sits one past the live
    prefix); ``k_new``/``v_new`` (B, 1, Hkv, D) enable append mode.
    Returns (B, 1, H, D) in q.dtype.
    """
    B, S, H, D = q.shape
    assert S == 1, "paged_attention is a decode (S=1) kernel"
    Hkv = kp.shape[2]
    G = H // Hkv
    lengths = jnp.asarray(lengths, jnp.int32)
    if lengths.ndim == 0:
        lengths = jnp.broadcast_to(lengths, (B,))
    q_pos = lengths if q_pos is None else jnp.asarray(q_pos, jnp.int32)
    if q_pos.ndim == 0:
        q_pos = jnp.broadcast_to(q_pos, (B,))

    qg = q.reshape(B, Hkv, G, D)
    acc, m, l = paged_attention_kernel(qg, kp, vp, page_table, lengths,
                                       q_pos, window=window,
                                       interpret=interpret)
    if k_new is not None:
        # splice the new token's logit into the streamed softmax (fp32);
        # round k/v through the pool dtype first so the result is consistent
        # with the write-then-gather formulation
        kn = k_new.astype(kp.dtype).reshape(B, 1, Hkv, D)[:, 0]     # (B,Hkv,D)
        vn = v_new.astype(vp.dtype).reshape(B, 1, Hkv, D)[:, 0]
        s_new = jnp.einsum("bhgd,bhd->bhg", qg.astype(jnp.float32),
                           kn.astype(jnp.float32)) / math.sqrt(D)
        m_tot = jnp.maximum(m, s_new)
        alpha = jnp.exp(m - m_tot)
        beta = jnp.exp(s_new - m_tot)
        acc = acc * alpha[..., None] + beta[..., None] * vn[:, :, None, :].astype(jnp.float32)
        l = l * alpha + beta
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, H, D).astype(q.dtype)


def _splice_new(qg, acc, m, l, k_new, v_new, pool_dtype, D):
    """Fold the just-projected token into the streamed softmax state (fp32),
    identical math to the append branch of :func:`paged_attention`."""
    B, Hkv = qg.shape[0], qg.shape[1]
    kn = k_new.astype(pool_dtype).reshape(B, 1, Hkv, D)[:, 0]
    vn = v_new.astype(pool_dtype).reshape(B, 1, Hkv, D)[:, 0]
    s_new = jnp.einsum("bhgd,bhd->bhg", qg.astype(jnp.float32),
                       kn.astype(jnp.float32)) / math.sqrt(D)
    m_tot = jnp.maximum(m, s_new)
    alpha = jnp.exp(m - m_tot)
    beta = jnp.exp(s_new - m_tot)
    acc = acc * alpha[..., None] + beta[..., None] * vn[:, :, None, :].astype(jnp.float32)
    l = l * alpha + beta
    return acc, l


def sharded_paged_attention(q: jnp.ndarray, kp: jnp.ndarray, vp: jnp.ndarray,
                            page_table: jnp.ndarray, lengths: jnp.ndarray, *,
                            policy,
                            q_pos: Optional[jnp.ndarray] = None,
                            k_new: Optional[jnp.ndarray] = None,
                            v_new: Optional[jnp.ndarray] = None,
                            window: Optional[int] = None,
                            interpret: Optional[bool] = None) -> jnp.ndarray:
    """:func:`paged_attention` decomposed per mesh shard under
    ``jax.shard_map`` so the fused kernel reads only the *local* slice of
    the lane-sharded pool — the GSPMD partitioner cannot see through the
    ``pallas_call``'s table-indirect ``index_map``, so left to itself it
    all-gathers the whole pool every step (the 65–73 GB/device wire numbers
    the cache-sharding rule documents).

    Two decompositions, chosen to match ``cache_shardings``' pool rule so
    the resident pool is never re-laid-out at the boundary:

    * **lane** (``page_size % |model| == 0`` — the pool rule's first
      choice): each shard runs the kernel over its contiguous
      ``ps_local``-lane slice of every page (global positions via
      ``lane_base``/``pos_stride``), producing a partial online-softmax
      state ``(acc, m, l)``; the states merge with the standard fp32
      running-max combine (``pmax``/``psum`` over ``model``) and the
      new-token logit is spliced in *after* the merge, replicated.  Not
      bitwise the single-shard kernel (summation order), same fp32
      contract.
    * **head** (kv heads divide ``model``): each shard owns whole kv-head
      groups of q and the matching pool slice; kernel, splice and
      normalization are fully shard-local — bitwise the unsharded kernel.

    Anything else falls back to the plain (replicated-pool) call.  The slot
    batch additionally shards over dp when it divides.  ``policy`` is a
    :class:`repro.dist.sharding.ShardingPolicy` carrying the concrete mesh.
    """
    mesh = policy.mesh
    rules = policy.rules
    mdl = rules.model
    B, S, H, D = q.shape
    Hkv = kp.shape[2]
    G = H // Hkv
    ps = kp.shape[1]
    msize = mesh.shape[mdl] if mdl is not None else 1
    if msize <= 1:
        return paged_attention(q, kp, vp, page_table, lengths, q_pos=q_pos,
                               k_new=k_new, v_new=v_new, window=window,
                               interpret=interpret)
    lengths = jnp.asarray(lengths, jnp.int32)
    if lengths.ndim == 0:
        lengths = jnp.broadcast_to(lengths, (B,))
    q_pos = lengths if q_pos is None else jnp.asarray(q_pos, jnp.int32)
    if q_pos.ndim == 0:
        q_pos = jnp.broadcast_to(q_pos, (B,))

    dp_size = rules.dp_size(mesh)
    dp = (tuple(rules.dp)
          if (policy.batch_shardable and rules.dp and B % dp_size == 0)
          else None)
    has_new = k_new is not None

    if ps % msize == 0:          # lane decomposition (pool rule's 1st pick)
        def lane_body(lengths, q_pos, pt, q, kp_s, vp_s, *new):
            base = (jax.lax.axis_index(mdl) * (ps // msize)
                    ).astype(jnp.int32).reshape(1)
            Bl = q.shape[0]
            qg = q.reshape(Bl, Hkv, G, D)
            acc, m, l = paged_attention_kernel(
                qg, kp_s, vp_s, pt, lengths, q_pos, lane_base=base,
                pos_stride=ps, window=window, interpret=interpret)
            # fp32 running-max merge of the per-shard softmax states; empty
            # shards contribute (0, NEG_INF, 0) and vanish via alpha = 0
            m_tot = jax.lax.pmax(m, mdl)
            alpha = jnp.exp(m - m_tot)
            l = jax.lax.psum(l * alpha, mdl)
            acc = jax.lax.psum(acc * alpha[..., None], mdl)
            if new:
                acc, l = _splice_new(qg, acc, m_tot, l, new[0], new[1],
                                     kp_s.dtype, D)
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            return out.reshape(Bl, 1, H, D).astype(q.dtype)

        body = lane_body
        pool_spec = P(None, mdl, None, None)
        q_spec = P(dp, None, None, None)
        new_spec = P(dp, None, None, None)
        out_spec = P(dp, None, None, None)
    elif Hkv % msize == 0:       # head decomposition: fully shard-local
        def head_body(lengths, q_pos, pt, q, kp_s, vp_s, *new):
            Bl, Hl = q.shape[0], q.shape[2]
            qg = q.reshape(Bl, Hl // G, G, D)
            acc, m, l = paged_attention_kernel(
                qg, kp_s, vp_s, pt, lengths, q_pos, window=window,
                interpret=interpret)
            if new:
                acc, l = _splice_new(qg, acc, m, l, new[0], new[1],
                                     kp_s.dtype, D)
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            return out.reshape(Bl, 1, Hl, D).astype(q.dtype)

        body = head_body
        pool_spec = P(None, None, mdl, None)
        q_spec = P(dp, None, mdl, None)
        new_spec = P(dp, None, mdl, None)
        out_spec = P(dp, None, mdl, None)
    else:
        return paged_attention(q, kp, vp, page_table, lengths, q_pos=q_pos,
                               k_new=k_new, v_new=v_new, window=window,
                               interpret=interpret)

    args = [lengths, q_pos, jnp.asarray(page_table, jnp.int32), q, kp, vp]
    in_specs = [P(dp), P(dp), P(dp, None), q_spec, pool_spec, pool_spec]
    if has_new:
        args += [k_new, v_new]
        in_specs += [new_spec, new_spec]
    return jax.shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                         out_specs=out_spec, check_vma=False)(*args)
