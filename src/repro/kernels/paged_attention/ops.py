"""jit'd wrapper: model layout (B,1,H,D) + pool layout -> kernel + append.

Two call modes, matching how the decode paths use the gathered view today:

* **append** (``k_new``/``v_new`` given): attention over the *pre-update*
  pool plus an explicit rank-1 term for the just-projected token — the
  paged-kernel analogue of :func:`repro.models.layers.sdpa_append`.  The
  kernel streams the pool pages; the one extra logit is spliced into the
  streamed softmax here in fp32 via the kernel's ``(m, l)`` state.
* **post-update** (no ``k_new``): the token was already written into the
  pool (hybrid local-attention layers do this); the kernel's accumulator is
  simply normalized.  ``lengths`` then counts the new token too.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import paged_attention_kernel


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention(q: jnp.ndarray, kp: jnp.ndarray, vp: jnp.ndarray,
                    page_table: jnp.ndarray, lengths: jnp.ndarray, *,
                    q_pos: Optional[jnp.ndarray] = None,
                    k_new: Optional[jnp.ndarray] = None,
                    v_new: Optional[jnp.ndarray] = None,
                    window: Optional[int] = None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: (B, 1, H, D); kp/vp: (n_pages, page_size, Hkv, D);
    page_table: (B, max_pages); lengths: (B,) attendable pool tokens.

    ``q_pos`` (B,) is the query's absolute position (defaults to
    ``lengths`` — the append case, where the query sits one past the live
    prefix); ``k_new``/``v_new`` (B, 1, Hkv, D) enable append mode.
    Returns (B, 1, H, D) in q.dtype.
    """
    B, S, H, D = q.shape
    assert S == 1, "paged_attention is a decode (S=1) kernel"
    Hkv = kp.shape[2]
    G = H // Hkv
    lengths = jnp.asarray(lengths, jnp.int32)
    if lengths.ndim == 0:
        lengths = jnp.broadcast_to(lengths, (B,))
    q_pos = lengths if q_pos is None else jnp.asarray(q_pos, jnp.int32)
    if q_pos.ndim == 0:
        q_pos = jnp.broadcast_to(q_pos, (B,))

    qg = q.reshape(B, Hkv, G, D)
    acc, m, l = paged_attention_kernel(qg, kp, vp, page_table, lengths,
                                       q_pos, window=window,
                                       interpret=interpret)
    if k_new is not None:
        # splice the new token's logit into the streamed softmax (fp32);
        # round k/v through the pool dtype first so the result is consistent
        # with the write-then-gather formulation
        kn = k_new.astype(kp.dtype).reshape(B, 1, Hkv, D)[:, 0]     # (B,Hkv,D)
        vn = v_new.astype(vp.dtype).reshape(B, 1, Hkv, D)[:, 0]
        s_new = jnp.einsum("bhgd,bhd->bhg", qg.astype(jnp.float32),
                           kn.astype(jnp.float32)) / math.sqrt(D)
        m_tot = jnp.maximum(m, s_new)
        alpha = jnp.exp(m - m_tot)
        beta = jnp.exp(s_new - m_tot)
        acc = acc * alpha[..., None] + beta[..., None] * vn[:, :, None, :].astype(jnp.float32)
        l = l * alpha + beta
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, H, D).astype(q.dtype)
