"""jit'd wrapper: model layout (B,S,H,D) + GQA -> kernel layout (BH,S,D)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: (B, S, H, D); k, v: (B, T, Hkv, D) -> (B, S, H, D).

    GQA: repeats each kv head over its query group via the flattened BH dim
    (pure indexing — no materialized repeat on TPU thanks to the BlockSpec
    index_map operating on the flattened axis)."""
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3)                    # (B, Hkv, T, D)
    kf = jnp.repeat(kf, G, axis=1).reshape(B * H, T, D)
    vf = v.transpose(0, 2, 1, 3)
    vf = jnp.repeat(vf, G, axis=1).reshape(B * H, T, D)

    # pad sequence dims to block multiples; padded kv rows are masked inside
    # the kernel via t_real (q padding rows produce garbage, sliced away).
    bq_ = min(bq, S)
    bk_ = min(bk, T)
    pad_s = (-S) % bq_
    pad_t = (-T) % bk_
    if pad_s:
        qf = jnp.pad(qf, ((0, 0), (0, pad_s), (0, 0)))
    if pad_t:
        kf = jnp.pad(kf, ((0, 0), (0, pad_t), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_t), (0, 0)))
    out = flash_attention_kernel(qf, kf, vf, causal=causal, window=window,
                                 bq=bq_, bk=bk_, t_real=T, interpret=interpret)
    out = out[:, :S]
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
