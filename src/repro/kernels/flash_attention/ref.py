"""Pure-jnp oracle for flash attention."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def reference_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """q: (BH, S, D); k, v: (BH, T, D) -> (BH, S, D); fp32 math."""
    S, D = q.shape[1], q.shape[2]
    T = k.shape[1]
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32)).astype(q.dtype)
