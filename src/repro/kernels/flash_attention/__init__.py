from .ops import flash_attention
from .ref import reference_attention

__all__ = ["flash_attention", "reference_attention"]
