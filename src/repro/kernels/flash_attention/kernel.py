"""Flash attention (streaming softmax) Pallas TPU kernel.

Tiling: grid (B*H, S/bq, T/bk), kv-block index innermost (sequential on TPU),
so the running max / normalizer / accumulator live in VMEM scratch across the
kv sweep for one q block.  GQA folds the head-group mapping into the k/v
index_map (h -> h // group).  Causal and sliding-window masking are applied
per-tile with iota offsets; bq/bk default to 128 to keep the MXU matmul dims
hardware-aligned and the tile working set (bq*D + 2*bk*D + bq*bk floats)
well inside the ~16 MB/core VMEM budget.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..runtime import resolve_interpret

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: Optional[int],
                 bq: int, bk: int, n_k_blocks: int, t_real: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, ...].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0, ...].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0, ...].astype(jnp.float32)                  # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    i = pl.program_id(1)
    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    # padded kv rows must be masked explicitly: causality only covers them
    # when T >= S (hypothesis-found: S=10, T=9 leaked zero-key rows)
    mask = k_pos < t_real
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(j == n_k_blocks - 1)
    def _finish():
        # rows with zero valid keys (possible only for q beyond the kv
        # horizon under a window) come out as zeros, by convention
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: Optional[int] = None,
                           bq: int = 128, bk: int = 128,
                           t_real: Optional[int] = None,
                           interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: (BH, S, D); k, v: (BH, T, D) — head-group mapping done by ops.py.

    Returns (BH, S, D).  S % bq == 0 and T % bk == 0 (ops.py pads;
    ``t_real`` is the unpadded kv length so padded rows are masked).
    """
    BH, S, D = q.shape
    T = k.shape[1]
    bq = min(bq, S)
    bk = min(bk, T)
    n_k_blocks = T // bk
    grid = (BH, S // bq, n_k_blocks)

    kernel = functools.partial(
        _attn_kernel, scale=1.0 / math.sqrt(D), causal=causal, window=window,
        bq=bq, bk=bk, n_k_blocks=n_k_blocks,
        t_real=T if t_real is None else t_real)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),     # running max
            pltpu.VMEM((bq,), jnp.float32),     # running normalizer
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v)
