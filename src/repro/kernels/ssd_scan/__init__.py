from .ops import ssd_scan
from .ref import reference_ssd

__all__ = ["ssd_scan", "reference_ssd"]
