"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

Grid (B, H/bh, L/Q); the chunk index is innermost (sequential on TPU), so the
inter-chunk state (bh, P, N) lives in VMEM scratch across the sweep.  Within
a chunk the quadratic "attention form" runs on the MXU: the (Q, Q) decay
kernel, CB^T Gram matrix, and the state outer products are all dense matmuls.
This is the TPU adaptation of the paper's algorithm: chunk size Q and head
block bh trade VMEM footprint (Q^2 + 2 Q N + bh P N floats) against MXU
utilization; Q = 128 aligns every contraction to the systolic array.

vs the pure-XLA path (models/mamba2.py): identical math, but the (Q,Q,H)
decay tensor never round-trips to HBM — it is built and consumed in VMEM,
which removes the memory-bound hot spot the roofline analysis flags.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..runtime import resolve_interpret


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                Q: int, bh: int, n_chunks: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, ...].astype(jnp.float32)          # (Q, bh, P)
    dt = dt_ref[0, ...].astype(jnp.float32)        # (Q, bh)
    A = a_ref[...].astype(jnp.float32)             # (bh,)
    Bm = b_ref[0, ...].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0, ...].astype(jnp.float32)         # (Q, N)

    da = dt * A[None, :]                           # (Q, bh) log-decay
    cums = jnp.cumsum(da, axis=0)                  # inclusive

    # intra-chunk: y[i] += sum_j<=i C_i.B_j * exp(cums_i - cums_j) * dt_j x_j
    seg = cums[:, None, :] - cums[None, :, :]      # (Q, Q, bh)
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.exp(jnp.where(tri[:, :, None], seg, -jnp.inf))  # mask inside exp
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (Q, Q)
    w = cb[:, :, None] * L * dt[None, :, :]        # (Q, Q, bh)
    y = jnp.einsum("ijh,jhp->ihp", w, x)

    # inter-chunk: y[i] += C_i . h_prev * exp(cums_i)
    h_prev = h_ref[...]                            # (bh, P, N)
    y += jnp.einsum("in,ih,hpn->ihp", Cm, jnp.exp(cums), h_prev)

    # state update: h = exp(sum da) * h_prev + sum_j exp(cums_last - cums_j) dt_j B_j x_j
    decay_all = jnp.exp(cums[-1, :])               # (bh,)
    decay_to_end = jnp.exp(cums[-1:, :] - cums)    # (Q, bh)
    new_state = jnp.einsum("jh,jn,jhp->hpn", decay_to_end * dt, Bm, x)
    h_ref[...] = h_prev * decay_all[:, None, None] + new_state

    y_ref[0, ...] = y.astype(y_ref.dtype)


def ssd_scan_kernel(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                    Bm: jnp.ndarray, Cm: jnp.ndarray, *,
                    chunk: int = 128, bh: int = 8,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """x: (B, L, H, P); dt: (B, L, H); A: (H,); Bm/Cm: (B, L, N) -> y like x.

    L % chunk == 0 and H % bh == 0 (ops.py pads/validates).
    """
    B, L, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    bh = min(bh, H)
    n_chunks = L // Q
    grid = (B, H // bh, n_chunks)

    kernel = functools.partial(_ssd_kernel, Q=Q, bh=bh, n_chunks=n_chunks)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # x viewed (B, nc, Q, H, P): block (1, Q, bh, P) at (b, c, hb)
            pl.BlockSpec((1, Q, bh, Pd), lambda b, hb, c: (b, c, hb, 0)),
            pl.BlockSpec((1, Q, bh), lambda b, hb, c: (b, c, hb)),
            pl.BlockSpec((bh,), lambda b, hb, c: (hb,)),
            pl.BlockSpec((1, Q, N), lambda b, hb, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, hb, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, bh, Pd), lambda b, hb, c: (b, c, hb, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L, H, Pd), x.dtype),
        scratch_shapes=[pltpu.VMEM((bh, Pd, N), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(x, dt, A, Bm, Cm)
