"""Pure-jnp oracle: sequential (non-chunked) SSD recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_ssd(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                  Bm: jnp.ndarray, Cm: jnp.ndarray) -> jnp.ndarray:
    """Token-by-token recurrence (the definitional form).

    x: (B, L, H, P); dt: (B, L, H); A: (H,); Bm/Cm: (B, L, N) -> (B, L, H, P).
    """
    B, L, H, Pd = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32

    def step(h, inp):
        xt, dtt, bt, ct = inp          # (B,H,P), (B,H), (B,N), (B,N)
        da = jnp.exp(dtt * A[None, :])
        h = h * da[:, :, None, None] + jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((B, H, Pd, N), f32)
    xs = (jnp.moveaxis(x.astype(f32), 1, 0), jnp.moveaxis(dt.astype(f32), 1, 0),
          jnp.moveaxis(Bm.astype(f32), 1, 0), jnp.moveaxis(Cm.astype(f32), 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
