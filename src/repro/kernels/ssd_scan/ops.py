"""jit'd wrapper for the SSD scan kernel (padding + head blocking)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "bh", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             Bm: jnp.ndarray, Cm: jnp.ndarray, *,
             chunk: int = 128, bh: int = 8,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    """x: (B, L, H, P); dt: (B, L, H); A: (H,); Bm/Cm: (B, L, N).

    Pads L to a chunk multiple (dt=0 on padding => decay 1, zero input) and
    H to a head-block multiple (A=0 rows are inert), then calls the kernel.
    """
    B, L, H, Pd = x.shape
    pad_l = (-L) % chunk
    pad_h = (-H) % bh
    if pad_l:
        x = jnp.pad(x, ((0, 0), (0, pad_l), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_l), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad_l), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad_l), (0, 0)))
    if pad_h:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad_h), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad_h)))
        A = jnp.pad(A, (0, pad_h))
    y = ssd_scan_kernel(x, dt, A, Bm, Cm, chunk=chunk, bh=bh,
                        interpret=interpret)
    return y[:, :L, :H]
