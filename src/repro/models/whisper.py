"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv-mel frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, n_frames, d_model) — what Whisper's two conv
layers would emit.  Positional information is sinusoidal (length-agnostic).
Decoder = causal self-attention + cross-attention to the encoder output.

Decode shapes cache (a) the decoder self-attn ring and (b) the per-layer
cross-attn k/v computed once from the encoder output.
"""

from __future__ import annotations

import operator
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from . import kvcache, layers
from .config import ArchConfig
from .layers import cast, wcast
from .transformer import DenseLM, remat_wrap


def init_enc_layer(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": layers.init_norm(cfg.norm, cfg.d_model),
        "attn": layers.init_attention(ks[0], cfg),
        "mlp_norm": layers.init_norm(cfg.norm, cfg.d_model),
        "mlp": layers.init_mlp(ks[1], cfg),
    }


def init_dec_layer(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 3)
    p = init_enc_layer(ks[0], cfg)
    p["xattn_norm"] = layers.init_norm(cfg.norm, cfg.d_model)
    p["xattn"] = layers.init_attention(ks[1], cfg)
    return p


def _xattn(p: Dict, cfg: ArchConfig, x: jnp.ndarray,
           enc_k: jnp.ndarray, enc_v: jnp.ndarray) -> jnp.ndarray:
    """Cross-attention with precomputed encoder k/v (B, F, Hkv, D)."""
    B, S = x.shape[0], x.shape[1]
    hd = cfg.the_head_dim()
    q = jnp.einsum("bsd,dq->bsq", x, cast(p["wq"])).reshape(B, S, cfg.n_heads, hd)
    o = layers.sdpa(q, enc_k, enc_v, causal=False)
    o = o.reshape(B, S, cfg.n_heads * hd)
    return jnp.einsum("bsq,qd->bsd", o, wcast(p["wo"], "row"))


def _enc_kv(p: Dict, cfg: ArchConfig, enc_out: jnp.ndarray):
    hd = cfg.the_head_dim()
    B, F = enc_out.shape[0], enc_out.shape[1]
    k = jnp.einsum("bfd,dq->bfq", enc_out, cast(p["wk"])).reshape(B, F, cfg.n_kv_heads, hd)
    v = jnp.einsum("bfd,dq->bfq", enc_out, cast(p["wv"])).reshape(B, F, cfg.n_kv_heads, hd)
    return k, v


class EncDecLM(DenseLM):
    def init(self, key) -> Dict:
        cfg = self.cfg
        k_emb, k_enc, k_dec = jax.random.split(key, 3)
        enc_keys = jax.random.split(k_enc, cfg.encdec.n_encoder_layers)
        dec_keys = jax.random.split(k_dec, cfg.n_layers)
        return {
            "embedding": layers.init_embedding(k_emb, cfg),
            "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
            "enc_norm": layers.init_norm(cfg.norm, cfg.d_model),
            "layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
            "final_norm": layers.init_norm(cfg.norm, cfg.d_model),
        }

    # -- encoder ----------------------------------------------------------------

    def encode(self, params: Dict, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        B, F, _ = frames.shape
        x = frames.astype(layers.COMPUTE_DTYPE)
        x = x + layers.sinusoidal_positions(F, cfg.d_model)[None]
        positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))

        def body(h, p):
            a = layers.apply_norm(cfg.norm, p["attn_norm"], h)
            a = layers.attention_block(p["attn"], cfg, a, positions, causal=False)
            h = h + a
            mzn = layers.apply_norm(cfg.norm, p["mlp_norm"], h)
            h = h + layers.apply_mlp(p["mlp"], cfg, mzn)
            return constrain(h, "activation"), None

        fn = remat_wrap(body, cfg.remat)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(fn, x, params["enc_layers"])
        else:
            for i in range(cfg.encdec.n_encoder_layers):
                p = jax.tree_util.tree_map(operator.itemgetter(i), params["enc_layers"])
                x, _ = fn(x, p)
        return layers.apply_norm(cfg.norm, params["enc_norm"], x)

    # -- decoder ----------------------------------------------------------------

    def apply(self, params: Dict, batch: Dict) -> jnp.ndarray:
        cfg = self.cfg
        tokens = batch["tokens"]
        enc_out = self.encode(params, batch["frames"])
        B, S = tokens.shape
        x = layers.embed_tokens(params["embedding"], cfg, tokens)
        x = x + layers.sinusoidal_positions(S, cfg.d_model)[None]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

        def body(h, p):
            a = layers.apply_norm(cfg.norm, p["attn_norm"], h)
            a = layers.attention_block(p["attn"], cfg, a, positions, causal=True)
            h = h + a
            c = layers.apply_norm(cfg.norm, p["xattn_norm"], h)
            ek, ev = _enc_kv(p["xattn"], cfg, enc_out)
            h = h + _xattn(p["xattn"], cfg, c, ek, ev)
            mzn = layers.apply_norm(cfg.norm, p["mlp_norm"], h)
            h = h + layers.apply_mlp(p["mlp"], cfg, mzn)
            return constrain(h, "activation"), None

        fn = remat_wrap(body, cfg.remat)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(fn, x, params["layers"])
        else:
            for i in range(cfg.n_layers):
                p = jax.tree_util.tree_map(operator.itemgetter(i), params["layers"])
                x, _ = fn(x, p)
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        return constrain(layers.lm_head(params["embedding"], cfg, x), "logits")

    # -- decode -------------------------------------------------------------------

    def init_cache(self, B: int, seq_len: int, n_frames: Optional[int] = None) -> Dict:
        cfg = self.cfg
        F = n_frames if n_frames is not None else cfg.encdec.n_frames
        hd = cfg.the_head_dim()
        cache = kvcache.init_attn_cache(cfg.n_layers, B, seq_len, cfg.n_kv_heads, hd)
        cache["xk"] = jnp.zeros((cfg.n_layers, B, F, cfg.n_kv_heads, hd), layers.COMPUTE_DTYPE)
        cache["xv"] = jnp.zeros((cfg.n_layers, B, F, cfg.n_kv_heads, hd), layers.COMPUTE_DTYPE)
        return cache

    def prefill(self, params: Dict, tokens: jnp.ndarray,
                frames: Optional[jnp.ndarray] = None, *,
                seq_len: Optional[int] = None) -> Tuple[jnp.ndarray, Dict]:
        """``seq_len`` sizes the decoder's self-attention ring for the total
        sequence (prompt + decode budget); the prompt-sized default wraps —
        and evicts prompt keys — once decode runs past it."""
        cfg = self.cfg
        B, S = tokens.shape
        if frames is None:
            frames = jnp.zeros((B, cfg.encdec.n_frames, cfg.d_model), layers.COMPUTE_DTYPE)
        enc_out = self.encode(params, frames)

        def kv_layer(p):
            return _enc_kv(p["xattn"], cfg, enc_out)

        xk, xv = jax.vmap(kv_layer)(params["layers"]) if cfg.scan_layers else _stack_kv(
            params["layers"], cfg, enc_out)
        cache = self.init_cache(B, seq_len or S, n_frames=frames.shape[1])
        cache["xk"], cache["xv"] = xk, xv
        return self._decode_with_cross(params, cache, tokens)

    def decode_step(self, params: Dict, cache: Dict, tokens: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, Dict]:
        return self._decode_with_cross(params, cache, tokens)

    def _decode_with_cross(self, params, cache, tokens):
        cfg = self.cfg
        B, S = tokens.shape
        x = layers.embed_tokens(params["embedding"], cfg, tokens)
        pos = cache["length"]
        x = x + layers.sinusoidal_positions(S, cfg.d_model, offset=pos)[None]
        positions = jnp.broadcast_to(pos + jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))

        def body(h, layer_in):
            p, lc = layer_in
            a = layers.apply_norm(cfg.norm, p["attn_norm"], h)
            q, k, v = layers.qkv_project(p["attn"], cfg, a, positions)
            new_self = kvcache.cache_update_layer(
                {"k": lc["k"], "v": lc["v"], "positions": lc["positions"]}, k, v, pos)
            if S > lc["k"].shape[1]:
                o = layers.sdpa(q, k, v, causal=True,
                                q_positions=positions, kv_positions=positions)
            else:
                ck, cv, kv_pos, kv_valid = kvcache.cache_kv_view(new_self)
                o = layers.sdpa(q, ck, cv, causal=True, q_positions=positions,
                                kv_positions=kv_pos, kv_valid=kv_valid)
            o = o.reshape(B, S, cfg.n_heads * cfg.the_head_dim())
            h = h + jnp.einsum("bsq,qd->bsd", o, layers.wcast(p["attn"]["wo"], "row"))
            c = layers.apply_norm(cfg.norm, p["xattn_norm"], h)
            h = h + _xattn(p["xattn"], cfg, c, lc["xk"], lc["xv"])
            mzn = layers.apply_norm(cfg.norm, p["mlp_norm"], h)
            h = h + layers.apply_mlp(p["mlp"], cfg, mzn)
            new_self["xk"], new_self["xv"] = lc["xk"], lc["xv"]
            return h, new_self

        layer_caches = {k: cache[k] for k in ("k", "v", "positions", "xk", "xv")}
        if cfg.scan_layers:
            x, new_caches = jax.lax.scan(body, x, (params["layers"], layer_caches))
        else:
            outs = []
            for i in range(cfg.n_layers):
                p = jax.tree_util.tree_map(operator.itemgetter(i), params["layers"])
                lc = jax.tree_util.tree_map(operator.itemgetter(i), layer_caches)
                x, nc = body(x, (p, lc))
                outs.append(nc)
            new_caches = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = layers.lm_head(params["embedding"], cfg, x)
        new_cache = dict(new_caches)
        new_cache["length"] = cache["length"] + S
        return constrain(logits, "logits"), new_cache


def _stack_kv(layers_params, cfg, enc_out):
    ks, vs = [], []
    n = jax.tree_util.tree_leaves(layers_params)[0].shape[0]
    for i in range(n):
        p = jax.tree_util.tree_map(operator.itemgetter(i), layers_params)
        k, v = _enc_kv(p["xattn"], cfg, enc_out)
        ks.append(k)
        vs.append(v)
    return jnp.stack(ks), jnp.stack(vs)
