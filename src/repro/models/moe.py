"""Token-choice MoE transformer (moonshot-v1-16b-a3b, qwen3-moe-235b-a22b).

Dispatch strategy (TPU adaptation, DESIGN.md §5): activations are replicated
across the ``model`` mesh axis, so each model-rank owns ``E / |model|``
experts and *locally* gathers the tokens routed to them — no all-to-all is
needed; the combine is a single ``psum`` over ``model``, the same collective
a dense TP MLP pays.  Capacity-based dropping (factor ``capacity_factor``)
keeps every shape static.  FLOPs are the *active*-expert FLOPs (each rank
computes E_local experts x capacity tokens), so the roofline's
MODEL_FLOPS/HLO_FLOPs ratio stays honest — no dense-all-experts fakery.

On a single device (smoke tests) the identical dispatch math runs without
shard_map.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist import sharding as shd
from . import layers
from .config import ArchConfig
from .layers import cast
from .transformer import DenseLM


# ---------------------------------------------------------------------------
# Expert dispatch core (runs per data-shard; E_local experts per model-rank)
# ---------------------------------------------------------------------------


def _rank_within_expert(e_flat: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Position of each routing pair within its expert's arrival order.

    Sort-based: O(TK log TK) time, O(TK) memory — the one-hot-cumsum
    formulation costs O(TK * E) memory ((TK, E) int32 tensors measured as a
    dominant §Perf memory term for the 128-expert arch)."""
    TK = e_flat.shape[0]
    order = jnp.argsort(e_flat, stable=True)                  # (TK,)
    e_sorted = e_flat[order]
    # index of the first occurrence of each pair's expert in sorted order
    first = jnp.searchsorted(e_sorted, jnp.arange(n_experts, dtype=e_flat.dtype),
                             side="left")                     # (E,)
    rank_sorted = jnp.arange(TK, dtype=jnp.int32) - first[e_sorted]
    return jnp.zeros((TK,), jnp.int32).at[order].set(rank_sorted)


def _dispatch_ffn(xf: jnp.ndarray, w_flat: jnp.ndarray, e_flat: jnp.ndarray,
                  experts: Dict, mlp: str, e_lo, E_local: int,
                  n_experts: int, capacity: int) -> jnp.ndarray:
    """xf: (T, D) tokens; (w|e)_flat: (T*k,) routing pairs; experts: stacked
    weights for the E_local experts starting at ``e_lo`` (``e_lo`` may be a
    traced axis_index value; ``E_local`` must be static).  Returns this
    rank's partial (T, D)."""
    T, D = xf.shape
    TK = e_flat.shape[0]
    k = TK // T
    e_hi = e_lo + E_local

    # rank of each pair within its expert (capacity-based dropping)
    rank = _rank_within_expert(e_flat, n_experts)                     # (TK,)
    local = (e_flat >= e_lo) & (e_flat < e_hi) & (rank < capacity)
    slot = jnp.where(local, (e_flat - e_lo) * capacity + rank, E_local * capacity)

    # single gather->scatter dispatch.  (A per-slot k-loop variant was tried
    # in §Perf cell-2 iteration 3 and REFUTED: each of the k scatters
    # rewrites the whole (E_local*cap, D) buffer, +10% bytes accessed.)
    tok_idx = jnp.arange(TK, dtype=jnp.int32) // k
    buf = jnp.zeros((E_local * capacity + 1, D), xf.dtype)
    buf = buf.at[slot].add(xf[tok_idx])
    buf = buf[: E_local * capacity].reshape(E_local, capacity, D)

    if mlp == "swiglu":
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, cast(experts["w_gate"])))
        u = jnp.einsum("ecd,edf->ecf", buf, cast(experts["w_up"]))
        y = jnp.einsum("ecf,efd->ecd", g * u, cast(experts["w_down"]))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, cast(experts["w_up"])))
        y = jnp.einsum("ecf,efd->ecd", h, cast(experts["w_down"]))

    # combine: gather + weighted sum over the k slots.  (Per-slot combine
    # loop also REFUTED in §Perf cell-2: +9% bytes accessed.)  Weights cast
    # to compute dtype — an f32 multiply here would promote the whole (TK, D)
    # buffer to f32.
    y_flat = y.reshape(E_local * capacity, D)
    picked = y_flat[jnp.minimum(slot, E_local * capacity - 1)]        # (TK, D)
    picked = picked * (local & (slot < E_local * capacity))[:, None]
    picked = picked * w_flat[:, None].astype(xf.dtype)
    return picked.reshape(T, k, D).sum(axis=1)


# Below this many tokens, the shard_map EP path switches to the stationary-
# weights formulation: gathering every expert's weights to process a handful
# of decode tokens dominated the decode collective term (§Perf cell 3).
DECODE_TOKEN_THRESHOLD = 2048


def _moe_decode_stationary(xf, w_flat, e_flat, p, cfg, mesh, rules, cap):
    """Decode-time MoE: weights stay in their (EP x FSDP) storage sharding;
    the (tiny) token set is replicated across dp and only (E_loc, C, *)
    partials cross the wire.  Expert weight bytes moved: zero."""
    m_cfg = cfg.moe
    e_per = m_cfg.n_experts // mesh.shape[rules.model]

    def body(xf_l, w_l, e_l, wg, wu, wd):
        e_lo = jax.lax.axis_index(rules.model) * e_per
        T, D_full = xf_l.shape
        TK = e_l.shape[0]
        k = TK // T
        rank = _rank_within_expert(e_l, m_cfg.n_experts)
        local = (e_l >= e_lo) & (e_l < e_lo + e_per) & (rank < cap)
        slot = jnp.where(local, (e_l - e_lo) * cap + rank, e_per * cap)
        tok_idx = jnp.arange(TK, dtype=jnp.int32) // k
        buf = jnp.zeros((e_per * cap + 1, D_full), xf_l.dtype)
        buf = buf.at[slot].add(xf_l[tok_idx])[: e_per * cap]
        buf = buf.reshape(e_per, cap, D_full)
        # wg/wu blocks: (e_per, D/|dp|, F) -> contract the local D slice,
        # psum the (e_per, cap, F) partial over dp (tiny at decode sizes)
        d_idx = jnp.zeros((), jnp.int32)
        for a in rules.dp:
            d_idx = d_idx * mesh.shape[a] + jax.lax.axis_index(a)
        d_lo = d_idx * wg.shape[1]
        buf_d = jax.lax.dynamic_slice_in_dim(buf, d_lo, wg.shape[1], axis=2)
        g = jax.lax.psum(jnp.einsum("ecd,edf->ecf", buf_d, wg.astype(xf_l.dtype)),
                         rules.dp)
        u = jax.lax.psum(jnp.einsum("ecd,edf->ecf", buf_d, wu.astype(xf_l.dtype)),
                         rules.dp)
        h = jax.nn.silu(g) * u if cfg.mlp == "swiglu" else jax.nn.gelu(g)
        # wd block: (e_per, F, D/|dp|) -> local D slice, all-gather D (tiny)
        y_part = jnp.einsum("ecf,efd->ecd", h, wd.astype(xf_l.dtype))
        y = jax.lax.all_gather(y_part, rules.dp, axis=2, tiled=True)
        y_flat = y.reshape(e_per * cap, D_full)
        picked = y_flat[jnp.minimum(slot, e_per * cap - 1)]
        picked = picked * (local & (slot < e_per * cap))[:, None]
        picked = picked * w_l[:, None].astype(xf_l.dtype)
        return jax.lax.psum(picked.reshape(T, k, D_full).sum(1), rules.model)

    P_ = P
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P_(), P_(), P_(),
                  P_(rules.model, rules.dp, None),   # w_gate storage sharding
                  P_(rules.model, rules.dp, None),   # w_up
                  P_(rules.model, None, rules.dp)),  # w_down
        out_specs=P_(),
        check_vma=False,
    )(xf, w_flat, e_flat, p["experts"]["w_gate"], p["experts"]["w_up"],
      p["experts"]["w_down"])


def moe_ffn(p: Dict, cfg: ArchConfig, x: jnp.ndarray, *, no_drop: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,D), aux load-balance loss scalar).

    ``no_drop=True`` (the decode/serving path) sizes capacity to the T*k
    worst case so routing never drops a token: capacity dropping is a
    training-throughput trade, and at serve time it would make outputs
    depend on what else shares the batch — chunked prefill must produce the
    same tokens as a monolithic prefill regardless of chunk boundaries.
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"]["w"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)                           # (T, E)
    weights, idx = jax.lax.top_k(gates, m.top_k)                      # (T, k)
    weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)

    # switch-style load-balance aux: E * sum_e f_e * P_e
    pe = gates.mean(axis=0)
    fe = jax.nn.one_hot(idx, m.n_experts).sum(axis=(0, 1)) / (T * m.top_k)
    aux = m.n_experts * jnp.sum(fe * pe)

    e_flat = idx.reshape(-1).astype(jnp.int32)
    w_flat = weights.reshape(-1)

    policy = shd.current_policy()
    if policy is None:
        out = _dispatch_ffn(
            xf, w_flat, e_flat, p["experts"], cfg.mlp, 0, m.n_experts,
            m.n_experts, _capacity(T, m, no_drop=no_drop),
        )
    else:
        mesh = policy.mesh
        rules = shd.MeshRules.for_mesh(mesh)
        dp_size = int(math.prod(mesh.shape[a] for a in rules.dp))
        model_size = mesh.shape[rules.model]
        if (T <= DECODE_TOKEN_THRESHOLD and cfg.mlp == "swiglu"
                and m.n_experts % model_size == 0 and D % dp_size == 0):
            # decode: weights stay put; only tiny partials cross the wire
            out = _moe_decode_stationary(xf, w_flat, e_flat, p, cfg, mesh,
                                         rules, _capacity(T, m, no_drop=no_drop))
        elif T % dp_size != 0 or m.n_experts % model_size != 0:
            out = _dispatch_ffn(xf, w_flat, e_flat, p["experts"], cfg.mlp,
                                0, m.n_experts, m.n_experts,
                                _capacity(T, m, no_drop=no_drop))
        else:
            cap = _capacity(T // dp_size, m, no_drop=no_drop)
            e_per = m.n_experts // model_size  # static experts-per-rank

            def body(xf_l, w_l, e_l, experts_l):
                e_lo = jax.lax.axis_index(rules.model) * e_per  # traced offset
                partial = _dispatch_ffn(xf_l, w_l, e_l, experts_l, cfg.mlp,
                                        e_lo, e_per, m.n_experts, cap)
                return jax.lax.psum(partial, rules.model)

            # tokens split over dp; experts split over model; inside the body
            # each (dp, model) cell sees its token block and its expert block.
            out = jax.shard_map(
                body, mesh=mesh,
                in_specs=(P(rules.dp, None), P(rules.dp), P(rules.dp),
                          P(rules.model, None, None)),
                out_specs=P(rules.dp, None),
                check_vma=False,
            )(xf, w_flat, e_flat, p["experts"])

    if "shared" in p:
        out = out + layers.apply_mlp(p["shared"], cfg, xf[None])[0]
    return out.reshape(B, S, D), aux.astype(jnp.float32)


def _capacity(tokens: int, m, *, no_drop: bool = False) -> int:
    if no_drop:      # serving: cover the all-to-one-expert worst case
        return tokens * m.top_k
    return max(4, int(math.ceil(tokens * m.top_k / m.n_experts * m.capacity_factor)))


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def init_moe_layer(key, cfg: ArchConfig) -> Dict:
    m = cfg.moe
    ks = jax.random.split(key, 6)
    ek = jax.random.split(ks[1], m.n_experts)

    def one_expert(k):
        kk = jax.random.split(k, 3)
        e = {
            "w_gate": layers.dense_init(kk[0], cfg.d_model, m.d_expert),
            "w_up": layers.dense_init(kk[1], cfg.d_model, m.d_expert),
            "w_down": layers.dense_init(kk[2], m.d_expert, cfg.d_model),
        }
        if cfg.mlp != "swiglu":
            del e["w_gate"]
        return e

    p = {
        "attn_norm": layers.init_norm(cfg.norm, cfg.d_model),
        "attn": layers.init_attention(ks[0], cfg),
        "mlp_norm": layers.init_norm(cfg.norm, cfg.d_model),
        "moe": {
            "router": {"w": layers.dense_init(ks[2], cfg.d_model, m.n_experts)},
            "experts": jax.vmap(one_expert)(ek),
        },
    }
    if cfg.d_ff > 0 and cfg.name.startswith("moonshot"):
        # moonlight/deepseek-style shared expert alongside routed experts
        p["moe"]["shared"] = layers.init_mlp(ks[3], cfg, d_ff=2 * m.d_expert)
    return p


class MoELM(DenseLM):
    def __init__(self, cfg: ArchConfig):
        super().__init__(cfg)
        self._aux_weight = cfg.moe.router_aux_weight

    def _init_layer(self, key):
        return init_moe_layer(key, self.cfg)

    def _layer_fwd_aux(self, p, x, positions, aux):
        cfg = self.cfg
        rs = jnp.asarray(cfg.residual_scale, x.dtype)
        h = layers.apply_norm(cfg.norm, p["attn_norm"], x)
        h = layers.attention_block(p["attn"], cfg, h, positions,
                                   window=cfg.sliding_window)
        x = x + h * rs
        x = shd.constrain(x, "activation")
        h = layers.apply_norm(cfg.norm, p["mlp_norm"], x)
        h, layer_aux = moe_ffn(p["moe"], cfg, h)
        x = x + h * rs
        return shd.constrain(x, "activation"), (aux + layer_aux if aux is not None else layer_aux)

    def _layer_fwd(self, p, x, positions):
        y, _ = self._layer_fwd_aux(p, x, positions, jnp.zeros((), jnp.float32))
        return y

    def apply(self, params, batch):
        logits, _ = self.loss_aux(params, batch)
        return logits

    def loss_aux(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = layers.embed_tokens(params["embedding"], cfg, tokens)
        x = shd.constrain(x, "activation")
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, aux = self._run_stack(params["layers"], x, positions,
                                 aux_init=jnp.zeros((), jnp.float32))
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = layers.lm_head(params["embedding"], cfg, x)
        return shd.constrain(logits, "logits"), aux * self._aux_weight

    def _layer_decode(self, p, x, layer_cache, pos):
        from . import kvcache
        cfg = self.cfg
        rs = jnp.asarray(cfg.residual_scale, x.dtype)
        B, S = x.shape[0], x.shape[1]
        positions = kvcache.decode_positions(pos, B, S)
        h = layers.apply_norm(cfg.norm, p["attn_norm"], x)
        q, k, v = layers.qkv_project(p["attn"], cfg, h, positions)
        new_cache = kvcache.cache_update_layer(layer_cache, k, v, pos)
        if (S == 1 and cfg.attn_backend == "paged_kernel"
                and kvcache.is_paged(layer_cache)):
            # fused table-indirect kernel: pre-update pool + fp32 append
            o = kvcache.paged_attn_decode(layer_cache, q, pos,
                                          window=cfg.sliding_window,
                                          k_new=k, v_new=v)
        else:
            # S=1 rides the chunk path (post-update view) so decode-written
            # KV is bitwise prefill KV — see dense_layer_decode.
            ck, cv, kv_pos, kv_valid = kvcache.cache_kv_view(new_cache, upto=pos + S)
            o = layers.sdpa(q, ck, cv, causal=True, window=cfg.sliding_window,
                            q_positions=positions, kv_positions=kv_pos,
                            kv_valid=kv_valid)
        o = o.reshape(B, S, cfg.n_heads * cfg.the_head_dim())
        h = jnp.einsum("bsq,qd->bsd", o, layers.wcast(p["attn"]["wo"], "row"))
        x = x + h * rs
        h = layers.apply_norm(cfg.norm, p["mlp_norm"], x)
        h, _ = moe_ffn(p["moe"], cfg, h, no_drop=True)
        x = x + h * rs
        return x, new_cache
