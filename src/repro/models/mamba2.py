"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD forward: within a chunk of length Q the quadratic "attention-like"
form is used (dense matmuls — MXU-friendly), states are carried across chunks
with a first-order recurrence.  This is the TPU adaptation of the paper's
algorithm: chunk size is a VMEM/MXU tile knob, and the Pallas kernel in
``repro.kernels.ssd_scan`` implements the same math with explicit BlockSpecs.

Decode keeps an O(1) recurrent state — this is why mamba2 runs the
``long_500k`` cell that full-attention archs must skip.
"""

from __future__ import annotations

import operator
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from . import layers
from .config import ArchConfig
from .layers import cast, wcast
from .transformer import DenseLM


# ---------------------------------------------------------------------------
# SSD core (pure JAX; mirrored by kernels/ssd_scan)
# ---------------------------------------------------------------------------


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                h0: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD over a full sequence.

    x : (B, L, H, P)    per-head inputs
    dt: (B, L, H)       softplus-ed step sizes (>= 0; 0 on padding)
    A : (H,)            negative decay rates
    Bm: (B, L, N)       input projections (ngroups=1, shared across heads)
    Cm: (B, L, N)       output projections
    h0: (B, H, P, N)    optional initial state
    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    Bsz, L, H, Pd = x.shape
    N = Bm.shape[-1]
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # dt=0 => decay 1, input 0
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk

    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, chunk, H, Pd).astype(f32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(f32)

    da = dtc * A.astype(f32)[None, None, None, :]          # (B,nc,Q,H) log-decay
    cums = jnp.cumsum(da, axis=2)                          # inclusive cumsum

    # ---- intra-chunk (quadratic within chunk) -----------------------------
    # L_mat[i,j] = exp(cums_i - cums_j) for i >= j else 0.  The mask goes
    # INSIDE the exp: for i < j the difference is positive and can overflow,
    # and where(mask, exp(big), 0) still propagates NaN through the grad.
    seg = cums[:, :, :, None, :] - cums[:, :, None, :, :]  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.exp(jnp.where(tri[None, None, :, :, None], seg, -jnp.inf))
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)             # (B,nc,Q,Q)
    w = cb[..., None] * Lmat * dtc[:, :, None, :, :]       # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc)

    # ---- chunk states ------------------------------------------------------
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)      # (B,nc,Q,H)
    states = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                        decay_to_end * dtc, Bc, xc)        # (B,nc,H,P,N)

    # ---- inter-chunk recurrence -------------------------------------------
    chunk_decay = jnp.exp(cums[:, :, -1, :])               # (B,nc,H)

    def step(h, inp):
        d, s = inp                                         # (B,H), (B,H,P,N)
        h = h * d[:, :, None, None] + s
        return h, h

    init = jnp.zeros((Bsz, H, Pd, N), f32) if h0 is None else h0.astype(f32)
    hs_final, hs = jax.lax.scan(
        step, init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    h_prev = jnp.concatenate([init[None], hs[:-1]], axis=0)  # state entering chunk c
    h_prev = jnp.moveaxis(h_prev, 0, 1)                     # (B,nc,H,P,N)

    # ---- inter-chunk contribution -----------------------------------------
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         Cc, jnp.exp(cums), h_prev)
    y = (y_intra + y_inter).reshape(Bsz, Lp, H, Pd)[:, :L]
    return y.astype(x.dtype), hs_final


def ssd_decode_step(h: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray,
                    A: jnp.ndarray, Bm: jnp.ndarray, Cm: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token recurrent update.  h: (B,H,P,N); x: (B,H,P); dt: (B,H);
    Bm, Cm: (B,N)."""
    f32 = jnp.float32
    da = jnp.exp(dt.astype(f32) * A.astype(f32)[None, :])            # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt.astype(f32), Bm.astype(f32), x.astype(f32))
    h = h * da[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(f32), h)
    return y.astype(x.dtype), h


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    d_xbc = di + 2 * s.d_state  # conv covers [x, B, C]
    return s, di, nh, d_xbc


def init_mamba_layer(key, cfg: ArchConfig) -> Dict:
    s, di, nh, d_xbc = _dims(cfg)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * s.d_state + nh  # z, x, B, C, dt
    return {
        "norm": layers.init_norm(cfg.norm, cfg.d_model),
        "ssm": {
            "in_proj": layers.dense_init(ks[0], cfg.d_model, d_in_proj),
            "conv_w": (0.1 * jax.random.normal(ks[1], (s.d_conv, d_xbc))).astype(layers.PARAM_DTYPE),
            "conv_b": jnp.zeros((d_xbc,), layers.PARAM_DTYPE),
            "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(layers.PARAM_DTYPE),
            "dt_bias": jnp.zeros((nh,), layers.PARAM_DTYPE),
            "D": jnp.ones((nh,), layers.PARAM_DTYPE),
            "norm": jnp.ones((di,), layers.PARAM_DTYPE),
            "out_proj": layers.dense_init(ks[2], di, cfg.d_model),
        },
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 carry: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv1d.  xbc: (B,L,C); w: (K,C).  ``carry`` (B,K-1,C)
    provides left context in decode mode."""
    K = w.shape[0]
    if carry is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = carry.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1], :] * cast(w[i]) for i in range(K))
    return jax.nn.silu(out + cast(b))


def mamba_mix(p: Dict, cfg: ArchConfig, x: jnp.ndarray,
              state: Optional[Dict] = None, want_state: bool = False
              ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Sequence-mixing half of the block.

    ``state`` given & L==1 -> recurrent decode step.
    ``want_state``         -> also return the final state (prefill).
    """
    s, di, nh, d_xbc = _dims(cfg)
    B_, L, _ = x.shape
    proj = jnp.einsum("bld,dp->blp", x, wcast(p["in_proj"], "col"))
    z, xs, Bm, Cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + s.d_state, 2 * di + 2 * s.d_state], axis=-1
    )
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)

    # state with L > 1 is a chunked-prefill continuation: the conv carry and
    # the SSD initial state h0 thread the recurrence across chunk boundaries
    # (from a zero state this is the same computation as monolithic prefill).
    decode = state is not None and L == 1
    continuing = state is not None
    carry = state["conv"] if continuing else None
    conv_in = xbc
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"], carry=carry)
    new_state: Optional[Dict] = None
    if continuing or want_state:
        prev = (carry if carry is not None
                else jnp.zeros((B_, s.d_conv - 1, d_xbc), conv_in.dtype))
        tail = jnp.concatenate([prev.astype(conv_in.dtype), conv_in], axis=1)[:, -(s.d_conv - 1):]
        new_state = {"conv": tail}

    xs, Bm, Cm = jnp.split(xbc, [di, di + s.d_state], axis=-1)
    xh = xs.reshape(B_, L, nh, s.head_dim)
    xh = constrain(xh, "ssm_heads")
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    dtp = constrain(dtp, "ssm_dt")  # H-shard the decay tensors (and with them
    # the (Q,Q,H) intra-chunk tensors, the SSD memory hot spot)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if decode:
        y, h = ssd_decode_step(state["ssm"], xh[:, 0], dtp[:, 0], A, Bm[:, 0], Cm[:, 0])
        y = y[:, None]
        new_state["ssm"] = h
    else:
        h0 = state["ssm"] if continuing else None
        y, hfin = ssd_chunked(xh, dtp, A, Bm, Cm, min(cfg.ssm.chunk, L), h0=h0)
        if new_state is not None:
            new_state["ssm"] = hfin

    y = y + xh * p["D"].astype(jnp.float32)[None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, L, di)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)  # grouped rmsnorm (single group)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm"]).astype(x.dtype)
    return jnp.einsum("bli,id->bld", y, wcast(p["out_proj"], "row")), new_state


class Mamba2LM(DenseLM):
    """Attention-free; the paper's coordination technique applies unchanged
    (DESIGN.md §Arch-applicability) — only the sequence mixer differs."""

    def _init_layer(self, key):
        return init_mamba_layer(key, self.cfg)

    def _layer_fwd(self, p, x, positions):
        h = layers.apply_norm(self.cfg.norm, p["norm"], x)
        h, _ = mamba_mix(p["ssm"], self.cfg, h)
        return constrain(x + h, "activation")

    # -- decode ---------------------------------------------------------------

    def init_cache(self, B: int, seq_len: int) -> Dict:
        s, di, nh, d_xbc = _dims(self.cfg)
        L = self.cfg.n_layers
        return {
            "conv": jnp.zeros((L, B, s.d_conv - 1, d_xbc), layers.COMPUTE_DTYPE),
            "ssm": jnp.zeros((L, B, nh, s.head_dim, s.d_state), jnp.float32),
            "length": jnp.zeros((), jnp.int32),
        }

    def _stack_step(self, params, cache, tokens, layer_fn):
        cfg = self.cfg
        x = layers.embed_tokens(params["embedding"], cfg, tokens)
        layer_caches = {"conv": cache["conv"], "ssm": cache["ssm"]}
        if cfg.scan_layers:
            x, new_caches = jax.lax.scan(layer_fn, x, (params["layers"], layer_caches))
        else:
            outs = []
            for i in range(cfg.n_layers):
                p = jax.tree_util.tree_map(operator.itemgetter(i), params["layers"])
                lc = jax.tree_util.tree_map(operator.itemgetter(i), layer_caches)
                x, nc = layer_fn(x, (p, lc))
                outs.append(nc)
            new_caches = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = layers.lm_head(params["embedding"], cfg, x)
        new_cache = dict(new_caches)
        new_cache["length"] = cache["length"] + tokens.shape[1]
        return constrain(logits, "logits"), new_cache

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg

        def body(h, layer_in):
            p, lc = layer_in
            hn = layers.apply_norm(cfg.norm, p["norm"], h)
            out, new_lc = mamba_mix(p["ssm"], cfg, hn, state=lc)
            return h + out, new_lc

        return self._stack_step(params, cache, tokens, body)

    def prefill(self, params, tokens, *, seq_len=None):
        # SSM state has no sequence dim: seq_len is accepted for API
        # uniformity with the attention families but does not change shapes.
        cfg = self.cfg
        cache = self.init_cache(tokens.shape[0], tokens.shape[1])

        def body(h, layer_in):
            p, _lc = layer_in
            hn = layers.apply_norm(cfg.norm, p["norm"], h)
            out, new_lc = mamba_mix(p["ssm"], cfg, hn, want_state=True)
            return h + out, new_lc

        return self._stack_step(params, cache, tokens, body)
