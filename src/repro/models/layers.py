"""Shared neural-net building blocks (pure JAX, functional).

Parameters are plain nested dicts of ``jnp.ndarray``; initialization takes an
explicit PRNG key.  Everything here is shape-polymorphic over a leading batch
dim and differentiable; models compose these into scanned layer stacks.

Compute dtype discipline: parameters are stored in ``param_dtype`` (fp32
masters) and cast to ``compute_dtype`` (bf16) at use — the usual mixed
precision recipe, and what the roofline's bf16 peak assumes.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


def cast(x: jnp.ndarray) -> jnp.ndarray:
    return x.astype(COMPUTE_DTYPE)


def wcast(x: jnp.ndarray, orient: str) -> jnp.ndarray:
    """Cast a weight to compute dtype and (under an explicit-blocks policy)
    constrain the *cast* result so the ZeRO-3 dp-gather moves bf16, not the
    fp32 master.  orient: 'col' (out-dim on model) | 'row' (in-dim on model).
    """
    from ..dist.sharding import constrain

    return constrain(x.astype(COMPUTE_DTYPE), f"w_{orient}")


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, scale: float = 1.0) -> jnp.ndarray:
    std = scale / math.sqrt(d_in)
    return (std * jax.random.normal(key, (d_in, d_out))).astype(PARAM_DTYPE)


def embed_init(key, vocab: int, d: int) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(PARAM_DTYPE)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(kind: str, d: int) -> Dict[str, jnp.ndarray]:
    p = {"scale": jnp.ones((d,), PARAM_DTYPE)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), PARAM_DTYPE)
    return p


def apply_norm(kind: str, p: Dict[str, jnp.ndarray], x: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    """Normalization with f32 *statistics* but a bf16 *tensor* path.

    Upcasting the whole (B,S,D) tensor to f32 (the naive recipe) doubles the
    bytes of every activation reshard GSPMD places near a norm — measured as
    the dominant wire term on qwen1.5-110b (§Perf iteration 2).  Only the
    (B,S,1) moment statistics are f32."""
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
        return x * inv * p["scale"].astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return ((x - mu.astype(x.dtype)) * inv * p["scale"].astype(x.dtype)
            + p["bias"].astype(x.dtype))


def rms_norm_head(p_scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Per-head RMSNorm over the trailing head_dim (qwen3 qk_norm)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * p_scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_frequencies(x.shape[-1], theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs          # (..., S, D/2)
    angles = angles[..., None, :]                                      # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal / sliding-window / cross, shared by all families)
# ---------------------------------------------------------------------------


def init_attention(key, cfg) -> Dict[str, jnp.ndarray]:
    d, hd = cfg.d_model, cfg.the_head_dim()
    q_dim, kv_dim = cfg.n_heads * hd, cfg.n_kv_heads * hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, q_dim),
        "wk": dense_init(ks[1], d, kv_dim),
        "wv": dense_init(ks[2], d, kv_dim),
        "wo": dense_init(ks[3], q_dim, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((q_dim,), PARAM_DTYPE)
        p["bk"] = jnp.zeros((kv_dim,), PARAM_DTYPE)
        p["bv"] = jnp.zeros((kv_dim,), PARAM_DTYPE)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), PARAM_DTYPE)
        p["k_norm"] = jnp.ones((hd,), PARAM_DTYPE)
    return p


def qkv_project(p, cfg, x: jnp.ndarray, positions: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> q (B,S,H,D), k/v (B,S,Hkv,D) with RoPE applied."""
    from ..dist.sharding import constrain

    hd = cfg.the_head_dim()
    x = constrain(x, "block_in")
    q = jnp.einsum("bsd,dq->bsq", x, wcast(p["wq"], "col"))
    k = jnp.einsum("bsd,dq->bsq", x, wcast(p["wk"], "col"))
    v = jnp.einsum("bsd,dq->bsq", x, wcast(p["wv"], "col"))
    if cfg.qkv_bias:
        q = q + cast(p["bq"])
        k = k + cast(p["bk"])
        v = v + cast(p["bv"])
    B, S = x.shape[0], x.shape[1]
    # explicit head-layout constraints: without these, GSPMD propagates the
    # 16-way projection sharding through the reshape and splits head_dim (the
    # attention *contraction* dim), all-reducing full score tensors.
    q = constrain(q.reshape(B, S, cfg.n_heads, hd), "q_heads")
    k = constrain(k.reshape(B, S, cfg.n_kv_heads, hd), "kv_heads")
    v = constrain(v.reshape(B, S, cfg.n_kv_heads, hd), "kv_heads")
    if cfg.qk_norm:
        q = rms_norm_head(p["q_norm"], q)
        k = rms_norm_head(p["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# Above this many kv positions, sdpa switches to a streaming-softmax scan
# over kv blocks (flash attention expressed in XLA): the full (S, T) score
# tensor is never materialized, which is what makes the 32k-prefill cells fit
# HBM without the Pallas kernel.  The Pallas kernel implements the same
# algorithm with explicit VMEM tiles for real-TPU runs.
STREAM_KV_THRESHOLD = 4096
STREAM_KV_BLOCK = 1024


def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
         causal: bool = True,
         window: Optional[int] = None,
         q_positions: Optional[jnp.ndarray] = None,
         kv_positions: Optional[jnp.ndarray] = None,
         kv_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Grouped-query scaled-dot-product attention.

    q: (B, S, H, D); k, v: (B, T, Hkv, D).  H must be a multiple of Hkv.
    ``q_positions``/``kv_positions`` (B, S)/(B, T) define the mask when the
    query block is not aligned with the kv block (decode with a cache).
    ``kv_valid`` (B, T) masks unfilled cache slots.
    """
    from ..dist.sharding import constrain

    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))

    if S > 1 and T >= STREAM_KV_THRESHOLD and T % STREAM_KV_BLOCK == 0:
        out = _sdpa_streaming(q, k, v, causal=causal, window=window,
                              q_positions=q_positions,
                              kv_positions=kv_positions, kv_valid=kv_valid)
        return constrain(out, "attn_out")

    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(D)
    scores = jnp.where(_attn_mask(q_positions, kv_positions, kv_valid,
                                  causal, window), scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v)
    return constrain(out.reshape(B, S, H, D), "attn_out")


def sdpa_append(q: jnp.ndarray, ck: jnp.ndarray, cv: jnp.ndarray,
                k_new: jnp.ndarray, v_new: jnp.ndarray, *,
                window: Optional[int] = None,
                q_positions: jnp.ndarray,
                kv_positions: jnp.ndarray,
                kv_valid: jnp.ndarray) -> jnp.ndarray:
    """Single-token decode attention over (old cache || new token).

    Scores against the *pre-update* cache plus an explicit rank-1 term for
    the new token, combined in one softmax — the reference semantics of the
    fused paged kernel (which streams the pre-update pool and appends the
    new token in fp32).  No longer on the gather decode path: S=1 decode
    rides the chunked ``sdpa`` formulation so decode-written KV is bitwise
    prefill KV.  q/k_new/v_new: (B, 1, H*, D).
    """
    B, S, H, D = q.shape
    Hkv = ck.shape[2]
    G = H // Hkv
    # round the new token through the cache dtype so results are
    # bit-consistent with the read-back-after-update formulation
    k_new = k_new.astype(ck.dtype)
    v_new = v_new.astype(cv.dtype)
    qg = q.reshape(B, S, Hkv, G, D)
    s_old = jnp.einsum("bshgd,bthd->bhgst", qg, ck).astype(jnp.float32)
    s_old = s_old / math.sqrt(D)
    mask = _attn_mask(q_positions, kv_positions, kv_valid, True, window)
    s_old = jnp.where(mask, s_old, -1e30)
    s_new = jnp.einsum("bshgd,bthd->bhgst", qg, k_new).astype(jnp.float32)
    s_new = s_new / math.sqrt(D)   # self-attention of the new token: always valid
    s = jnp.concatenate([s_old, s_new], axis=-1)
    # probs and the value accumulation stay fp32, cast once on the way out —
    # matching the fused paged kernel's fp32 VMEM online-softmax state.
    # Rounding probs to the activation dtype here gave ~1-ulp logit skew vs
    # the kernel, which the MoE router's discreteness could amplify into a
    # token flip (the seed-pinned parity cases test_paged_kernel.py carried).
    p = jax.nn.softmax(s, axis=-1)
    p_old, p_new = p[..., :-1], p[..., -1:]
    out = jnp.einsum("bhgst,bthd->bshgd", p_old, cv.astype(jnp.float32))
    out = out + jnp.einsum("bhgst,bthd->bshgd", p_new,
                           v_new.astype(jnp.float32))
    out = out.astype(q.dtype)
    from ..dist.sharding import constrain

    return constrain(out.reshape(B, S, H, D), "attn_out")


def _attn_mask(q_positions, kv_positions, kv_valid, causal, window):
    qp = q_positions[:, None, None, :, None]      # (B,1,1,S,1)
    kp = kv_positions[:, None, None, None, :]     # (B,1,1,1,T)
    mask = jnp.ones(qp.shape[:-1] + (kp.shape[-1],), dtype=bool)
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        mask = mask & (kp > qp - window)
    if kv_valid is not None:
        mask = mask & kv_valid[:, None, None, None, :]
    return mask


def _sdpa_streaming(q, k, v, *, causal, window, q_positions, kv_positions,
                    kv_valid, block: int = STREAM_KV_BLOCK) -> jnp.ndarray:
    """Numerically exact streaming softmax over kv blocks (lax.scan)."""
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    nb = T // block
    scale = 1.0 / math.sqrt(D)
    qg = (q.astype(jnp.float32) * scale).reshape(B, S, Hkv, G, D)

    kb = jnp.moveaxis(k.reshape(B, nb, block, Hkv, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nb, block, Hkv, D), 1, 0)
    pb = jnp.moveaxis(kv_positions.reshape(B, nb, block), 1, 0)
    valb = (jnp.moveaxis(kv_valid.reshape(B, nb, block), 1, 0)
            if kv_valid is not None else jnp.ones((nb, B, block), bool))

    def step(carry, inp):
        m, l, acc = carry                                # (B,h,g,S), (…), (B,h,g,S,D)
        kc, vc, pc, vac = inp
        s = jnp.einsum("bshgd,bthd->bhgst", qg, kc.astype(jnp.float32))
        mask = _attn_mask(q_positions, pc, vac, causal, window)
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bthd->bhgsd", p, vc.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, S), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, G, S, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, pb, valb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1)                        # (B,S,Hkv,G,D)
    return out.reshape(B, S, H, D).astype(q.dtype)


def attention_block(p, cfg, x: jnp.ndarray, positions: jnp.ndarray, *,
                    window: Optional[int] = None, causal: bool = True) -> jnp.ndarray:
    q, k, v = qkv_project(p, cfg, x, positions)
    o = sdpa(q, k, v, causal=causal, window=window)
    B, S = x.shape[0], x.shape[1]
    o = o.reshape(B, S, cfg.n_heads * cfg.the_head_dim())
    return jnp.einsum("bsq,qd->bsd", o, wcast(p["wo"], "row"))


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, d_ff: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], d, f),
            "w_up": dense_init(ks[1], d, f),
            "w_down": dense_init(ks[2], f, d),
        }
    return {
        "w_up": dense_init(ks[0], d, f),
        "b_up": jnp.zeros((f,), PARAM_DTYPE),
        "w_down": dense_init(ks[1], f, d),
        "b_down": jnp.zeros((d,), PARAM_DTYPE),
    }


def apply_mlp(p, cfg, x: jnp.ndarray) -> jnp.ndarray:
    from ..dist.sharding import constrain

    x = constrain(x, "block_in")   # gather S at block entry (Megatron-SP)
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        g = act(jnp.einsum("bsd,df->bsf", x, wcast(p["w_gate"], "col")))
        u = jnp.einsum("bsd,df->bsf", x, wcast(p["w_up"], "col"))
        h = constrain(g * u, "mlp_hidden")
        return jnp.einsum("bsf,fd->bsd", h, wcast(p["w_down"], "row"))
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, wcast(p["w_up"], "col")) + cast(p["b_up"]))
    h = constrain(h, "mlp_hidden")
    return jnp.einsum("bsf,fd->bsd", h, wcast(p["w_down"], "row")) + cast(p["b_down"])


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embedding(key, cfg) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 2)
    vp = cfg.padded_vocab
    p = {"embed": embed_init(ks[0], vp, cfg.d_model)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], cfg.d_model, vp)
    return p


def embed_tokens(p, cfg, tokens: jnp.ndarray) -> jnp.ndarray:
    x = cast(p["embed"])[tokens]
    return x * jnp.asarray(cfg.emb_scale, x.dtype)


def lm_head(p, cfg, x: jnp.ndarray) -> jnp.ndarray:
    from ..dist.sharding import constrain

    if cfg.tie_embeddings:
        # re-shard the tied table from (gather-friendly) d-sharded to
        # (matmul-friendly) vocab-sharded before the projection: a small
        # weight all-to-all instead of a huge logits all-reduce.
        w = constrain(cast(p["embed"]).T, "head_weight")
    else:
        w = cast(p["head"])
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return logits * jnp.asarray(cfg.logit_scale, logits.dtype)


def sinusoidal_positions(S: int, d: int, offset=0) -> jnp.ndarray:
    """Length-agnostic absolute embeddings (whisper stub-fidelity).

    ``offset`` may be a traced scalar (decode position)."""
    pos = (jnp.arange(S, dtype=jnp.float32) + offset)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    pe = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)
    return pe.astype(COMPUTE_DTYPE)
