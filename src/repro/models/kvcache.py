"""Decode-time caches.

A cache layer is a dict:
  k, v      : (B, T, Hkv, D)  ring buffer (T = window for SWA archs)
  positions : (B, T) int32    absolute position stored in each slot (-1 empty)

Stacked over layers (leading L dim) so that decode can ``lax.scan`` over the
layer stack.  ``positions`` doubles as the validity mask, which makes full and
sliding-window caches the same code path.

``pos`` (the absolute position of the first new token) may be a scalar — the
whole batch decodes in lockstep — or a ``(B,)`` vector, which is what the
continuous-batching scheduler uses: each slot of the decode batch sits at its
own sequence position, so admissions at different times share one ring.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import COMPUTE_DTYPE


def init_attn_cache(n_layers: int, B: int, T: int, n_kv: int, head_dim: int) -> Dict:
    return {
        "k": jnp.zeros((n_layers, B, T, n_kv, head_dim), COMPUTE_DTYPE),
        "v": jnp.zeros((n_layers, B, T, n_kv, head_dim), COMPUTE_DTYPE),
        "positions": -jnp.ones((n_layers, B, T), jnp.int32),
        "length": jnp.zeros((), jnp.int32),  # absolute position of next token
    }


def decode_positions(pos, B: int, S: int) -> jnp.ndarray:
    """(B, S) absolute query positions for a decode step.

    ``pos`` is the scalar shared length or a ``(B,)`` per-slot length vector.
    """
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        pos = pos[:, None]
    return jnp.broadcast_to(pos + jnp.arange(S, dtype=jnp.int32), (B, S))


def cache_update_layer(layer_cache: Dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                       pos: jnp.ndarray) -> Dict:
    """Insert S_new tokens at absolute position ``pos`` (ring for windows).

    layer_cache k/v: (B, T, Hkv, D); k_new/v_new: (B, S, Hkv, D).
    ``pos`` scalar (lockstep batch) or (B,) (per-slot continuous batching).
    """
    T = layer_cache["k"].shape[1]
    B, S = k_new.shape[0], k_new.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if S > T:
        # prefill longer than the (windowed) cache: only the trailing T
        # tokens can ever be attended to — drop the rest (static slice, and
        # it keeps the ring scatter free of duplicate slots).
        k_new, v_new = k_new[:, -T:], v_new[:, -T:]
        pos = pos + (S - T)
        S = T
    if pos.ndim == 0:
        abs_pos = pos + jnp.arange(S, dtype=jnp.int32)        # (S,)
        slots = abs_pos % T                                   # ring slots
        k = layer_cache["k"].at[:, slots].set(k_new.astype(layer_cache["k"].dtype))
        v = layer_cache["v"].at[:, slots].set(v_new.astype(layer_cache["v"].dtype))
        positions = layer_cache["positions"].at[:, slots].set(
            jnp.broadcast_to(abs_pos[None, :], (B, S))
        )
    else:
        abs_pos = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]  # (B, S)
        slots = abs_pos % T                                   # per-row ring slots
        b = jnp.arange(B, dtype=jnp.int32)[:, None]
        k = layer_cache["k"].at[b, slots].set(k_new.astype(layer_cache["k"].dtype))
        v = layer_cache["v"].at[b, slots].set(v_new.astype(layer_cache["v"].dtype))
        positions = layer_cache["positions"].at[b, slots].set(abs_pos)
    return {"k": k, "v": v, "positions": positions}


def cache_kv_view(layer_cache: Dict) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (k, v, kv_positions, kv_valid) for sdpa()."""
    pos = layer_cache["positions"]
    return layer_cache["k"], layer_cache["v"], pos, pos >= 0


# ---------------------------------------------------------------------------
# Slot-level cache surgery (continuous-batching scheduler support)
# ---------------------------------------------------------------------------


def batched_cache(model, n_slots: int, seq_len: int) -> Dict:
    """A decode cache for ``n_slots`` independent sequences: the model's
    normal batch cache with the shared scalar ``length`` widened to a
    per-slot ``(n_slots,)`` vector."""
    cache = dict(model.init_cache(n_slots, seq_len))
    cache["length"] = jnp.zeros((n_slots,), jnp.int32)
    return cache


def _slot_axis(batch_shape: Tuple[int, ...], one_shape: Tuple[int, ...]) -> Optional[int]:
    """The axis along which a B=1 cache leaf scatters into the batch leaf.

    Cache trees from ``init_cache(B, T)`` and ``init_cache(1, T)`` are
    structurally identical, so the slot axis is the unique axis where the
    shapes disagree (stacked leaves carry a leading layer dim, tail leaves do
    not — shape matching handles both without per-family knowledge).
    """
    diffs = [i for i, (a, b) in enumerate(zip(batch_shape, one_shape)) if a != b]
    if not diffs:
        return None  # n_slots == 1: leaves are identical, replace wholesale
    if len(diffs) > 1 or one_shape[diffs[0]] != 1:
        raise ValueError(
            f"cannot locate slot axis: batch {batch_shape} vs one {one_shape}")
    return diffs[0]


def cache_insert_slot(batch_cache: Dict, one_cache: Dict, slot: int) -> Dict:
    """Scatter a freshly-prefilled B=1 cache into row ``slot`` of a batched
    cache (prefill-on-admit).  ``batch_cache['length']`` must be per-slot
    (see :func:`batched_cache`); the admitted sequence keeps its own length."""
    length = batch_cache["length"].at[slot].set(
        jnp.asarray(one_cache["length"], jnp.int32).reshape(()))
    rest = {k: v for k, v in batch_cache.items() if k != "length"}
    one_rest = {k: v for k, v in one_cache.items() if k != "length"}

    def ins(b, o):
        ax = _slot_axis(tuple(b.shape), tuple(o.shape))
        if ax is None:
            return o.astype(b.dtype)
        idx = [slice(None)] * b.ndim
        idx[ax] = slot
        return b.at[tuple(idx)].set(jnp.squeeze(o, axis=ax).astype(b.dtype))

    out = jax.tree_util.tree_map(ins, rest, one_rest)
    out["length"] = length
    return out
