"""Decode-time caches: per-slot rings and the shared paged-block KV pool.

Two storage layouts behind one layer-level interface
(:func:`cache_update_layer` / :func:`cache_kv_view` dispatch on the dict
keys):

**Ring** (the classic layout).  A cache layer is a dict:
  k, v      : (B, T, Hkv, D)  ring buffer (T = window for SWA archs)
  positions : (B, T) int32    absolute position stored in each slot (-1 empty)
Memory is reserved at worst case: every row owns ``T`` slots whether the
sequence is 3 tokens or 3000.

**Paged pool** (continuous-batching serving).  One shared pool per layer plus
a per-slot page table:
  kp, vp     : (n_pages, page_size, Hkv, D)  shared block pool
  page_table : (B, max_pages) int32          slot's logical->physical map
                                             (-1 = unmapped)
Token at absolute position ``p`` of slot ``b`` lives at
``kp[page_table[b, p // page_size], p % page_size]``.  Pages are handed out
by the host-side :class:`PageAllocator` (alloc-on-write, free-on-completion),
so pool memory scales with *live tokens* instead of ``n_slots * max_seq``.
Validity is derived, not stored: lane ``t`` is attendable iff its page is
mapped and ``t < upto`` (the caller's live length) — no positions array.
Writes to unmapped pages are dropped (the physical index is pushed out of
bounds and JAX scatters drop OOB updates), so a freed slot's stale decode
traffic can never corrupt a page that now belongs to another slot.

Both layouts are stacked over layers (leading L dim) so decode can
``lax.scan`` the layer stack; the page table is replicated per layer (int32,
negligible) so the scan carries one pytree.  ``pos`` may be a scalar or a
``(B,)`` vector exactly as before.

The paged view gathers pages in *logical* order, so when no ring wrap has
occurred the gathered (B, max_pages*page_size, Hkv, D) tensor is lane-for-
lane identical to the ring view and attention results match bit-for-bit —
the property the paged parity suite pins.
"""

from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import COMPUTE_DTYPE

# Leaf keys of the shared page pool: no slot axis, never sliced or masked
# per-slot.
POOL_KEYS = frozenset({"kp", "vp"})


def init_attn_cache(n_layers: int, B: int, T: int, n_kv: int, head_dim: int) -> Dict:
    return {
        "k": jnp.zeros((n_layers, B, T, n_kv, head_dim), COMPUTE_DTYPE),
        "v": jnp.zeros((n_layers, B, T, n_kv, head_dim), COMPUTE_DTYPE),
        "positions": -jnp.ones((n_layers, B, T), jnp.int32),
        "length": jnp.zeros((), jnp.int32),  # absolute position of next token
    }


def decode_positions(pos, B: int, S: int) -> jnp.ndarray:
    """(B, S) absolute query positions for a decode step.

    ``pos`` is the scalar shared length or a ``(B,)`` per-slot length vector.
    """
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        pos = pos[:, None]
    return jnp.broadcast_to(pos + jnp.arange(S, dtype=jnp.int32), (B, S))


def is_paged(layer_cache: Dict) -> bool:
    return "kp" in layer_cache


def cache_capacity(layer_cache: Dict) -> int:
    """Static token capacity of one row of a layer cache (ring T, or the
    page table's logical span for the pool)."""
    if is_paged(layer_cache):
        return layer_cache["page_table"].shape[-1] * layer_cache["kp"].shape[-3]
    return layer_cache["k"].shape[-3]


def cache_update_layer(layer_cache: Dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                       pos: jnp.ndarray) -> Dict:
    """Insert S_new tokens at absolute position ``pos``.

    Ring layout scatters into per-row ring slots (``pos % T``); paged layout
    routes each token through the page table into the shared pool.
    layer_cache k/v or kp/vp as documented above; k_new/v_new: (B, S, Hkv, D).
    ``pos`` scalar (lockstep batch) or (B,) (per-slot continuous batching).
    """
    if is_paged(layer_cache):
        return _paged_update_layer(layer_cache, k_new, v_new, pos)
    T = layer_cache["k"].shape[1]
    B, S = k_new.shape[0], k_new.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if S > T:
        # prefill longer than the (windowed) cache: only the trailing T
        # tokens can ever be attended to — drop the rest (static slice, and
        # it keeps the ring scatter free of duplicate slots).
        k_new, v_new = k_new[:, -T:], v_new[:, -T:]
        pos = pos + (S - T)
        S = T
    if pos.ndim == 0:
        abs_pos = pos + jnp.arange(S, dtype=jnp.int32)        # (S,)
        slots = abs_pos % T                                   # ring slots
        k = layer_cache["k"].at[:, slots].set(k_new.astype(layer_cache["k"].dtype))
        v = layer_cache["v"].at[:, slots].set(v_new.astype(layer_cache["v"].dtype))
        positions = layer_cache["positions"].at[:, slots].set(
            jnp.broadcast_to(abs_pos[None, :], (B, S))
        )
    else:
        abs_pos = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]  # (B, S)
        slots = abs_pos % T                                   # per-row ring slots
        b = jnp.arange(B, dtype=jnp.int32)[:, None]
        k = layer_cache["k"].at[b, slots].set(k_new.astype(layer_cache["k"].dtype))
        v = layer_cache["v"].at[b, slots].set(v_new.astype(layer_cache["v"].dtype))
        positions = layer_cache["positions"].at[b, slots].set(abs_pos)
    return {"k": k, "v": v, "positions": positions}


def _paged_update_layer(layer_cache: Dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                       pos: jnp.ndarray) -> Dict:
    kp, vp, pt = layer_cache["kp"], layer_cache["vp"], layer_cache["page_table"]
    n_pages, page_size = kp.shape[-4], kp.shape[-3]
    max_pages = pt.shape[-1]
    B, S = k_new.shape[0], k_new.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (B,))
    abs_pos = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]      # (B, S)
    page_idx = abs_pos // page_size
    offset = abs_pos % page_size
    pid = jnp.take_along_axis(pt, jnp.clip(page_idx, 0, max_pages - 1), axis=-1)
    # unmapped / out-of-table positions are pushed out of bounds: JAX drops
    # OOB scatter updates, so stale traffic from freed or admitting slots can
    # never land in a page it does not own.
    pid = jnp.where((page_idx < max_pages) & (pid >= 0), pid, n_pages)
    kp = kp.at[pid, offset].set(k_new.astype(kp.dtype))
    vp = vp.at[pid, offset].set(v_new.astype(vp.dtype))
    return {"kp": kp, "vp": vp, "page_table": pt}


def cache_kv_view(layer_cache: Dict, upto: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (k, v, kv_positions, kv_valid) for sdpa().

    Ring layout reads the buffers directly (``positions`` doubles as the
    validity mask).  Paged layout gathers the slot's pages from the pool in
    logical order; ``upto`` (scalar or (B,) live length) is required there —
    lanes at or past it, and lanes on unmapped pages, are masked invalid.
    """
    if is_paged(layer_cache):
        if upto is None:
            raise ValueError("paged cache view needs `upto` (the live length)")
        return _paged_kv_view(layer_cache, upto)
    pos = layer_cache["positions"]
    return layer_cache["k"], layer_cache["v"], pos, pos >= 0


def paged_attn_decode(layer_cache: Dict, q: jnp.ndarray, pos, *,
                      window: Optional[int] = None,
                      k_new: Optional[jnp.ndarray] = None,
                      v_new: Optional[jnp.ndarray] = None,
                      include_new: bool = False) -> jnp.ndarray:
    """Fused table-indirect decode attention over the paged pool.

    The ``attn_backend='paged_kernel'`` alternative to
    ``cache_kv_view`` + ``sdpa_append``/``sdpa``: the Pallas kernel streams
    the slot's K/V pages straight from the pool via the scalar-prefetched
    page table — the gathered (B, T, Hkv, D) cache never materializes in
    HBM.  Read-only: ``_prepare_write_span`` / ``cache_update_layer`` still
    own every pool write, so CoW splits and ``mask_slot_rows`` freezing are
    untouched.

    ``pos`` is the slot's live length pre-write (scalar or (B,)).  With
    ``k_new``/``v_new`` the just-projected token is appended in fp32 on top
    of the streamed softmax (the ``sdpa_append`` contract: attend the
    PRE-update pool + a rank-1 new-token term).  With ``include_new`` the
    token was already written into the pool (hybrid local-attention layers)
    and lane ``pos`` itself is attended instead.  q: (B, 1, H, D).
    """
    from ..dist.sharding import constrain, current_policy
    from ..kernels.paged_attention import paged_attention

    kp, vp, pt = layer_cache["kp"], layer_cache["vp"], layer_cache["page_table"]
    B = q.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (B,))
    lengths = pos + 1 if include_new else pos
    policy = current_policy()
    if policy is not None and getattr(policy, "shard_map_pool", False):
        # shard_map decomposition over the lane-sharded pool: GSPMD cannot
        # partition the table-indirect pallas_call without all-gathering the
        # pool, so the per-shard kernel + softmax merge runs explicitly
        from ..kernels.paged_attention.ops import sharded_paged_attention
        out = sharded_paged_attention(q, kp, vp, pt, lengths, policy=policy,
                                      q_pos=pos, window=window, k_new=k_new,
                                      v_new=v_new)
    else:
        out = paged_attention(q, kp, vp, pt, lengths, q_pos=pos,
                              window=window, k_new=k_new, v_new=v_new)
    return constrain(out, "attn_out")


def _paged_kv_view(layer_cache: Dict, upto) -> Tuple[jnp.ndarray, ...]:
    kp, vp, pt = layer_cache["kp"], layer_cache["vp"], layer_cache["page_table"]
    n_pages, page_size, n_kv, head_dim = kp.shape[-4:]
    B, max_pages = pt.shape[-2:]
    T = max_pages * page_size
    pid = jnp.clip(pt, 0, n_pages - 1)
    k = kp[pid].reshape(B, T, n_kv, head_dim)
    v = vp[pid].reshape(B, T, n_kv, head_dim)
    kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    upto = jnp.asarray(upto, jnp.int32)
    if upto.ndim == 0:
        upto = jnp.broadcast_to(upto, (B,))
    mapped = jnp.repeat(pt >= 0, page_size, axis=-1)                      # (B, T)
    return k, v, kv_pos, mapped & (kv_pos < upto[:, None])


# ---------------------------------------------------------------------------
# Host-side page allocator (free list over the shared pool's page ids)
# ---------------------------------------------------------------------------


class PageAllocator:
    """Refcounted free-list allocator for the paged pool.

    Pure host-side bookkeeping: the device only ever sees the page table.
    Pages are a *shared* resource: :meth:`alloc` hands a page out with
    refcount 1, :meth:`share` takes an additional reference (a second slot
    mapping the page read-only, the prefix index publishing it, a parked
    session retaining it), and :meth:`release` drops one reference — the
    page only returns to the free list when its last reference dies.

    Invariants (pinned by the property tests and the scheduler's
    ``audit()``): ``free_count + in_use == n_pages`` at every point, every
    in-use page has refcount >= 1, no free page carries a refcount, no page
    is ever handed out twice, and :meth:`reset` returns the pool to fully
    free.
    """

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, -1, -1))  # pop() -> 0 first
        self._rc: Dict[int, int] = {}       # page -> reference count (mapped only)
        self.high_water = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._rc)

    @property
    def total_refs(self) -> int:
        """Sum of refcounts over mapped pages (== total mappings held by
        slots + prefix index + parked sessions; the audit cross-checks)."""
        return sum(self._rc.values())

    def refcount(self, page: int) -> int:
        return self._rc.get(page, 0)

    def alloc(self, n: int = 1) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"KV pool exhausted: need {n} pages, {len(self._free)} free "
                f"of {self.n_pages}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._rc[p] = 1
        self.high_water = max(self.high_water, len(self._rc))
        return pages

    def share(self, pages: Sequence[int]) -> None:
        """Take one extra reference on each (already mapped) page."""
        for p in pages:
            if p not in self._rc:
                raise ValueError(f"sharing unmapped page {p}")
            self._rc[p] += 1

    def release(self, pages: Sequence[int]) -> None:
        """Drop one reference per page; a page with no references left goes
        back to the free list."""
        for p in pages:
            rc = self._rc.get(p)
            if rc is None:
                raise ValueError(f"releasing unmapped page {p}")
            if rc == 1:
                del self._rc[p]
                self._free.append(p)
            else:
                self._rc[p] = rc - 1

    # Back-compat name: before refcounts, completion-time frees called this.
    free = release

    def check(self) -> None:
        """Raise if the allocator invariants do not hold."""
        if len(self._free) + len(self._rc) != self.n_pages:
            raise AssertionError(
                f"page leak: {len(self._free)} free + {len(self._rc)} mapped "
                f"!= {self.n_pages}")
        if any(rc < 1 for rc in self._rc.values()):
            raise AssertionError(f"mapped page with refcount < 1: {self._rc}")
        overlap = set(self._free) & set(self._rc)
        if overlap:
            raise AssertionError(f"pages both free and mapped: {overlap}")

    def reset(self) -> None:
        """Back to fully free; the high-water gauge restarts too, so
        post-crash stats describe the replayed run, not the aborted one."""
        self._free = list(range(self.n_pages - 1, -1, -1))
        self._rc.clear()
        self.high_water = 0


# ---------------------------------------------------------------------------
# Prefix index: content-addressed full pages for cross-request sharing
# ---------------------------------------------------------------------------


def page_hashes(tokens, page_size: int) -> List[bytes]:
    """Chain hashes of the *full* pages of a token sequence.

    ``h_i = H(h_{i-1} || tokens[i*ps : (i+1)*ps])`` — keyed on the whole
    token prefix, not the page content alone, so two sequences share a chain
    entry iff they agree on every token up to that page boundary.  Only full
    pages get a hash: a partial page's content is still growing and cannot
    be content-addressed.
    """
    arr = np.asarray(tokens, np.int32).reshape(-1)
    out: List[bytes] = []
    h = b"\x00" * 16
    for i in range(len(arr) // page_size):
        m = hashlib.blake2b(digest_size=16)
        m.update(h)
        m.update(arr[i * page_size:(i + 1) * page_size].tobytes())
        h = m.digest()
        out.append(h)
    return out


INDEX_JOURNAL_PREFIX = "index/"


class PrefixIndex:
    """Content-addressed map from token-chain hashes to resident pool pages.

    The FaaSKeeper/FaaSFS move applied to KV state: a full page whose tokens
    are fixed is an immutable journal entry, so it can be shared read-only by
    any request whose prompt carries the same token prefix.  The index holds
    **one allocator reference per published page** (taken via
    :meth:`PageAllocator.share` at publish time), which is what keeps a page
    alive after the slot that wrote it completes.  Pages are immutable once
    published: appends only ever write at ``pos >= length``, and a writer
    that must touch a shared page first copy-on-write splits it.

    Eviction is LRU over publish/hit order and only reclaims the *index's*
    reference — a page another slot or parked session still maps survives
    with its remaining refcount.

    The index itself is worker-local (physical page ids mean nothing outside
    one pool), but its *entries* are durable content: :meth:`journal` pushes
    each published page to a blob store under its chain hash, and
    :meth:`rebuild` re-adopts journaled pages into a fresh pool on worker
    start — how shared prefixes survive a fleet worker's death.
    """

    def __init__(self):
        self._pages: Dict[bytes, int] = {}       # chain hash -> physical page

    def __len__(self) -> int:
        return len(self._pages)

    @property
    def pages(self) -> List[int]:
        return list(self._pages.values())

    def has(self, h: bytes) -> bool:
        return h in self._pages

    @staticmethod
    def journal_key(h: bytes) -> str:
        """Blob-store key of one journaled entry: content-addressed by the
        token-chain hash, so concurrent workers journaling the same prefix
        write the same key with the same bytes (idempotent by construction —
        decode-written KV is bitwise prefill KV)."""
        return INDEX_JOURNAL_PREFIX + h.hex()

    def journal(self, pairs, blob_store, extract) -> int:
        """Persist ``(hash, physical page)`` entries to ``blob_store``: each
        page's contents are gathered via ``extract(page_ids) -> blob`` and
        PUT under :meth:`journal_key`.  Entries whose key is already stored
        are skipped (content-addressed — same hash, same bytes).  Returns
        how many blobs were written."""
        n = 0
        for h, pid in pairs:
            key = self.journal_key(h)
            if key in blob_store.blobs:
                continue
            blob = extract([int(pid)])
            blob_store.put(key, blob, blob_nbytes(blob))
            n += 1
        return n

    def adopt(self, h: bytes, pid: int) -> None:
        """Record an entry whose allocator reference the caller *transfers*
        (vs :meth:`publish`, which takes its own): the rebuild path allocates
        a fresh page, scatters journaled contents into it, and hands the
        alloc-time reference straight to the index."""
        self._pages[h] = int(pid)

    def rebuild(self, blob_store, allocator, budget, install) -> int:
        """Re-adopt journaled entries into a fresh pool (worker cold start).

        For every ``index/<hash>`` blob in the store not already indexed,
        while ``budget()`` pages remain adoptable: allocate one page, call
        ``install(pid, blob)`` to scatter the journaled contents into it,
        and adopt the entry.  Entries that do not fit stay in the store —
        adoption is an optimization; a missed entry just re-prefills.
        Returns the number of pages adopted."""
        n = 0
        for key in list(blob_store.blobs):
            if not key.startswith(INDEX_JOURNAL_PREFIX):
                continue
            h = bytes.fromhex(key[len(INDEX_JOURNAL_PREFIX):])
            if h in self._pages:
                continue
            if budget() < 1:
                break
            pid = allocator.alloc(1)[0]
            install(pid, blob_store.get(key))
            self.adopt(h, pid)
            n += 1
        return n

    def publish(self, hashes: Sequence[bytes], page_ids: Sequence[int],
                allocator: PageAllocator) -> int:
        """Publish (hash, page) pairs not already indexed; the index takes
        one reference per page it actually adopts.  Returns how many."""
        n = 0
        for h, pid in zip(hashes, page_ids, strict=True):
            if h in self._pages:
                continue
            allocator.share([pid])
            self._pages[h] = int(pid)
            n += 1
        return n

    def lookup(self, hashes: Sequence[bytes]) -> List[int]:
        """Longest indexed chain prefix: physical pages for ``hashes[:k]``
        where ``k`` is the first miss.  Hits are re-marked most recent."""
        out: List[int] = []
        for h in hashes:
            pid = self._pages.get(h)
            if pid is None:
                break
            self._pages[h] = self._pages.pop(h)   # LRU bump
            out.append(pid)
        return out

    def evict(self, allocator: PageAllocator, need_free: int,
              pinned: Sequence[int] = ()) -> int:
        """Drop LRU entries (releasing the index's reference) until the
        allocator has ``need_free`` free pages or every unpinned entry is
        gone.  ``pinned`` pages are skipped — the admission driving the
        eviction may be about to map them.  Returns the number dropped."""
        keep = set(pinned)
        n = 0
        for h in list(self._pages):               # LRU first
            if allocator.free_count >= need_free:
                break
            pid = self._pages[h]
            if pid in keep:
                continue
            allocator.release([self._pages.pop(h)])
            n += 1
        return n

    def clear(self, allocator: Optional[PageAllocator] = None) -> None:
        if allocator is not None:
            for pid in self._pages.values():
                allocator.release([pid])
        self._pages.clear()


# ---------------------------------------------------------------------------
# Batched-cache construction & slot-level surgery (scheduler support)
# ---------------------------------------------------------------------------


def batched_cache(model, n_slots: int, seq_len: int) -> Dict:
    """A decode cache for ``n_slots`` independent sequences: the model's
    normal batch cache with the shared scalar ``length`` widened to a
    per-slot ``(n_slots,)`` vector."""
    cache = dict(model.init_cache(n_slots, seq_len))
    cache["length"] = jnp.zeros((n_slots,), jnp.int32)
    return cache


def paged_cache(model, n_slots: int, *, page_size: int, n_pages: int,
                max_pages: int) -> Dict:
    """A paged decode cache: every KV ring of the model's batch cache is
    replaced by a shared ``(n_pages, page_size, Hkv, D)`` pool plus a
    per-slot ``(n_slots, max_pages)`` page table (replicated across the
    stacked layer dim so the decode scan carries one pytree).  Ring-free
    state (SSM/RG-LRU recurrences, conv tails) keeps its per-slot layout."""

    def transform(tree):
        if isinstance(tree, dict) and {"k", "v", "positions"} <= set(tree):
            k = tree["k"]                       # (..., B, T, Hkv, D)
            lead = k.shape[:-4]
            out = {kk: transform(vv) for kk, vv in tree.items()
                   if kk not in ("k", "v", "positions")}
            out["kp"] = jnp.zeros(lead + (n_pages, page_size) + k.shape[-2:], k.dtype)
            out["vp"] = jnp.zeros(lead + (n_pages, page_size) + k.shape[-2:], k.dtype)
            out["page_table"] = -jnp.ones(lead + (n_slots, max_pages), jnp.int32)
            return out
        if isinstance(tree, dict):
            return {kk: transform(vv) for kk, vv in tree.items()}
        return tree

    cache = transform(dict(model.init_cache(n_slots, page_size)))
    cache["length"] = jnp.zeros((n_slots,), jnp.int32)
    return cache


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
                 for k in path)


def _slot_axis_of(keys: Tuple[str, ...]) -> int:
    """Axis carrying the slot (batch) dim for a per-slot cache leaf.

    Stacked leaves (dense/moe/ssm top-level arrays, hybrid ``blocks``) carry
    a leading layer dim, so B sits at axis 1; the hybrid ``tail`` layers and
    the per-slot ``length`` vector are unstacked (axis 0)."""
    return 0 if keys[0] in ("tail", "length") else 1


def mask_slot_rows(new_cache: Dict, old_cache: Dict, keep: jnp.ndarray) -> Dict:
    """Keep a decode step's updates only for slots where ``keep`` is True.

    Inactive rows (freed slots, slots mid-chunked-admission) are restored to
    their pre-step state so a batched decode step cannot advance their
    lengths or evolve their recurrent states.  Shared pool leaves have no
    slot axis and pass through — unmapped page tables already drop their
    writes at the scatter."""

    def sel(path, new, old):
        keys = _path_keys(path)
        if keys[-1] in POOL_KEYS:
            return new
        ax = _slot_axis_of(keys)
        shape = [1] * new.ndim
        shape[ax] = keep.shape[0]
        return jnp.where(keep.reshape(shape), new, old)

    return jax.tree_util.tree_map_with_path(sel, new_cache, old_cache)


def cache_slot_view(batch_cache: Dict, slot) -> Dict:
    """The B=1 view of one slot: per-slot leaves sliced at ``slot`` (kept
    dim), shared pool leaves passed through whole.  ``slot`` may be traced —
    the chunked-prefill step jits over it."""

    def slice_leaf(path, leaf):
        keys = _path_keys(path)
        if keys[-1] in POOL_KEYS:
            return leaf
        return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=_slot_axis_of(keys))

    return jax.tree_util.tree_map_with_path(slice_leaf, batch_cache)


def cache_clear_slot(batch_cache: Dict, slot) -> Dict:
    """Zero one slot's rows (page table and ring positions to -1): fresh
    state for an admission, and — on completion — an unmapped page table so
    the freed slot's residual decode writes are dropped, never landing in
    pages that now belong to another slot."""

    def clear(path, leaf):
        keys = _path_keys(path)
        if keys[-1] in POOL_KEYS:
            return leaf
        ax = _slot_axis_of(keys)
        idx = [slice(None)] * leaf.ndim
        idx[ax] = slot
        fill = -1 if keys[-1] in ("page_table", "positions") else 0
        return leaf.at[tuple(idx)].set(jnp.asarray(fill, leaf.dtype))

    return jax.tree_util.tree_map_with_path(clear, batch_cache)


def set_page_row(batch_cache: Dict, slot: int, row) -> Dict:
    """Install a slot's (max_pages,) page-table row on every replicated
    page-table leaf (no-op for ring or ring-free caches)."""
    row = jnp.asarray(row, jnp.int32)

    def upd(path, leaf):
        if _path_keys(path)[-1] != "page_table":
            return leaf
        idx = [slice(None)] * leaf.ndim
        idx[-2] = slot
        return leaf.at[tuple(idx)].set(row)

    return jax.tree_util.tree_map_with_path(upd, batch_cache)


# ---------------------------------------------------------------------------
# Page-level offload: extract / inject pool pages (storage-backed preemption)
# ---------------------------------------------------------------------------

# Axis of the page dim in a pool leaf (..., n_pages, page_size, Hkv, D).
_PAGE_AXIS = -4


def gather_pages(cache: Dict, page_ids) -> Dict:
    """Extract physical pages ``page_ids`` from every pool leaf.

    Returns a pytree with the cache's structure restricted to pool leaves:
    each ``kp``/``vp`` leaf becomes ``(..., len(page_ids), page_size, Hkv,
    D)`` — the staging buffer a preemption ships to the object store.  The
    caller supplies ``page_ids`` in *logical* order (the slot's page-table
    order), so a blob is position-ordered regardless of how scrambled the
    physical table is.  Exact inverse of :func:`scatter_pages` through any
    page table: ``gather(scatter(cache, ids, blob), ids) == blob``.
    """
    ids = jnp.asarray(page_ids, jnp.int32)

    def pick(path, leaf):
        if _path_keys(path)[-1] in POOL_KEYS:
            return jnp.take(leaf, ids, axis=_PAGE_AXIS)
        return None

    tree = jax.tree_util.tree_map_with_path(pick, cache)
    return _prune_none(tree)


def scatter_pages(cache: Dict, page_ids, blob: Dict) -> Dict:
    """Inject a page blob back into the pool at physical pages ``page_ids``
    (the restore half of offload; the new ids need not match the ids the
    blob was extracted from — the page table re-maps them).  Non-pool leaves
    pass through untouched."""
    ids = jnp.asarray(page_ids, jnp.int32)
    flat = dict(_iter_pool_leaves(blob))

    def put(path, leaf):
        keys = _path_keys(path)
        if keys[-1] not in POOL_KEYS:
            return leaf
        src = flat[keys]
        idx = [slice(None)] * leaf.ndim
        idx[leaf.ndim + _PAGE_AXIS] = ids
        return leaf.at[tuple(idx)].set(src.astype(leaf.dtype))

    return jax.tree_util.tree_map_with_path(put, cache)


def copy_pages(cache: Dict, src_ids, dst_ids) -> Dict:
    """Copy pool pages ``src_ids`` onto ``dst_ids`` (the copy-on-write
    split: a writer about to mutate a page with refcount > 1 duplicates it
    onto a fresh page and remaps its own table; every other reference keeps
    reading the original bytes).  Non-pool leaves pass through untouched."""
    src = jnp.asarray(src_ids, jnp.int32)
    dst = jnp.asarray(dst_ids, jnp.int32)

    def cp(path, leaf):
        if _path_keys(path)[-1] not in POOL_KEYS:
            return leaf
        vals = jnp.take(leaf, src, axis=_PAGE_AXIS)
        idx = [slice(None)] * leaf.ndim
        idx[leaf.ndim + _PAGE_AXIS] = dst
        return leaf.at[tuple(idx)].set(vals)

    return jax.tree_util.tree_map_with_path(cp, cache)


def gather_slot_state(cache: Dict, slot) -> Dict:
    """Snapshot one slot's per-slot rows (lengths, recurrent conv/SSM/RG-LRU
    state — everything except the shared pool and the page table, which the
    scheduler mirrors on the host).  The snapshot is what a parked session
    carries after its slot is reclaimed; :func:`scatter_slot_state` is the
    exact inverse into any slot index."""

    def pick(path, leaf):
        keys = _path_keys(path)
        if keys[-1] in POOL_KEYS or keys[-1] == "page_table":
            return None
        return jax.lax.dynamic_slice_in_dim(leaf, slot, 1,
                                            axis=_slot_axis_of(keys))

    return _prune_none(jax.tree_util.tree_map_with_path(pick, cache))


def scatter_slot_state(cache: Dict, slot, state: Dict) -> Dict:
    """Install a :func:`gather_slot_state` snapshot into row ``slot`` (the
    restore half of parked-slot eviction; the target slot need not be the
    one the snapshot came from).  Pool and page-table leaves pass through."""
    flat = dict(_iter_pool_leaves(state))

    def put(path, leaf):
        keys = _path_keys(path)
        src = flat.get(keys)
        if src is None:
            return leaf
        return jax.lax.dynamic_update_slice_in_dim(
            leaf, src.astype(leaf.dtype), slot, axis=_slot_axis_of(keys))

    return jax.tree_util.tree_map_with_path(put, cache)


def slice_page_blob(blob: Dict, lo: int, hi: int) -> Dict:
    """Pages ``[lo, hi)`` of a blob — the unit of a chunked restore."""
    def cut(leaf):
        idx = [slice(None)] * leaf.ndim
        idx[leaf.ndim + _PAGE_AXIS] = slice(lo, hi)
        return leaf[tuple(idx)]

    return jax.tree_util.tree_map(cut, blob)


def blob_nbytes(blob: Dict) -> int:
    """Serialized size of a page blob (drives the storage billing)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(blob))


def _iter_pool_leaves(tree, prefix: Tuple[str, ...] = ()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _iter_pool_leaves(v, prefix + (str(k),))
    elif tree is not None:
        yield prefix, tree


def _prune_none(tree):
    """Drop None-valued subtrees (non-pool leaves filtered by gather)."""
    if isinstance(tree, dict):
        out = {k: _prune_none(v) for k, v in tree.items()}
        return {k: v for k, v in out.items()
                if v is not None and not (isinstance(v, dict) and not v)}
    return tree


def kv_bytes_per_token(cache: Dict) -> int:
    """Bytes of KV state per stored token, summed over layers (ring k/v or
    pool kp/vp leaves; recurrent state excluded — it is O(1) per slot)."""
    total = 0

    def visit(path, leaf):
        nonlocal total
        key = _path_keys(path)[-1]
        if key in POOL_KEYS:            # (..., Np, ps, H, D)
            tokens = leaf.shape[-4] * leaf.shape[-3]
            total += leaf.size * leaf.dtype.itemsize // tokens
        elif key in ("k", "v"):         # (..., B, T, H, D)
            per_row_tokens = leaf.shape[-3]
            total += (leaf.size * leaf.dtype.itemsize
                      // (leaf.shape[-4] * per_row_tokens))
        return leaf

    jax.tree_util.tree_map_with_path(visit, cache)
    return total


def _slot_axis(batch_shape: Tuple[int, ...], one_shape: Tuple[int, ...]) -> Optional[int]:
    """The axis along which a B=1 cache leaf scatters into the batch leaf.

    Cache trees from ``init_cache(B, T)`` and ``init_cache(1, T)`` are
    structurally identical, so the slot axis is the unique axis where the
    shapes disagree (stacked leaves carry a leading layer dim, tail leaves do
    not — shape matching handles both without per-family knowledge).
    """
    diffs = [i for i, (a, b)
             in enumerate(zip(batch_shape, one_shape, strict=False)) if a != b]
    if not diffs:
        return None  # identical shapes: pool leaves / n_slots == 1 — replace wholesale
    if len(diffs) > 1 or one_shape[diffs[0]] != 1:
        raise ValueError(
            f"cannot locate slot axis: batch {batch_shape} vs one {one_shape}")
    return diffs[0]


def cache_insert_slot(batch_cache: Dict, one_cache: Dict, slot) -> Dict:
    """Scatter a B=1 cache into row ``slot`` of a batched cache (prefill-on-
    admit, and the write-back half of the chunked-prefill step).  Leaves with
    identical shapes — the shared page pool, or everything when n_slots == 1
    — are replaced wholesale.  ``batch_cache['length']`` must be per-slot
    (see :func:`batched_cache`); the admitted sequence keeps its own length."""
    length = batch_cache["length"].at[slot].set(
        jnp.asarray(one_cache["length"], jnp.int32).reshape(()))
    rest = {k: v for k, v in batch_cache.items() if k != "length"}
    one_rest = {k: v for k, v in one_cache.items() if k != "length"}

    def ins(b, o):
        ax = _slot_axis(tuple(b.shape), tuple(o.shape))
        if ax is None:
            return o.astype(b.dtype)
        idx = [slice(None)] * b.ndim
        idx[ax] = slot
        return b.at[tuple(idx)].set(jnp.squeeze(o, axis=ax).astype(b.dtype))

    out = jax.tree_util.tree_map(ins, rest, one_rest)
    out["length"] = length
    return out
