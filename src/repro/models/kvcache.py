"""Decode-time caches: per-slot rings and the shared paged-block KV pool.

Two storage layouts behind one layer-level interface
(:func:`cache_update_layer` / :func:`cache_kv_view` dispatch on the dict
keys):

**Ring** (the classic layout).  A cache layer is a dict:
  k, v      : (B, T, Hkv, D)  ring buffer (T = window for SWA archs)
  positions : (B, T) int32    absolute position stored in each slot (-1 empty)
Memory is reserved at worst case: every row owns ``T`` slots whether the
sequence is 3 tokens or 3000.

**Paged pool** (continuous-batching serving).  One shared pool per layer plus
a per-slot page table:
  kp, vp     : (n_pages, page_size, Hkv, D)  shared block pool
  page_table : (B, max_pages) int32          slot's logical->physical map
                                             (-1 = unmapped)
Token at absolute position ``p`` of slot ``b`` lives at
``kp[page_table[b, p // page_size], p % page_size]``.  Pages are handed out
by the host-side :class:`PageAllocator` (alloc-on-write, free-on-completion),
so pool memory scales with *live tokens* instead of ``n_slots * max_seq``.
Validity is derived, not stored: lane ``t`` is attendable iff its page is
mapped and ``t < upto`` (the caller's live length) — no positions array.
Writes to unmapped pages are dropped (the physical index is pushed out of
bounds and JAX scatters drop OOB updates), so a freed slot's stale decode
traffic can never corrupt a page that now belongs to another slot.

Both layouts are stacked over layers (leading L dim) so decode can
``lax.scan`` the layer stack; the page table is replicated per layer (int32,
negligible) so the scan carries one pytree.  ``pos`` may be a scalar or a
``(B,)`` vector exactly as before.

The paged view gathers pages in *logical* order, so when no ring wrap has
occurred the gathered (B, max_pages*page_size, Hkv, D) tensor is lane-for-
lane identical to the ring view and attention results match bit-for-bit —
the property the paged parity suite pins.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .layers import COMPUTE_DTYPE

# Leaf keys of the shared page pool: no slot axis, never sliced or masked
# per-slot.
POOL_KEYS = frozenset({"kp", "vp"})


def init_attn_cache(n_layers: int, B: int, T: int, n_kv: int, head_dim: int) -> Dict:
    return {
        "k": jnp.zeros((n_layers, B, T, n_kv, head_dim), COMPUTE_DTYPE),
        "v": jnp.zeros((n_layers, B, T, n_kv, head_dim), COMPUTE_DTYPE),
        "positions": -jnp.ones((n_layers, B, T), jnp.int32),
        "length": jnp.zeros((), jnp.int32),  # absolute position of next token
    }


def decode_positions(pos, B: int, S: int) -> jnp.ndarray:
    """(B, S) absolute query positions for a decode step.

    ``pos`` is the scalar shared length or a ``(B,)`` per-slot length vector.
    """
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        pos = pos[:, None]
    return jnp.broadcast_to(pos + jnp.arange(S, dtype=jnp.int32), (B, S))


def is_paged(layer_cache: Dict) -> bool:
    return "kp" in layer_cache


def cache_capacity(layer_cache: Dict) -> int:
    """Static token capacity of one row of a layer cache (ring T, or the
    page table's logical span for the pool)."""
    if is_paged(layer_cache):
        return layer_cache["page_table"].shape[-1] * layer_cache["kp"].shape[-3]
    return layer_cache["k"].shape[-3]


def cache_update_layer(layer_cache: Dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                       pos: jnp.ndarray) -> Dict:
    """Insert S_new tokens at absolute position ``pos``.

    Ring layout scatters into per-row ring slots (``pos % T``); paged layout
    routes each token through the page table into the shared pool.
    layer_cache k/v or kp/vp as documented above; k_new/v_new: (B, S, Hkv, D).
    ``pos`` scalar (lockstep batch) or (B,) (per-slot continuous batching).
    """
    if is_paged(layer_cache):
        return _paged_update_layer(layer_cache, k_new, v_new, pos)
    T = layer_cache["k"].shape[1]
    B, S = k_new.shape[0], k_new.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if S > T:
        # prefill longer than the (windowed) cache: only the trailing T
        # tokens can ever be attended to — drop the rest (static slice, and
        # it keeps the ring scatter free of duplicate slots).
        k_new, v_new = k_new[:, -T:], v_new[:, -T:]
        pos = pos + (S - T)
        S = T
    if pos.ndim == 0:
        abs_pos = pos + jnp.arange(S, dtype=jnp.int32)        # (S,)
        slots = abs_pos % T                                   # ring slots
        k = layer_cache["k"].at[:, slots].set(k_new.astype(layer_cache["k"].dtype))
        v = layer_cache["v"].at[:, slots].set(v_new.astype(layer_cache["v"].dtype))
        positions = layer_cache["positions"].at[:, slots].set(
            jnp.broadcast_to(abs_pos[None, :], (B, S))
        )
    else:
        abs_pos = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]  # (B, S)
        slots = abs_pos % T                                   # per-row ring slots
        b = jnp.arange(B, dtype=jnp.int32)[:, None]
        k = layer_cache["k"].at[b, slots].set(k_new.astype(layer_cache["k"].dtype))
        v = layer_cache["v"].at[b, slots].set(v_new.astype(layer_cache["v"].dtype))
        positions = layer_cache["positions"].at[b, slots].set(abs_pos)
    return {"k": k, "v": v, "positions": positions}


def _paged_update_layer(layer_cache: Dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                       pos: jnp.ndarray) -> Dict:
    kp, vp, pt = layer_cache["kp"], layer_cache["vp"], layer_cache["page_table"]
    n_pages, page_size = kp.shape[-4], kp.shape[-3]
    max_pages = pt.shape[-1]
    B, S = k_new.shape[0], k_new.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (B,))
    abs_pos = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]      # (B, S)
    page_idx = abs_pos // page_size
    offset = abs_pos % page_size
    pid = jnp.take_along_axis(pt, jnp.clip(page_idx, 0, max_pages - 1), axis=-1)
    # unmapped / out-of-table positions are pushed out of bounds: JAX drops
    # OOB scatter updates, so stale traffic from freed or admitting slots can
    # never land in a page it does not own.
    pid = jnp.where((page_idx < max_pages) & (pid >= 0), pid, n_pages)
    kp = kp.at[pid, offset].set(k_new.astype(kp.dtype))
    vp = vp.at[pid, offset].set(v_new.astype(vp.dtype))
    return {"kp": kp, "vp": vp, "page_table": pt}


def cache_kv_view(layer_cache: Dict, upto: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (k, v, kv_positions, kv_valid) for sdpa().

    Ring layout reads the buffers directly (``positions`` doubles as the
    validity mask).  Paged layout gathers the slot's pages from the pool in
    logical order; ``upto`` (scalar or (B,) live length) is required there —
    lanes at or past it, and lanes on unmapped pages, are masked invalid.
    """
    if is_paged(layer_cache):
        if upto is None:
            raise ValueError("paged cache view needs `upto` (the live length)")
        return _paged_kv_view(layer_cache, upto)
    pos = layer_cache["positions"]
    return layer_cache["k"], layer_cache["v"], pos, pos >= 0


def _paged_kv_view(layer_cache: Dict, upto) -> Tuple[jnp.ndarray, ...]:
    kp, vp, pt = layer_cache["kp"], layer_cache["vp"], layer_cache["page_table"]
    n_pages, page_size, n_kv, head_dim = kp.shape[-4:]
    B, max_pages = pt.shape[-2:]
    T = max_pages * page_size
    pid = jnp.clip(pt, 0, n_pages - 1)
    k = kp[pid].reshape(B, T, n_kv, head_dim)
    v = vp[pid].reshape(B, T, n_kv, head_dim)
    kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    upto = jnp.asarray(upto, jnp.int32)
    if upto.ndim == 0:
        upto = jnp.broadcast_to(upto, (B,))
    mapped = jnp.repeat(pt >= 0, page_size, axis=-1)                      # (B, T)
    return k, v, kv_pos, mapped & (kv_pos < upto[:, None])


# ---------------------------------------------------------------------------
# Host-side page allocator (free list over the shared pool's page ids)
# ---------------------------------------------------------------------------


class PageAllocator:
    """Free-list allocator for the paged pool.

    Pure host-side bookkeeping: the device only ever sees the page table.
    Invariant (pinned by the property tests): ``free_count + in_use ==
    n_pages`` at every point, no page is ever handed out twice, and
    :meth:`reset` returns the pool to fully free.
    """

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, -1, -1))  # pop() -> 0 first
        self._mapped: set = set()
        self.high_water = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._mapped)

    def alloc(self, n: int = 1) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"KV pool exhausted: need {n} pages, {len(self._free)} free "
                f"of {self.n_pages}")
        pages = [self._free.pop() for _ in range(n)]
        self._mapped.update(pages)
        self.high_water = max(self.high_water, len(self._mapped))
        return pages

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p not in self._mapped:
                raise ValueError(f"freeing unmapped page {p}")
            self._mapped.remove(p)
            self._free.append(p)

    def reset(self) -> None:
        """Back to fully free; the high-water gauge restarts too, so
        post-crash stats describe the replayed run, not the aborted one."""
        self._free = list(range(self.n_pages - 1, -1, -1))
        self._mapped.clear()
        self.high_water = 0


# ---------------------------------------------------------------------------
# Batched-cache construction & slot-level surgery (scheduler support)
# ---------------------------------------------------------------------------


def batched_cache(model, n_slots: int, seq_len: int) -> Dict:
    """A decode cache for ``n_slots`` independent sequences: the model's
    normal batch cache with the shared scalar ``length`` widened to a
    per-slot ``(n_slots,)`` vector."""
    cache = dict(model.init_cache(n_slots, seq_len))
    cache["length"] = jnp.zeros((n_slots,), jnp.int32)
    return cache


def paged_cache(model, n_slots: int, *, page_size: int, n_pages: int,
                max_pages: int) -> Dict:
    """A paged decode cache: every KV ring of the model's batch cache is
    replaced by a shared ``(n_pages, page_size, Hkv, D)`` pool plus a
    per-slot ``(n_slots, max_pages)`` page table (replicated across the
    stacked layer dim so the decode scan carries one pytree).  Ring-free
    state (SSM/RG-LRU recurrences, conv tails) keeps its per-slot layout."""

    def transform(tree):
        if isinstance(tree, dict) and {"k", "v", "positions"} <= set(tree):
            k = tree["k"]                       # (..., B, T, Hkv, D)
            lead = k.shape[:-4]
            out = {kk: transform(vv) for kk, vv in tree.items()
                   if kk not in ("k", "v", "positions")}
            out["kp"] = jnp.zeros(lead + (n_pages, page_size) + k.shape[-2:], k.dtype)
            out["vp"] = jnp.zeros(lead + (n_pages, page_size) + k.shape[-2:], k.dtype)
            out["page_table"] = -jnp.ones(lead + (n_slots, max_pages), jnp.int32)
            return out
        if isinstance(tree, dict):
            return {kk: transform(vv) for kk, vv in tree.items()}
        return tree

    cache = transform(dict(model.init_cache(n_slots, page_size)))
    cache["length"] = jnp.zeros((n_slots,), jnp.int32)
    return cache


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
                 for k in path)


def _slot_axis_of(keys: Tuple[str, ...]) -> int:
    """Axis carrying the slot (batch) dim for a per-slot cache leaf.

    Stacked leaves (dense/moe/ssm top-level arrays, hybrid ``blocks``) carry
    a leading layer dim, so B sits at axis 1; the hybrid ``tail`` layers and
    the per-slot ``length`` vector are unstacked (axis 0)."""
    return 0 if keys[0] in ("tail", "length") else 1


def mask_slot_rows(new_cache: Dict, old_cache: Dict, keep: jnp.ndarray) -> Dict:
    """Keep a decode step's updates only for slots where ``keep`` is True.

    Inactive rows (freed slots, slots mid-chunked-admission) are restored to
    their pre-step state so a batched decode step cannot advance their
    lengths or evolve their recurrent states.  Shared pool leaves have no
    slot axis and pass through — unmapped page tables already drop their
    writes at the scatter."""

    def sel(path, new, old):
        keys = _path_keys(path)
        if keys[-1] in POOL_KEYS:
            return new
        ax = _slot_axis_of(keys)
        shape = [1] * new.ndim
        shape[ax] = keep.shape[0]
        return jnp.where(keep.reshape(shape), new, old)

    return jax.tree_util.tree_map_with_path(sel, new_cache, old_cache)


def cache_slot_view(batch_cache: Dict, slot) -> Dict:
    """The B=1 view of one slot: per-slot leaves sliced at ``slot`` (kept
    dim), shared pool leaves passed through whole.  ``slot`` may be traced —
    the chunked-prefill step jits over it."""

    def slice_leaf(path, leaf):
        keys = _path_keys(path)
        if keys[-1] in POOL_KEYS:
            return leaf
        return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=_slot_axis_of(keys))

    return jax.tree_util.tree_map_with_path(slice_leaf, batch_cache)


def cache_clear_slot(batch_cache: Dict, slot) -> Dict:
    """Zero one slot's rows (page table and ring positions to -1): fresh
    state for an admission, and — on completion — an unmapped page table so
    the freed slot's residual decode writes are dropped, never landing in
    pages that now belong to another slot."""

    def clear(path, leaf):
        keys = _path_keys(path)
        if keys[-1] in POOL_KEYS:
            return leaf
        ax = _slot_axis_of(keys)
        idx = [slice(None)] * leaf.ndim
        idx[ax] = slot
        fill = -1 if keys[-1] in ("page_table", "positions") else 0
        return leaf.at[tuple(idx)].set(jnp.asarray(fill, leaf.dtype))

    return jax.tree_util.tree_map_with_path(clear, batch_cache)


def set_page_row(batch_cache: Dict, slot: int, row) -> Dict:
    """Install a slot's (max_pages,) page-table row on every replicated
    page-table leaf (no-op for ring or ring-free caches)."""
    row = jnp.asarray(row, jnp.int32)

    def upd(path, leaf):
        if _path_keys(path)[-1] != "page_table":
            return leaf
        idx = [slice(None)] * leaf.ndim
        idx[-2] = slot
        return leaf.at[tuple(idx)].set(row)

    return jax.tree_util.tree_map_with_path(upd, batch_cache)


# ---------------------------------------------------------------------------
# Page-level offload: extract / inject pool pages (storage-backed preemption)
# ---------------------------------------------------------------------------

# Axis of the page dim in a pool leaf (..., n_pages, page_size, Hkv, D).
_PAGE_AXIS = -4


def gather_pages(cache: Dict, page_ids) -> Dict:
    """Extract physical pages ``page_ids`` from every pool leaf.

    Returns a pytree with the cache's structure restricted to pool leaves:
    each ``kp``/``vp`` leaf becomes ``(..., len(page_ids), page_size, Hkv,
    D)`` — the staging buffer a preemption ships to the object store.  The
    caller supplies ``page_ids`` in *logical* order (the slot's page-table
    order), so a blob is position-ordered regardless of how scrambled the
    physical table is.  Exact inverse of :func:`scatter_pages` through any
    page table: ``gather(scatter(cache, ids, blob), ids) == blob``.
    """
    ids = jnp.asarray(page_ids, jnp.int32)

    def pick(path, leaf):
        if _path_keys(path)[-1] in POOL_KEYS:
            return jnp.take(leaf, ids, axis=_PAGE_AXIS)
        return None

    tree = jax.tree_util.tree_map_with_path(pick, cache)
    return _prune_none(tree)


def scatter_pages(cache: Dict, page_ids, blob: Dict) -> Dict:
    """Inject a page blob back into the pool at physical pages ``page_ids``
    (the restore half of offload; the new ids need not match the ids the
    blob was extracted from — the page table re-maps them).  Non-pool leaves
    pass through untouched."""
    ids = jnp.asarray(page_ids, jnp.int32)
    flat = dict(_iter_pool_leaves(blob))

    def put(path, leaf):
        keys = _path_keys(path)
        if keys[-1] not in POOL_KEYS:
            return leaf
        src = flat[keys]
        idx = [slice(None)] * leaf.ndim
        idx[leaf.ndim + _PAGE_AXIS] = ids
        return leaf.at[tuple(idx)].set(src.astype(leaf.dtype))

    return jax.tree_util.tree_map_with_path(put, cache)


def slice_page_blob(blob: Dict, lo: int, hi: int) -> Dict:
    """Pages ``[lo, hi)`` of a blob — the unit of a chunked restore."""
    def cut(leaf):
        idx = [slice(None)] * leaf.ndim
        idx[leaf.ndim + _PAGE_AXIS] = slice(lo, hi)
        return leaf[tuple(idx)]

    return jax.tree_util.tree_map(cut, blob)


def blob_nbytes(blob: Dict) -> int:
    """Serialized size of a page blob (drives the storage billing)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(blob))


def _iter_pool_leaves(tree, prefix: Tuple[str, ...] = ()):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _iter_pool_leaves(v, prefix + (str(k),))
    elif tree is not None:
        yield prefix, tree


def _prune_none(tree):
    """Drop None-valued subtrees (non-pool leaves filtered by gather)."""
    if isinstance(tree, dict):
        out = {k: _prune_none(v) for k, v in tree.items()}
        return {k: v for k, v in out.items()
                if v is not None and not (isinstance(v, dict) and not v)}
    return tree


def kv_bytes_per_token(cache: Dict) -> int:
    """Bytes of KV state per stored token, summed over layers (ring k/v or
    pool kp/vp leaves; recurrent state excluded — it is O(1) per slot)."""
    total = 0

    def visit(path, leaf):
        nonlocal total
        key = _path_keys(path)[-1]
        if key in POOL_KEYS:            # (..., Np, ps, H, D)
            tokens = leaf.shape[-4] * leaf.shape[-3]
            total += leaf.size * leaf.dtype.itemsize // tokens
        elif key in ("k", "v"):         # (..., B, T, H, D)
            per_row_tokens = leaf.shape[-3]
            total += (leaf.size * leaf.dtype.itemsize
                      // (leaf.shape[-4] * per_row_tokens))
        return leaf

    jax.tree_util.tree_map_with_path(visit, cache)
    return total


def _slot_axis(batch_shape: Tuple[int, ...], one_shape: Tuple[int, ...]) -> Optional[int]:
    """The axis along which a B=1 cache leaf scatters into the batch leaf.

    Cache trees from ``init_cache(B, T)`` and ``init_cache(1, T)`` are
    structurally identical, so the slot axis is the unique axis where the
    shapes disagree (stacked leaves carry a leading layer dim, tail leaves do
    not — shape matching handles both without per-family knowledge).
    """
    diffs = [i for i, (a, b) in enumerate(zip(batch_shape, one_shape)) if a != b]
    if not diffs:
        return None  # identical shapes: pool leaves / n_slots == 1 — replace wholesale
    if len(diffs) > 1 or one_shape[diffs[0]] != 1:
        raise ValueError(
            f"cannot locate slot axis: batch {batch_shape} vs one {one_shape}")
    return diffs[0]


def cache_insert_slot(batch_cache: Dict, one_cache: Dict, slot) -> Dict:
    """Scatter a B=1 cache into row ``slot`` of a batched cache (prefill-on-
    admit, and the write-back half of the chunked-prefill step).  Leaves with
    identical shapes — the shared page pool, or everything when n_slots == 1
    — are replaced wholesale.  ``batch_cache['length']`` must be per-slot
    (see :func:`batched_cache`); the admitted sequence keeps its own length."""
    length = batch_cache["length"].at[slot].set(
        jnp.asarray(one_cache["length"], jnp.int32).reshape(()))
    rest = {k: v for k, v in batch_cache.items() if k != "length"}
    one_rest = {k: v for k, v in one_cache.items() if k != "length"}

    def ins(b, o):
        ax = _slot_axis(tuple(b.shape), tuple(o.shape))
        if ax is None:
            return o.astype(b.dtype)
        idx = [slice(None)] * b.ndim
        idx[ax] = slot
        return b.at[tuple(idx)].set(jnp.squeeze(o, axis=ax).astype(b.dtype))

    out = jax.tree_util.tree_map(ins, rest, one_rest)
    out["length"] = length
    return out
