"""Decode-time caches.

A cache layer is a dict:
  k, v      : (B, T, Hkv, D)  ring buffer (T = window for SWA archs)
  positions : (B, T) int32    absolute position stored in each slot (-1 empty)

Stacked over layers (leading L dim) so that decode can ``lax.scan`` over the
layer stack.  ``positions`` doubles as the validity mask, which makes full and
sliding-window caches the same code path.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from .layers import COMPUTE_DTYPE


def init_attn_cache(n_layers: int, B: int, T: int, n_kv: int, head_dim: int) -> Dict:
    return {
        "k": jnp.zeros((n_layers, B, T, n_kv, head_dim), COMPUTE_DTYPE),
        "v": jnp.zeros((n_layers, B, T, n_kv, head_dim), COMPUTE_DTYPE),
        "positions": -jnp.ones((n_layers, B, T), jnp.int32),
        "length": jnp.zeros((), jnp.int32),  # absolute position of next token
    }


def cache_update_layer(layer_cache: Dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                       pos: jnp.ndarray) -> Dict:
    """Insert S_new tokens at absolute position ``pos`` (ring for windows).

    layer_cache k/v: (B, T, Hkv, D); k_new/v_new: (B, S, Hkv, D).
    """
    T = layer_cache["k"].shape[1]
    S = k_new.shape[1]
    if S > T:
        # prefill longer than the (windowed) cache: only the trailing T
        # tokens can ever be attended to — drop the rest (static slice, and
        # it keeps the ring scatter free of duplicate slots).
        k_new, v_new = k_new[:, -T:], v_new[:, -T:]
        pos = pos + (S - T)
        S = T
    abs_pos = pos + jnp.arange(S, dtype=jnp.int32)            # (S,)
    slots = abs_pos % T                                       # ring slots
    k = layer_cache["k"].at[:, slots].set(k_new.astype(layer_cache["k"].dtype))
    v = layer_cache["v"].at[:, slots].set(v_new.astype(layer_cache["v"].dtype))
    positions = layer_cache["positions"].at[:, slots].set(
        jnp.broadcast_to(abs_pos[None, :], (k_new.shape[0], S))
    )
    return {"k": k, "v": v, "positions": positions}


def cache_kv_view(layer_cache: Dict) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (k, v, kv_positions, kv_valid) for sdpa()."""
    pos = layer_cache["positions"]
    return layer_cache["k"], layer_cache["v"], pos, pos >= 0
