"""InternVL2-style VLM backbone (arXiv:2404.16821).

The InternViT frontend is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings (B, n_patches, patch_dim).  A 2-layer
MLP projector (as in InternVL) maps them into the InternLM2 backbone, where
they are prepended to the token embeddings.  Decode shapes are pure-LM
(the image context lives inside the KV cache), so ``decode_step`` is
inherited from :class:`DenseLM` unchanged.
"""

from __future__ import annotations

import operator
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from . import layers
from .layers import cast
from .transformer import DenseLM


class VisionLM(DenseLM):
    def init(self, key) -> Dict:
        k_base, k_proj = jax.random.split(key)
        params = super().init(k_base)
        pd = self.cfg.vlm.patch_dim or self.cfg.d_model
        ks = jax.random.split(k_proj, 2)
        params["patch_proj"] = {
            "norm": layers.init_norm("layernorm", pd),
            "w": layers.dense_init(ks[0], pd, self.cfg.d_model),
            "w2": layers.dense_init(ks[1], self.cfg.d_model, self.cfg.d_model),
        }
        return params

    def _project_patches(self, params: Dict, patches: jnp.ndarray) -> jnp.ndarray:
        pp = params["patch_proj"]
        x = layers.apply_norm("layernorm", pp["norm"], patches.astype(layers.COMPUTE_DTYPE))
        x = jax.nn.gelu(jnp.einsum("bpd,dm->bpm", x, cast(pp["w"])))
        return jnp.einsum("bpm,mn->bpn", x, cast(pp["w2"]))

    def apply(self, params: Dict, batch: Dict) -> jnp.ndarray:
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        tok_x = layers.embed_tokens(params["embedding"], cfg, tokens)
        if "patch_embeds" in batch:
            img_x = self._project_patches(params, batch["patch_embeds"])
            x = jnp.concatenate([img_x, tok_x], axis=1)
        else:
            x = tok_x
        x = constrain(x, "activation")
        total = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(total, dtype=jnp.int32)[None], (B, total))
        x, _ = self._run_stack(params["layers"], x, positions)
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        x = x[:, -S:]  # logits only over the text positions
        return constrain(layers.lm_head(params["embedding"], cfg, x), "logits")

    def prefill(self, params: Dict, tokens: jnp.ndarray,
                patch_embeds=None, *, seq_len=None) -> Tuple[jnp.ndarray, Dict]:
        """``seq_len`` counts *text* positions (prompt + decode budget); the
        image prefix is added on top of it when patches are present."""
        if patch_embeds is None:
            return super().prefill(params, tokens, seq_len=seq_len)
        img_x = self._project_patches(params, patch_embeds)
        B, n_p = img_x.shape[0], img_x.shape[1]
        cache = self.init_cache(B, n_p + (seq_len or tokens.shape[1]))
        # run image prefix through the stack to fill the cache, then the text
        _, cache = self._decode_embedded(params, cache, img_x)
        return self.decode_step(params, cache, tokens)

    def _decode_embedded(self, params, cache, x_embed):
        """decode_step but starting from embeddings instead of token ids."""
        cfg = self.cfg
        pos = cache["length"]

        def body(carry, layer_in):
            h = carry
            p, lc = layer_in
            h, new_lc = self._layer_decode(p, h, lc, pos)
            return h, new_lc

        layer_caches = {k: cache[k] for k in ("k", "v", "positions")}
        if cfg.scan_layers:
            x, new_caches = jax.lax.scan(body, x_embed, (params["layers"], layer_caches))
        else:
            outs = []
            x = x_embed
            for i in range(cfg.n_layers):
                p = jax.tree_util.tree_map(operator.itemgetter(i), params["layers"])
                lc = jax.tree_util.tree_map(operator.itemgetter(i), layer_caches)
                x, nc = body(x, (p, lc))
                outs.append(nc)
            new_caches = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
        new_cache = dict(new_caches)
        new_cache["length"] = cache["length"] + x_embed.shape[1]
        return x, new_cache
