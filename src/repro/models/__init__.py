"""Data-plane model zoo: the ten assigned architectures.

``build_model(cfg)`` dispatches on ``cfg.family`` and returns an object with
the uniform interface::

    init(key) -> params
    apply(params, batch) -> logits                  # training forward
    loss_aux(params, batch) -> (logits, aux_loss)   # + MoE balance loss
    init_cache(B, seq_len) -> cache
    decode_step(params, cache, tokens) -> (logits, cache)
    prefill(params, tokens) -> (logits, cache)
"""

from .config import (
    ALL_SHAPES,
    SHAPES_BY_NAME,
    ArchConfig,
    EncDecConfig,
    HybridConfig,
    MoEConfig,
    ShapeSpec,
    SSMConfig,
    VLMConfig,
    shapes_for,
)


def build_model(cfg: ArchConfig):
    if cfg.family in ("dense",):
        from .transformer import DenseLM

        return DenseLM(cfg)
    if cfg.family == "moe":
        from .moe import MoELM

        return MoELM(cfg)
    if cfg.family == "ssm":
        from .mamba2 import Mamba2LM

        return Mamba2LM(cfg)
    if cfg.family == "hybrid":
        from .rglru import RecurrentLM

        return RecurrentLM(cfg)
    if cfg.family == "audio":
        from .whisper import EncDecLM

        return EncDecLM(cfg)
    if cfg.family == "vlm":
        from .vlm import VisionLM

        return VisionLM(cfg)
    raise ValueError(f"unknown family {cfg.family}")


__all__ = [
    "ALL_SHAPES",
    "SHAPES_BY_NAME",
    "ArchConfig",
    "EncDecConfig",
    "HybridConfig",
    "MoEConfig",
    "SSMConfig",
    "ShapeSpec",
    "VLMConfig",
    "build_model",
    "shapes_for",
]
