"""Dense decoder-only transformer (starcoder2 / qwen3 / qwen1.5 / minicpm,
and the LM backbone of internvl2).

Layer stack is a ``lax.scan`` over stacked per-layer params (HLO size is
O(1) in depth — mandatory for the 80/94-layer archs), with configurable
rematerialization.  The same block is reused by moe.py (which swaps the MLP)
and whisper.py (which adds cross-attention).
"""

from __future__ import annotations

import operator
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from . import kvcache, layers
from .config import ArchConfig


def remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def init_dense_layer(key, cfg: ArchConfig) -> Dict:
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": layers.init_norm(cfg.norm, cfg.d_model),
        "attn": layers.init_attention(ks[0], cfg),
        "mlp_norm": layers.init_norm(cfg.norm, cfg.d_model),
        "mlp": layers.init_mlp(ks[1], cfg),
    }


def dense_layer_fwd(p: Dict, cfg: ArchConfig, x: jnp.ndarray,
                    positions: jnp.ndarray) -> jnp.ndarray:
    rs = jnp.asarray(cfg.residual_scale, x.dtype)
    h = layers.apply_norm(cfg.norm, p["attn_norm"], x)
    h = layers.attention_block(p["attn"], cfg, h, positions,
                               window=cfg.sliding_window)
    x = x + h * rs
    x = constrain(x, "activation")
    h = layers.apply_norm(cfg.norm, p["mlp_norm"], x)
    h = layers.apply_mlp(p["mlp"], cfg, h)
    x = x + h * rs
    return constrain(x, "activation")


def dense_layer_decode(p: Dict, cfg: ArchConfig, x: jnp.ndarray,
                       layer_cache: Dict, pos: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """One-token (or short-S) step against a ring or paged cache.

    ``pos`` scalar (lockstep batch) or (B,) per-slot (continuous batching).
    """
    rs = jnp.asarray(cfg.residual_scale, x.dtype)
    B, S = x.shape[0], x.shape[1]
    positions = kvcache.decode_positions(pos, B, S)
    h = layers.apply_norm(cfg.norm, p["attn_norm"], x)
    q, k, v = layers.qkv_project(p["attn"], cfg, h, positions)
    new_cache = kvcache.cache_update_layer(layer_cache, k, v, pos)
    if S > kvcache.cache_capacity(layer_cache):
        # prefill-from-scratch longer than the (windowed) ring: the ring only
        # keeps the trailing window, so attend the fresh full-sequence k/v.
        o = layers.sdpa(q, k, v, causal=True, window=cfg.sliding_window,
                        q_positions=positions, kv_positions=positions)
    elif S == 1 and cfg.attn_backend == "paged_kernel" and kvcache.is_paged(layer_cache):
        # fused path: stream the slot's pages via the table-indirect Pallas
        # kernel (pre-update pool + fp32 new-token append) — the gathered
        # cache never materializes in HBM.
        o = kvcache.paged_attn_decode(layer_cache, q, pos,
                                      window=cfg.sliding_window,
                                      k_new=k, v_new=v)
    else:
        # S=1 steady-state decode is the S-chunk path at S=1: attend the
        # POST-update view so a decode step computes bit-identically to a
        # prefill chunk covering the same token.  (The old pre-update
        # ``sdpa_append`` formulation saved the read-after-write but made
        # decode-written KV diverge from prefill KV in low bf16 bits,
        # blocking generated-tail reuse and accept/reject speculation.)
        ck, cv, kv_pos, kv_valid = kvcache.cache_kv_view(new_cache, upto=pos + S)
        o = layers.sdpa(q, ck, cv, causal=True, window=cfg.sliding_window,
                        q_positions=positions, kv_positions=kv_pos, kv_valid=kv_valid)
    o = o.reshape(B, S, cfg.n_heads * cfg.the_head_dim())
    h = jnp.einsum("bsq,qd->bsd", o, layers.wcast(p["attn"]["wo"], "row"))
    x = x + h * rs
    h = layers.apply_norm(cfg.norm, p["mlp_norm"], x)
    h = layers.apply_mlp(p["mlp"], cfg, h)
    x = x + h * rs
    return x, new_cache


class DenseLM:
    """Functional model object; params are plain pytrees."""

    family_layer_init = staticmethod(init_dense_layer)

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- init ------------------------------------------------------------------

    def init(self, key) -> Dict:
        cfg = self.cfg
        k_emb, k_layers = jax.random.split(key)
        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        stacked = jax.vmap(lambda k: self._init_layer(k))(layer_keys)
        return {
            "embedding": layers.init_embedding(k_emb, cfg),
            "layers": stacked,
            "final_norm": layers.init_norm(cfg.norm, cfg.d_model),
        }

    def _init_layer(self, key) -> Dict:
        return init_dense_layer(key, self.cfg)

    def _layer_fwd(self, p, x, positions):
        return dense_layer_fwd(p, self.cfg, x, positions)

    def _layer_decode(self, p, x, layer_cache, pos):
        return dense_layer_decode(p, self.cfg, x, layer_cache, pos)

    # -- stack runner ------------------------------------------------------------

    def _run_stack(self, stacked: Dict, x: jnp.ndarray, positions: jnp.ndarray,
                   aux_init: Any = None):
        """Scan (or unroll) the layer stack.  Returns (x, aux)."""
        cfg = self.cfg

        def body(carry, p):
            h, aux = carry
            h2, aux2 = self._layer_fwd_aux(p, h, positions, aux)
            return (h2, aux2), None

        fn = remat_wrap(body, cfg.remat)
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(fn, (x, aux_init), stacked)
        else:
            aux = aux_init
            for i in range(cfg.n_layers):
                p = jax.tree_util.tree_map(operator.itemgetter(i), stacked)
                (x, aux), _ = fn((x, aux), p)
        return x, aux

    def _layer_fwd_aux(self, p, x, positions, aux):
        return self._layer_fwd(p, x, positions), aux

    # -- public API ----------------------------------------------------------------

    def apply(self, params: Dict, batch: Dict) -> jnp.ndarray:
        """Training/prefill forward over full sequences -> logits."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = layers.embed_tokens(params["embedding"], cfg, tokens)
        x = constrain(x, "activation")
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, _ = self._run_stack(params["layers"], x, positions)
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = layers.lm_head(params["embedding"], cfg, x)
        return constrain(logits, "logits")

    def loss_aux(self, params: Dict, batch: Dict):
        """Hook: families may add auxiliary losses (MoE load balance)."""
        return self.apply(params, batch), 0.0

    # -- decode ------------------------------------------------------------------

    def cache_len(self, seq_len: int) -> int:
        w = self.cfg.sliding_window
        return min(seq_len, w) if w else seq_len

    def init_cache(self, B: int, seq_len: int) -> Dict:
        cfg = self.cfg
        return kvcache.init_attn_cache(
            cfg.n_layers, B, self.cache_len(seq_len), cfg.n_kv_heads, cfg.the_head_dim()
        )

    def decode_step(self, params: Dict, cache: Dict, tokens: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, Dict]:
        """tokens: (B, S_new) — one (or a few) new tokens per sequence."""
        cfg = self.cfg
        x = layers.embed_tokens(params["embedding"], cfg, tokens)
        pos = cache["length"]

        def body(carry, layer_in):
            h = carry
            p, lc = layer_in
            h, new_lc = self._layer_decode(p, h, lc, pos)
            return h, new_lc

        layer_keys = (("kp", "vp", "page_table") if "kp" in cache
                      else ("k", "v", "positions"))
        layer_caches = {k: cache[k] for k in layer_keys}
        fn = remat_wrap(body, "none")
        if cfg.scan_layers:
            x, new_caches = jax.lax.scan(fn, x, (params["layers"], layer_caches))
        else:
            outs = []
            for i in range(cfg.n_layers):
                p = jax.tree_util.tree_map(operator.itemgetter(i), params["layers"])
                lc = jax.tree_util.tree_map(operator.itemgetter(i), layer_caches)
                x, nc = fn(x, (p, lc))
                outs.append(nc)
            new_caches = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = layers.lm_head(params["embedding"], cfg, x)
        new_cache = dict(new_caches)
        new_cache["length"] = cache["length"] + tokens.shape[1]
        return constrain(logits, "logits"), new_cache

    def prefill(self, params: Dict, tokens: jnp.ndarray, *,
                seq_len: Optional[int] = None) -> Tuple[jnp.ndarray, Dict]:
        """Full-sequence forward that also fills the cache (kind='prefill').

        ``seq_len`` sizes the ring for the *total* sequence (prompt + decode
        budget) so the scheduler can prefill straight into a slot-shaped
        cache; default is the prompt length (legacy behaviour).
        """
        cache = self.init_cache(tokens.shape[0], seq_len or tokens.shape[1])
        logits, cache = self.decode_step(params, cache, tokens)
        return logits, cache
