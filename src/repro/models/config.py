"""Architecture configuration for the data plane.

One :class:`ArchConfig` instance fully describes a model family member; the
ten assigned architectures live in :mod:`repro.configs` as module-level
constants built from this dataclass.  ``reduced()`` produces the smoke-test
scale of the same family (same code paths, tiny dims).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape) cell of the assignment matrix."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int           # hidden width of a single expert FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style block pattern: ``pattern`` repeated over layers.

    'r' = RG-LRU recurrent block, 'a' = local-attention block.
    """

    pattern: str = "rra"
    lru_width: Optional[int] = None     # defaults to d_model
    local_window: int = 2048
    d_conv: int = 4


@dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int
    n_frames: int = 1500        # whisper-base: 30 s of audio after conv stub
    frame_dim: Optional[int] = None  # dims of the precomputed frame embeddings


@dataclass(frozen=True)
class VLMConfig:
    n_patches: int = 256        # precomputed ViT patch embeddings (stub frontend)
    patch_dim: Optional[int] = None


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None       # default d_model // n_heads
    mlp: str = "swiglu"                  # swiglu | gelu | geglu
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    qk_norm: bool = False                # qwen3
    qkv_bias: bool = False               # qwen1.5, starcoder2
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None  # starcoder2 = 4096
    emb_scale: float = 1.0               # minicpm scale_emb
    residual_scale: float = 1.0          # minicpm scale_depth / sqrt(L)
    logit_scale: float = 1.0             # minicpm d_model/dim_model_base scaling
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None

    # which assignment shapes apply (decode skipped for enc-only, long_500k
    # skipped for pure full-attention archs — DESIGN.md §Arch-applicability)
    shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")

    # scan-over-layers (compile-time/HLO-size control; always true at scale)
    scan_layers: bool = True
    remat: str = "full"                  # none | full | dots  (hillclimb lever)

    # decode attention over a paged cache: 'gather' materializes the pooled
    # view in HBM (reference and CPU fallback), 'paged_kernel' streams pages
    # through the Pallas table-indirect kernel (S=1 decode only; gather
    # still serves chunked prefill and ring caches)
    attn_backend: str = "gather"

    def kv_dim(self) -> int:
        return self.n_kv_heads * self.the_head_dim()

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so logits always vocab-shard on the model
        axis (and embedding rows stay MXU-aligned).  lm_head masks the pad."""
        return -(-self.vocab // 256) * 256

    def the_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def sub_quadratic(self) -> bool:
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window is not None
        )

    # ----- parameter counting (for roofline MODEL_FLOPS = 6·N·D) -------------

    def param_count(self, active_only: bool = False) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.the_head_dim()
        q_dim, kv = self.n_heads * hd, self.n_kv_heads * hd

        def attn_params() -> int:
            return d * (q_dim + 2 * kv) + q_dim * d

        def mlp_params(width: int) -> int:
            return d * width * (3 if self.mlp in ("swiglu", "geglu") else 2)

        n = 0
        if self.family == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            # in_proj -> [z, x, B, C, dt], conv over (x,B,C), out_proj
            n_bc = 2 * s.d_state
            n += d * (2 * di + n_bc + nh)            # in_proj
            n += (di + n_bc) * s.d_conv              # conv1d
            n += di * d                              # out_proj
            n += nh * 2 + di                         # A_log, dt_bias, norm-ish
            n *= self.n_layers
        elif self.family == "hybrid":
            h = self.hybrid
            lw = h.lru_width or d
            pat = layer_pattern(self)
            n_r = pat.count("r")
            n_a = pat.count("a")
            rec = d * lw * 2 + lw * h.d_conv + lw * d + 3 * lw  # x/y proj, conv, out, gates-ish
            rec += 2 * lw * (lw // 8)  # rg-lru input/recurrence gates (block-diag, 8 blocks)
            n += n_r * rec + n_a * attn_params()
            n += self.n_layers * mlp_params(f)
        else:
            per_layer = attn_params()
            if self.family == "moe" and self.moe is not None:
                m = self.moe
                experts = m.n_experts * d * m.d_expert * (3 if self.mlp == "swiglu" else 2)
                router = d * m.n_experts
                if active_only:
                    experts = m.top_k * d * m.d_expert * (3 if self.mlp == "swiglu" else 2)
                per_layer += experts + router
            else:
                per_layer += mlp_params(f)
            n = self.n_layers * per_layer
            if self.family == "audio" and self.encdec is not None:
                # encoder layers: self-attn + mlp; decoder adds cross-attn
                enc = self.encdec.n_encoder_layers * (attn_params() + mlp_params(f))
                n += enc + self.n_layers * attn_params()  # cross-attn in decoder
        n += self.vocab * d * (1 if self.tie_embeddings else 2)  # embed + head
        return n

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: Dict = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4) if self.family != "hybrid" else 3,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab=256,
            head_dim=16,
            scan_layers=self.scan_layers,
            remat="none",
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(n_experts=4, top_k=2, d_expert=32,
                                  capacity_factor=self.moe.capacity_factor)
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=8)
        if self.hybrid is not None:
            kw["hybrid"] = HybridConfig(pattern=self.hybrid.pattern, lru_width=64,
                                        local_window=8, d_conv=4)
        if self.encdec is not None:
            kw["encdec"] = EncDecConfig(n_encoder_layers=2, n_frames=8, frame_dim=64)
        if self.vlm is not None:
            kw["vlm"] = VLMConfig(n_patches=4, patch_dim=64)
        return dataclasses.replace(self, **kw)


def layer_pattern(cfg: ArchConfig) -> str:
    """Expanded per-layer kind string for hybrid archs, e.g. 'rrarra...'."""
    assert cfg.hybrid is not None
    p = cfg.hybrid.pattern
    return (p * math.ceil(cfg.n_layers / len(p)))[: cfg.n_layers]


def shapes_for(cfg: ArchConfig) -> List[ShapeSpec]:
    return [SHAPES_BY_NAME[s] for s in cfg.shapes]
