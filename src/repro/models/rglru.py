"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427).

Layer pattern ``rra`` (two RG-LRU recurrent blocks, one local-attention MQA
block) repeated over 26 layers.  The RG-LRU linear recurrence

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(L) * r_t)

is evaluated with ``lax.associative_scan`` (log-depth — the TPU-native way to
run a diagonal linear recurrence; kernels/rglru_scan gives the Pallas version).
Sub-quadratic (local attention window 2048 + O(1) recurrent state), so this
arch runs the ``long_500k`` cell.

The layer stack scans over *super-blocks* (one ``rra`` group), with the
non-divisible tail unrolled — HLO stays O(1) in depth.
"""

from __future__ import annotations

import operator
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from . import kvcache, layers
from .config import ArchConfig, layer_pattern
from .layers import cast, wcast
from .transformer import DenseLM, remat_wrap

C_RGLRU = 8.0

# Chunks up to this length run the recurrence as a strict left fold
# (``lax.scan``) instead of the log-depth ``associative_scan``.  The left
# fold computes h_t = a_t*h_{t-1} + b_t in exactly the order a sequence of
# S=1 decode steps would, so a short chunk (spec-decode verify, chunked
# prefill tail) is bitwise-identical to stepping token by token — the
# invariant accept/reject speculation relies on.  associative_scan happens
# to be left-fold-exact for S <= 3 but reassociates (and drifts in low fp32
# bits) from S = 4; long prefill keeps the log-depth form for perf.
RGLRU_LEFT_FOLD_MAX = 16


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------


def rglru_scan(x_in: jnp.ndarray, a: jnp.ndarray,
               h0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Diagonal linear recurrence h_t = a_t h_{t-1} + b_t over axis 1.

    x_in (=b), a: (B, S, W) fp32.  h0: (B, W) initial state.
    """
    if h0 is not None:
        x_in = x_in.at[:, 0].add(a[:, 0] * h0)

    if x_in.shape[1] <= RGLRU_LEFT_FOLD_MAX:
        # sequential fold from zero state (h0 already folded into b_0):
        # a_0*0 + b_0 == b_0 bitwise, and each a_t*h + b_t matches the
        # fold-in an S=1 step performs, so chunk == token-by-token exactly.
        def step(h, ab):
            a_t, b_t = ab
            h = a_t * h + b_t
            return h, h

        _, hs = jax.lax.scan(step, jnp.zeros_like(x_in[:, 0]),
                             (jnp.moveaxis(a, 1, 0), jnp.moveaxis(x_in, 1, 0)))
        return jnp.moveaxis(hs, 0, 1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, x_in), axis=1)
    return h


def rglru_gates(p: Dict, x: jnp.ndarray, n_blocks: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-diagonal gate projections (Griffin): returns (a, gated_input)."""
    B, S, W = x.shape
    Wb = W // n_blocks
    xb = x.reshape(B, S, n_blocks, Wb).astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bskw,kwv->bskv", xb, p["gate_w_a"].astype(jnp.float32))
                       + p["gate_b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bskw,kwv->bskv", xb, p["gate_w_x"].astype(jnp.float32))
                       + p["gate_b_x"].astype(jnp.float32))
    r = r.reshape(B, S, W)
    i = i.reshape(B, S, W)
    log_a = -C_RGLRU * jax.nn.softplus(p["a_param"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * x.astype(jnp.float32))
    return a, gated


def init_rec_mixer(key, cfg: ArchConfig) -> Dict:
    h = cfg.hybrid
    W = h.lru_width or cfg.d_model
    nb = cfg.n_heads
    Wb = W // nb
    ks = jax.random.split(key, 6)
    # a_param init so that a^(1/c) ~ U(0.9, 0.999) at r=1 (Griffin App. A)
    a0 = jax.random.uniform(ks[0], (W,), minval=0.9, maxval=0.999)
    a_param = jnp.log(jnp.expm1(-jnp.log(a0) / C_RGLRU))
    return {
        "w_x": layers.dense_init(ks[1], cfg.d_model, W),
        "w_y": layers.dense_init(ks[2], cfg.d_model, W),
        "conv_w": (0.1 * jax.random.normal(ks[3], (h.d_conv, W))).astype(layers.PARAM_DTYPE),
        "conv_b": jnp.zeros((W,), layers.PARAM_DTYPE),
        "gate_w_a": (jax.random.normal(ks[4], (nb, Wb, Wb)) / math.sqrt(Wb)).astype(layers.PARAM_DTYPE),
        "gate_b_a": jnp.zeros((nb, Wb), layers.PARAM_DTYPE),
        "gate_w_x": (jax.random.normal(ks[5], (nb, Wb, Wb)) / math.sqrt(Wb)).astype(layers.PARAM_DTYPE),
        "gate_b_x": jnp.zeros((nb, Wb), layers.PARAM_DTYPE),
        "a_param": a_param.astype(layers.PARAM_DTYPE),
        "w_out": layers.dense_init(ks[0], W, cfg.d_model),
    }


def rec_mix(p: Dict, cfg: ArchConfig, x: jnp.ndarray,
            state: Optional[Dict] = None, want_state: bool = False
            ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Griffin recurrent block mixer.  state={'h': (B,W), 'conv': (B,K-1,W)}."""
    h_cfg = cfg.hybrid
    K = h_cfg.d_conv
    y_branch = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, wcast(p["w_y"], "col")))
    xw = jnp.einsum("bsd,dw->bsw", x, wcast(p["w_x"], "col"))

    # a state with S > 1 is a *continuation* (chunked prefill): the conv
    # carry and h0 thread the recurrence across chunk boundaries exactly as
    # S == 1 decode does — from a zero state this reduces bitwise to the
    # zero-padded monolithic prefill.
    continuing = state is not None
    carry = state["conv"] if continuing else None
    conv_in = xw
    # causal depthwise conv (no activation in griffin conv)
    if carry is None:
        padded = jnp.concatenate(
            [jnp.zeros((xw.shape[0], K - 1, xw.shape[2]), xw.dtype), xw], axis=1)
    else:
        padded = jnp.concatenate([carry.astype(xw.dtype), xw], axis=1)
    xc = sum(padded[:, i:i + xw.shape[1], :] * cast(p["conv_w"][i]) for i in range(K))
    xc = xc + cast(p["conv_b"])

    new_state: Optional[Dict] = None
    if continuing or want_state:
        prev = (carry if carry is not None
                else jnp.zeros((xw.shape[0], K - 1, xw.shape[2]), conv_in.dtype))
        tail = jnp.concatenate([prev.astype(conv_in.dtype), conv_in], axis=1)[:, -(K - 1):]
        new_state = {"conv": tail}

    # shard channels (not seq) across model for the scan: the associative
    # scan is sequential in S, so S must be local; W/16 keeps its log-depth
    # intermediate buffers small.
    xc = constrain(xc, "lru_channels")
    a, gated = rglru_gates(p, xc, cfg.n_heads)
    a = constrain(a, "lru_channels")
    gated = constrain(gated, "lru_channels")
    h0 = state["h"] if continuing else None
    h = rglru_scan(gated, a, h0=h0)
    if continuing or want_state:
        new_state["h"] = h[:, -1]
    h = h.astype(x.dtype) * y_branch
    return jnp.einsum("bsw,wd->bsd", h, wcast(p["w_out"], "row")), new_state


# ---------------------------------------------------------------------------
# Layer / super-block structure
# ---------------------------------------------------------------------------


def init_hybrid_layer(key, cfg: ArchConfig, kind: str) -> Dict:
    ks = jax.random.split(key, 2)
    p = {
        "norm": layers.init_norm(cfg.norm, cfg.d_model),
        "mlp_norm": layers.init_norm(cfg.norm, cfg.d_model),
        "mlp": layers.init_mlp(ks[1], cfg),
    }
    if kind == "r":
        p["rec"] = init_rec_mixer(ks[0], cfg)
    else:
        p["attn"] = layers.init_attention(ks[0], cfg)
    return p


def _layer_step(p: Dict, cfg: ArchConfig, kind: str, x: jnp.ndarray,
                positions: jnp.ndarray, lc: Optional[Dict], pos,
                want_state: bool) -> Tuple[jnp.ndarray, Optional[Dict]]:
    h = layers.apply_norm(cfg.norm, p["norm"], x)
    new_lc: Optional[Dict] = None
    if kind == "r":
        h, new_lc = rec_mix(p["rec"], cfg, h,
                            state=lc if lc is not None else None,
                            want_state=want_state)
    else:
        if lc is None:
            h = layers.attention_block(p["attn"], cfg, h, positions,
                                       window=cfg.hybrid.local_window)
        else:
            B, S = h.shape[0], h.shape[1]
            q, k, v = layers.qkv_project(p["attn"], cfg, h, positions)
            new_lc = kvcache.cache_update_layer(lc, k, v, pos)
            if S > kvcache.cache_capacity(lc):  # prefill longer than the ring window
                o = layers.sdpa(q, k, v, causal=True, window=cfg.hybrid.local_window,
                                q_positions=positions, kv_positions=positions)
            elif (S == 1 and cfg.attn_backend == "paged_kernel"
                  and kvcache.is_paged(lc)):
                # fused table-indirect kernel over the POST-update pool (the
                # token is already written; lane ``pos`` itself is attended)
                o = kvcache.paged_attn_decode(new_lc, q, pos,
                                              window=cfg.hybrid.local_window,
                                              include_new=True)
            else:
                ck, cv, kv_pos, kv_valid = kvcache.cache_kv_view(new_lc, upto=pos + S)
                o = layers.sdpa(q, ck, cv, causal=True, window=cfg.hybrid.local_window,
                                q_positions=positions, kv_positions=kv_pos, kv_valid=kv_valid)
            o = o.reshape(B, S, cfg.n_heads * cfg.the_head_dim())
            h = jnp.einsum("bsq,qd->bsd", o, layers.wcast(p["attn"]["wo"], "row"))
    x = x + h
    x = constrain(x, "activation")
    h = layers.apply_norm(cfg.norm, p["mlp_norm"], x)
    x = x + layers.apply_mlp(p["mlp"], cfg, h)
    return constrain(x, "activation"), new_lc


class RecurrentLM(DenseLM):
    def __init__(self, cfg: ArchConfig):
        super().__init__(cfg)
        self.pattern = cfg.hybrid.pattern
        self.full_pattern = layer_pattern(cfg)
        self.n_sb = cfg.n_layers // len(self.pattern)
        self.tail_pattern = self.full_pattern[self.n_sb * len(self.pattern):]

    # -- init -------------------------------------------------------------------

    def init(self, key) -> Dict:
        cfg = self.cfg
        k_emb, k_blocks, k_tail = jax.random.split(key, 3)

        def one_sb(k):
            ks = jax.random.split(k, len(self.pattern))
            return {f"l{j}": init_hybrid_layer(ks[j], cfg, kind)
                    for j, kind in enumerate(self.pattern)}

        params = {
            "embedding": layers.init_embedding(k_emb, cfg),
            "blocks": jax.vmap(one_sb)(jax.random.split(k_blocks, self.n_sb)),
            "final_norm": layers.init_norm(cfg.norm, cfg.d_model),
        }
        if self.tail_pattern:
            ks = jax.random.split(k_tail, len(self.tail_pattern))
            params["tail"] = {f"t{j}": init_hybrid_layer(ks[j], cfg, kind)
                              for j, kind in enumerate(self.tail_pattern)}
        return params

    # -- fwd ---------------------------------------------------------------------

    def apply(self, params: Dict, batch: Dict) -> jnp.ndarray:
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = layers.embed_tokens(params["embedding"], cfg, tokens)
        x = constrain(x, "activation")
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

        def sb_body(carry, p):
            h = carry
            for j, kind in enumerate(self.pattern):
                h, _ = _layer_step(p[f"l{j}"], cfg, kind, h, positions, None, None, False)
            return h, None

        fn = remat_wrap(sb_body, cfg.remat)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(fn, x, params["blocks"])
        else:
            for i in range(self.n_sb):
                p = jax.tree_util.tree_map(operator.itemgetter(i), params["blocks"])
                x, _ = fn(x, p)
        for j, kind in enumerate(self.tail_pattern):
            x, _ = _layer_step(params["tail"][f"t{j}"], cfg, kind, x,
                               positions, None, None, False)
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = layers.lm_head(params["embedding"], cfg, x)
        return constrain(logits, "logits")

    # -- decode ------------------------------------------------------------------

    def cache_len(self, seq_len: int) -> int:
        return min(seq_len, self.cfg.hybrid.local_window)

    def _empty_caches(self, B: int, seq_len: int):
        cfg = self.cfg
        W = cfg.hybrid.lru_width or cfg.d_model
        K = cfg.hybrid.d_conv
        T = self.cache_len(seq_len)
        hd = cfg.the_head_dim()

        def one(kind):
            if kind == "r":
                return {"h": jnp.zeros((B, W), jnp.float32),
                        "conv": jnp.zeros((B, K - 1, W), layers.COMPUTE_DTYPE)}
            return {"k": jnp.zeros((B, T, cfg.n_kv_heads, hd), layers.COMPUTE_DTYPE),
                    "v": jnp.zeros((B, T, cfg.n_kv_heads, hd), layers.COMPUTE_DTYPE),
                    "positions": -jnp.ones((B, T), jnp.int32)}

        block = {f"l{j}": one(kind) for j, kind in enumerate(self.pattern)}
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (self.n_sb,) + a.shape).copy(), block)
        cache = {"blocks": stacked}
        if self.tail_pattern:
            cache["tail"] = {f"t{j}": one(kind) for j, kind in enumerate(self.tail_pattern)}
        return cache

    def init_cache(self, B: int, seq_len: int) -> Dict:
        cache = self._empty_caches(B, seq_len)
        cache["length"] = jnp.zeros((), jnp.int32)
        return cache

    def _step_with_cache(self, params, cache, tokens, want_state: bool):
        cfg = self.cfg
        B, S = tokens.shape
        x = layers.embed_tokens(params["embedding"], cfg, tokens)
        pos = cache["length"]
        positions = kvcache.decode_positions(pos, B, S)

        def sb_body(carry, pc):
            h = carry
            p, lc = pc
            new_lcs = {}
            for j, kind in enumerate(self.pattern):
                h, nlc = _layer_step(p[f"l{j}"], cfg, kind, h, positions,
                                     lc[f"l{j}"], pos, want_state)
                new_lcs[f"l{j}"] = nlc if nlc is not None else lc[f"l{j}"]
            return h, new_lcs

        if cfg.scan_layers:
            x, new_blocks = jax.lax.scan(sb_body, x, (params["blocks"], cache["blocks"]))
        else:
            outs = []
            for i in range(self.n_sb):
                p = jax.tree_util.tree_map(operator.itemgetter(i), params["blocks"])
                lc = jax.tree_util.tree_map(operator.itemgetter(i), cache["blocks"])
                x, nc = sb_body(x, (p, lc))
                outs.append(nc)
            new_blocks = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)

        new_cache = {"blocks": new_blocks, "length": cache["length"] + S}
        if self.tail_pattern:
            new_tail = {}
            for j, kind in enumerate(self.tail_pattern):
                x, nlc = _layer_step(params["tail"][f"t{j}"], cfg, kind, x, positions,
                                     cache["tail"][f"t{j}"], pos, want_state)
                new_tail[f"t{j}"] = nlc if nlc is not None else cache["tail"][f"t{j}"]
            new_cache["tail"] = new_tail
        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = layers.lm_head(params["embedding"], cfg, x)
        return constrain(logits, "logits"), new_cache

    def decode_step(self, params, cache, tokens):
        return self._step_with_cache(params, cache, tokens, want_state=False)

    def prefill(self, params, tokens, *, seq_len=None):
        cache = self.init_cache(tokens.shape[0], seq_len or tokens.shape[1])
        return self._step_with_cache(params, cache, tokens, want_state=True)
