"""Control-plane <-> data-plane coupling.

The data plane consumes FaaSKeeper exactly the way production fleets consume
ZooKeeper/etcd: ephemeral-znode membership, transactional checkpoint
manifests, watch-driven reconfiguration, heartbeat-based failure detection.
"""

from .membership import MembershipService, WorkerHandle
from .ckpt_coord import CoordinatedManifest
from .stragglers import StragglerDetector
from .serving_front import ServingFrontend

__all__ = [
    "CoordinatedManifest",
    "MembershipService",
    "ServingFrontend",
    "StragglerDetector",
    "WorkerHandle",
]
