"""Straggler detection via per-step progress znodes + the heartbeat function.

Each worker writes its step counter to ``/progress/<id>`` after every
training step (cheap: one conditional KV update, the paper's atomic-counter
primitive).  The scheduled heartbeat function — the same component the paper
uses to prune dead sessions — doubles as the straggler scanner: a worker
whose progress lags the median by more than ``lag_threshold`` steps is
flagged, and policy decides (re-dispatch its shard / drop-slowest / ignore).
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List

from ..core import FaaSKeeperService, NodeExistsError, NoNodeError

PROGRESS_DIR = "/progress"


@dataclass
class StragglerReport:
    median_step: float
    lagging: List[str]
    progress: Dict[str, int]


class StragglerDetector:
    def __init__(self, service: FaaSKeeperService, lag_threshold: int = 3):
        self.service = service
        self.lag_threshold = lag_threshold
        self.admin = service.connect_sync("straggler-admin")
        try:
            self.admin.create(PROGRESS_DIR, b"")
        except NodeExistsError:
            pass
        self._clients = {}

    def _client(self, worker_id: str):
        c = self._clients.get(worker_id)
        if c is None:
            c = self.service.connect_sync(f"progress:{worker_id}")
            self._clients[worker_id] = c
        return c

    # -- worker side -------------------------------------------------------------

    def report(self, worker_id: str, step: int) -> None:
        client = self._client(worker_id)
        path = f"{PROGRESS_DIR}/{worker_id}"
        payload = json.dumps({"step": step}).encode()
        try:
            client.set_data(path, payload)
        except NoNodeError:
            client.create(path, payload, ephemeral=True)

    # -- scanner (runs inside the scheduled heartbeat in production) ---------------

    def scan(self) -> StragglerReport:
        workers, _ = self.admin.get_children(PROGRESS_DIR)
        progress = {}
        for w in workers:
            try:
                data, _ = self.admin.get_data(f"{PROGRESS_DIR}/{w}")
                progress[w] = json.loads(data).get("step", 0)
            except NoNodeError:
                continue
        if not progress:
            return StragglerReport(0.0, [], {})
        steps = sorted(progress.values())
        median = steps[len(steps) // 2]
        lagging = [w for w, s in progress.items() if median - s > self.lag_threshold]
        return StragglerReport(float(median), lagging, progress)
