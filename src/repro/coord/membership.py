"""Worker membership via ephemeral znodes + watch-driven elastic re-meshing.

Every training worker holds a FaaSKeeper session and an *ephemeral* znode
under ``/cluster/members``; the paper's scheduled heartbeat function evicts
dead workers (their ephemeral disappears), and watches on the membership
directory push the change to every survivor, which triggers a re-mesh
(recompile with a smaller/larger device mesh) — elastic scaling with
ZooKeeper-grade consistency, from serverless parts only.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Callable, Dict, List

from ..core import FaaSKeeperService, NoNodeError, NodeExistsError

MEMBERS_DIR = "/cluster/members"
CONFIG_NODE = "/cluster/config"


@dataclass
class WorkerHandle:
    worker_id: str
    client: "SyncClient"  # noqa: F821
    path: str


class MembershipService:
    """One instance per process in the simulation; in production one per host."""

    def __init__(self, service: FaaSKeeperService):
        self.service = service
        self._joins: Dict[str, int] = {}   # per worker-id incarnation count
        self._bootstrap()

    def _bootstrap(self) -> None:
        admin = self.service.connect_sync("membership-admin")
        for path in ("/cluster", MEMBERS_DIR):
            try:
                admin.create(path, b"")
            except NodeExistsError:
                pass
        try:
            admin.create(CONFIG_NODE, json.dumps({"generation": 0}).encode())
        except NodeExistsError:
            pass
        self.admin = admin

    # -- worker lifecycle ---------------------------------------------------------

    def join(self, worker_id: str, capacity: Dict = None) -> WorkerHandle:
        # each join is a fresh FaaSKeeper session: a restart (or a takeover
        # while the predecessor is still live) must not collide with the old
        # incarnation's session id — only the *znode* name is stable
        n = self._joins.get(worker_id, 0) + 1
        self._joins[worker_id] = n
        sid = f"worker:{worker_id}" if n == 1 else f"worker:{worker_id}#{n}"
        client = self.service.connect_sync(sid)
        payload = json.dumps({"id": worker_id, **(capacity or {})}).encode()
        try:
            path = client.create(f"{MEMBERS_DIR}/{worker_id}", payload, ephemeral=True)
        except NodeExistsError:
            # stale ephemeral from a previous incarnation of this worker
            # (e.g. restart after crash, before the heartbeat evicted it):
            # take it over — delete + recreate under the new session.
            client.delete(f"{MEMBERS_DIR}/{worker_id}")
            path = client.create(f"{MEMBERS_DIR}/{worker_id}", payload, ephemeral=True)
        return WorkerHandle(worker_id, client, path)

    def leave(self, handle: WorkerHandle) -> None:
        try:
            handle.client.delete(handle.path)
        except NoNodeError:
            pass
        handle.client.close()

    def fail(self, handle: WorkerHandle) -> None:
        """Simulate a crash: stop answering heartbeats; the scheduled
        heartbeat function will evict the session and its ephemerals."""
        handle.client.client.failed = True

    # -- views ---------------------------------------------------------------------

    def members(self, watch: bool = False) -> List[str]:
        children, _ = self.admin.get_children(MEMBERS_DIR, watch=watch)
        return children

    def await_change(self, timeout: float = 600.0) -> List[str]:
        """Block (in virtual time) until the membership watch fires."""
        self.admin.wait_watch(MEMBERS_DIR, timeout=timeout)
        return self.members()

    # -- elastic re-mesh ------------------------------------------------------------

    def propose_mesh(self, n_workers: int, model_parallel: int) -> Dict:
        """Publish a new mesh generation; workers watch CONFIG_NODE."""
        data, stat = self.admin.get_data(CONFIG_NODE)
        gen = json.loads(data or b"{}").get("generation", 0) + 1
        dp = max(1, n_workers // model_parallel)
        cfgd = {"generation": gen, "mesh": [dp, model_parallel], "workers": n_workers}
        self.admin.set_data(CONFIG_NODE, json.dumps(cfgd).encode(), version=stat.version)
        return cfgd

    def current_mesh(self, watch: bool = False) -> Dict:
        data, _ = self.admin.get_data(CONFIG_NODE, watch=watch)
        return json.loads(data or b"{}")


def elastic_remesh_loop(membership: MembershipService, model_parallel: int,
                        on_remesh: Callable[[Dict], None], rounds: int = 1) -> List[Dict]:
    """Demo/integration driver: watch membership, republish mesh on change."""
    generations = []
    for _ in range(rounds):
        members = membership.await_change()
        cfgd = membership.propose_mesh(len(members), model_parallel)
        on_remesh(cfgd)
        generations.append(cfgd)
    return generations
