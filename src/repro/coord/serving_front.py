"""Serving frontend: per-session FIFO queues feeding one shared batcher.

Inference requests take the paper's write-request path — per-client session
FIFO queues with batched event-function invocation — but the decode slot is
now *cross-session*: every session queue routes into one shared dispatch
queue, so a model batch mixes arrivals from different sessions and the
per-invocation cost is amortized across clients (FaaSKeeper §4.2/§6: batching
occupancy is the cost lever; one queue per session can never batch across
arrivals).

Three batcher flavours behind the same queue plumbing:

* **whole-batch** (``model_fn``): one event-function invocation generates the
  full response for every request in its dispatch batch (works for any
  model, including enc-dec).
* **continuous** (``scheduler``): a :class:`repro.serve.DecodeScheduler`
  holds a fixed-width decode batch; the invocation admits its dispatch batch
  into free slots and, between decode steps, long-polls the dispatch queue
  (``FifoQueue.claim_pending``) to refill slots that free up — requests
  stream in and out of one running invocation.
* **fleet** (``fleet``): a :class:`repro.serve.FleetController` runs N
  disposable scheduler workers behind the same dispatch queue; the
  invocation ticks the controller (spawn on bursts, drain-and-park on idle,
  scale to zero) and bills each worker spawn as its own pay-per-invocation
  function start plus the parallel GB-seconds extra workers burn — the
  FaaSKeeper cost model applied to decode capacity.

Per-session FIFO survives both flavours: the dispatch queue is FIFO over
arrival order, whole-batch completes a batch atomically, and the scheduler
admits a session's next request only after its predecessor completes.
Delivery stays at-least-once: completions are deduped by request id, so a
crashed handler redelivers its batch without duplicating completions, and
claimed-but-unfinished messages are requeued.  ``mode='per-session'`` keeps
the old one-queue-per-session batcher as the cost baseline.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Generator, List, Optional


from ..core import FifoQueue, SimCloud
from ..core.cost import page_blob_op_cost, page_blob_retention_cost
from ..core.functions import (LAMBDA_GBS_PRICE, LAMBDA_INVOKE_PRICE,
                              FunctionRuntime)
from ..core.simcloud import Sleep

# per-worker billing identity in fleet mode: each spawn is a function start
# of its own (FaaSKeeper pay-per-invocation), kept separate from the "serve"
# controller invocation the dispatch queue triggers
WORKER_FN = "serve:worker"


@dataclass
class InferenceRequest:
    session: str
    request_id: str
    prompt: Any
    max_tokens: int = 8


def _ntokens(prompt: Any) -> int:
    return len(prompt) if hasattr(prompt, "__len__") else 1


class ServingFrontend:
    """Queue-fed batched inference over SimCloud.

    ``model_fn(prompts: list) -> list`` is the jitted decode/generate entry
    for the whole-batch flavour; ``scheduler`` (a ``DecodeScheduler``)
    selects the continuous flavour.  Compute is billed under the calibrated
    ``prefill`` / ``decode_step`` latency models (decode is
    weight-streaming-bound, so a batched step costs ~a batch-1 step — the
    economics batching exploits), so GB-second billing is deterministic and
    identical across flavours for the same token work.
    """

    def __init__(self, cloud: SimCloud,
                 model_fn: Optional[Callable[[List[Any]], List[Any]]] = None,
                 *, scheduler=None, fleet=None, batch_size: int = 4,
                 function_memory_mb: int = 2048, mode: str = "shared"):
        if model_fn is None and scheduler is None and fleet is None:
            raise ValueError("need model_fn (whole-batch), scheduler "
                             "(continuous) or fleet (elastic)")
        if fleet is not None and scheduler is not None:
            raise ValueError("fleet and scheduler flavours are exclusive "
                             "(the fleet owns its worker schedulers)")
        if mode not in ("shared", "per-session"):
            raise ValueError(f"unknown mode {mode!r}")
        if mode == "per-session" and (scheduler is not None
                                      or fleet is not None):
            raise ValueError("the per-session baseline has no shared scheduler")
        self.cloud = cloud
        self.model_fn = model_fn
        self.scheduler = scheduler
        self.fleet = fleet
        self.mode = mode
        self.runtime = FunctionRuntime(cloud, memory_mb=function_memory_mb)
        if fleet is not None:
            body = self._body_fleet
        elif scheduler is not None:
            body = self._body_continuous
        else:
            body = self._body_batch
        self._fn = self.runtime.wrap("serve", body)
        self.batch_size = batch_size
        self.queues: Dict[str, FifoQueue] = {}
        self.dispatch: Optional[FifoQueue] = None
        if mode == "shared":
            self.dispatch = FifoQueue(cloud, "serve:dispatch", handler=self._fn,
                                      batch_size=batch_size)
        self.results: Dict[str, List[Any]] = {}
        self.completions: Dict[str, List[str]] = {}
        self._done_ids: set = set()
        # KV offload storage accounting (continuous flavour, offload=True):
        # page-blob puts/gets drained from the scheduler and billed here
        self.offload_storage_usd = 0.0
        self.offload_storage_ops = 0
        # parked-session retention: blob bytes held between requests accrue
        # S3 GB-time at Table-4 rates (the other side of the re-prefill trade)
        self.park_storage_usd = 0.0
        self._retention_billed_at = cloud.now

    def queue_for(self, session: str) -> FifoQueue:
        q = self.queues.get(session)
        if q is None:
            handler = self._pipe if self.mode == "shared" else self._fn
            q = FifoQueue(self.cloud, f"serve:{session}", handler=handler,
                          batch_size=self.batch_size)
            self.queues[session] = q
        return q

    # -- client side ---------------------------------------------------------------

    def submit(self, req: InferenceRequest) -> Generator:
        yield from self.queue_for(req.session).push(
            {"session": req.session, "request_id": req.request_id,
             "prompt": req.prompt, "max_tokens": req.max_tokens},
            size_kb=0.5,
        )
        return req.request_id

    def submit_sync(self, req: InferenceRequest) -> str:
        return self.cloud.run_task(self.submit(req), name=f"submit:{req.request_id}")

    # -- routing (session queue -> shared dispatch) ----------------------------------

    def _pipe(self, batch) -> Generator:
        """Queue pipe, not a billed function: the session queue's trigger
        latency has already been paid, and the forward is an in-cloud push
        (EventBridge-pipe-style), so 'function invocations' stays the count
        of *model* invocations.  Zero wire latency, but the KB still count
        (the push_kb wire meter)."""
        for m in batch:
            self.dispatch.push_immediate(m.body, size_kb=m.size_kb)
        if False:
            yield
        return None

    # -- completion bookkeeping ------------------------------------------------------

    def _complete(self, session: str, request_id: str, out: Any) -> bool:
        """Record a completion exactly once (idempotent under redelivery)."""
        if request_id in self._done_ids:
            return False
        self._done_ids.add(request_id)
        self.results.setdefault(session, []).append(out)
        self.completions.setdefault(session, []).append(request_id)
        return True

    def dead_letter_ids(self) -> List[str]:
        """Requests lost to poison-batch drops, serving-plane-wide.

        A dead-lettered *message* whose request already completed (the
        at-least-once crash path: some attempts complete work before the
        batch exhausts retries) is not a lost request — filter those out.
        """
        qs = list(self.queues.values()) + ([self.dispatch] if self.dispatch else [])
        return [m.body.get("request_id", "?") for q in qs for m in q.dead_letters
                if m.body.get("request_id") not in self._done_ids]

    def dropped_requests(self) -> int:
        return len(self.dead_letter_ids())

    def serving_stats(self) -> Dict[str, Any]:
        """One merged stats dict for drivers/benchmarks: invocation counts
        and cost from the runtime, scheduler occupancy/token counters and —
        in paged mode — the KV pool gauges (pages in use / high water)."""
        st = self.runtime.stats.get("serve")
        if self.fleet is not None:
            mode = "fleet"
        elif self.scheduler is not None:
            mode = "continuous"
        else:
            mode = self.mode
        out: Dict[str, Any] = {
            "mode": mode,
            "invocations": st.invocations if st else 0,
            "cost_usd": self.runtime.cost_usd(),
            "dropped": self.dropped_requests(),
        }
        if self.fleet is not None:
            out.update(self.fleet.fleet_stats())
            wst = self.runtime.stats.get(WORKER_FN)
            out["worker_invocations"] = wst.invocations if wst else 0
            out["worker_cost_usd"] = (
                (wst.billed_seconds * LAMBDA_GBS_PRICE
                 + wst.invocations * LAMBDA_INVOKE_PRICE) if wst else 0.0)
            # the fleet always parks + journals — both storage meters apply
            out["offload_storage_usd"] = self.offload_storage_usd
            out["offload_storage_ops"] = self.offload_storage_ops
            out["park_storage_usd"] = self.park_storage_usd
        if self.scheduler is not None:
            out.update(self.scheduler.stats())
            out.update(self.scheduler.kv_memory_stats())
            sharing = (getattr(self.scheduler, "prefix_sharing", False)
                       or getattr(self.scheduler, "park_sessions", False))
            if getattr(self.scheduler, "offload", False) or sharing:
                # blob op spend covers preemption *and* parking traffic —
                # they share the store and the billing path
                out["offload_storage_usd"] = self.offload_storage_usd
                out["offload_storage_ops"] = self.offload_storage_ops
            if sharing:
                # the other side of the retention trade: the S3 GB-time for
                # keeping parked state durable between requests sits next to
                # shared_prefix_tokens (the prefill compute it avoided)
                out["park_storage_usd"] = self.park_storage_usd
        return out

    # -- KV offload billing ------------------------------------------------------

    def _bill_offload_ops(self) -> Generator:
        """Replay the scheduler's page-blob journal against the calibrated
        object-store latency models and Table-4 S3 op rates.  The blob data
        itself applied synchronously inside ``step()`` (a blocking S3
        client); what the cloud sees is the op's wire time and its bill.
        Parked/offloaded blob bytes additionally accrue S3 retention over
        simulated time — the storage side of the parking-vs-re-prefill
        trade.  In fleet mode the journal is the fleet's shared store —
        the same billing path covers every worker."""
        src = self.fleet if self.fleet is not None else self.scheduler
        now = self.cloud.now
        stored = src.blob_store.bytes_stored
        if stored and now > self._retention_billed_at:
            self.park_storage_usd += page_blob_retention_cost(
                stored * (now - self._retention_billed_at))
        self._retention_billed_at = now
        for op, _key, kb in src.drain_offload_ops():
            kind = "obj_read" if op == "get" else "obj_write"
            yield Sleep(self.cloud.sample(kind, kb))
            self.offload_storage_usd += page_blob_op_cost(op)
            self.offload_storage_ops += 1

    def _bill_worker_events(self) -> Generator:
        """Drain the fleet's lifecycle feed: every spawn is a pay-per-
        invocation function start (cold — a fleet spawn is a fresh
        container, that is the point of the warm-pool/billing split), so
        the fleet's elasticity shows up as invocation count + cold-start
        latency, not free capacity."""
        for ev in self.fleet.drain_events():
            if ev.kind == "spawn":
                st = self.runtime._stats(WORKER_FN)
                st.invocations += 1
                st.cold_starts += 1
                yield Sleep(self.cloud.sample("cold_start"))

    # -- event function: whole-batch flavour ------------------------------------------

    def _body_batch(self, ctx, batch) -> Generator:
        fresh = [m for m in batch if m.body["request_id"] not in self._done_ids]
        if not fresh:
            return None
        prompts = [m.body["prompt"] for m in fresh]
        outputs = self.model_fn(prompts)
        # billed compute under the calibrated serving model: one prefill over
        # the batch's prompt tokens, then one decode step per token the model
        # actually generated (falling back to the requested budget when the
        # outputs are opaque)
        yield Sleep(self.cloud.sample(
            "prefill", size_kb=sum(_ntokens(p) for p in prompts)))
        out_lens = [len(o) for o in outputs if hasattr(o, "__len__")]
        gen_steps = (max(out_lens) if out_lens
                     else max(m.body.get("max_tokens", 8) for m in fresh)) - 1
        for _ in range(gen_steps):
            yield Sleep(self.cloud.sample("decode_step", size_kb=len(fresh)))
        ctx.crash_point("post-model")
        # one storage-write-equivalent latency per batch (result persistence)
        yield Sleep(self.cloud.sample("kv_write", size_kb=1.0))
        for msg, out in zip(fresh, outputs, strict=True):
            body = msg.body
            self._complete(body["session"], body["request_id"], out)
            yield Sleep(self.cloud.sample("tcp_rtt"))
        return None

    # -- event function: continuous-batching flavour ----------------------------------

    def _body_continuous(self, ctx, batch) -> Generator:
        sched = self.scheduler
        claimed: List[Any] = []

        def feed(msgs):
            for m in msgs:
                b = m.body
                if b["request_id"] in self._done_ids:
                    continue
                sched.submit(b["session"], b["request_id"], b["prompt"],
                             b.get("max_tokens", 8))

        billed_prefill = sched.prefill_tokens
        try:
            feed(batch)
            while sched.busy():
                prev_slot_steps = sched.slot_steps
                finished = sched.step()
                # bill what actually decoded inside this step (a slot whose
                # last prefill chunk landed mid-step joins the same tick)
                active = sched.slot_steps - prev_slot_steps
                if sched.prefill_tokens > billed_prefill:
                    # admissions billed per landed chunk (paged) or per
                    # monolithic prefill (ring) — same token total either way
                    yield Sleep(self.cloud.sample(
                        "prefill", size_kb=sched.prefill_tokens - billed_prefill))
                    billed_prefill = sched.prefill_tokens
                if active:
                    yield Sleep(self.cloud.sample("decode_step", size_kb=active))
                yield from self._bill_offload_ops()
                for fin in finished:
                    self._complete(fin.session, fin.request_id, fin.tokens)
                    yield Sleep(self.cloud.sample("kv_write", size_kb=0.5))
                    yield Sleep(self.cloud.sample("tcp_rtt"))
                if finished:
                    ctx.crash_point("post-complete")
                # continuous batching: refill freed slots from arrivals that
                # queued up while this invocation was decoding; keep claiming
                # past head-of-line requests whose session is still active
                # (they hold back in the scheduler's FIFO pending list)
                while sched.wants_more():
                    extra = self.dispatch.claim_pending(sched.free_slots())
                    if not extra:
                        break
                    claimed.extend(extra)
                    feed(extra)
            yield from self._bill_offload_ops()   # tail ops of the last step
        except BaseException:
            # crash: the queue redelivers the original batch; hand back the
            # claimed messages and abort in-flight slots — completions
            # already recorded stay recorded (dedup makes redelivery safe)
            sched.reset()
            self.dispatch.requeue(
                [m for m in claimed if m.body["request_id"] not in self._done_ids])
            raise
        return None

    # -- event function: elastic-fleet flavour -----------------------------------------

    def _body_fleet(self, ctx, batch) -> Generator:
        """Continuous batching over the elastic fleet: the invocation ticks
        the controller until the queue is drained, then keeps ticking an
        idle cooldown so the autoscaler can drain-and-park down to its floor
        (scale-to-zero happens *inside* the serving path, between bursts).

        Billing: prefill/decode token work is billed once off the fleet's
        monotone aggregates (identical token work to the solo flavour —
        parity is what the differential harness pins), while each extra
        worker decoding in the same tick accrues its *own* GB-seconds (N
        workers each stream their own weights; wall time is one step, the
        bill is N) plus a per-spawn invocation + cold start via
        ``_bill_worker_events``."""
        fleet = self.fleet
        claimed: List[Any] = []

        def feed(msgs):
            for m in msgs:
                b = m.body
                if b["request_id"] in self._done_ids:
                    continue
                fleet.submit(b["session"], b["request_id"], b["prompt"],
                             b.get("max_tokens", 8))

        billed_prefill = fleet.prefill_tokens()
        try:
            feed(batch)
            while fleet.busy():
                prev_steps = fleet.slot_steps()
                finished = fleet.step()
                yield from self._bill_worker_events()
                pf = fleet.prefill_tokens()
                if pf > billed_prefill:
                    yield Sleep(self.cloud.sample(
                        "prefill", size_kb=pf - billed_prefill))
                    billed_prefill = pf
                active = fleet.slot_steps() - prev_steps
                if active:
                    dt = self.cloud.sample("decode_step", size_kb=active)
                    yield Sleep(dt)
                    extra = max(0, fleet.last_decoded_workers - 1)
                    if extra:
                        st = self.runtime._stats(WORKER_FN)
                        st.billed_seconds += (
                            dt * extra * (self.runtime.memory_mb / 1024.0))
                yield from self._bill_offload_ops()
                for fin in finished:
                    self._complete(fin.session, fin.request_id, fin.tokens)
                    yield Sleep(self.cloud.sample("kv_write", size_kb=0.5))
                    yield Sleep(self.cloud.sample("tcp_rtt"))
                if finished:
                    ctx.crash_point("post-complete")
                while fleet.wants_more():
                    extra_msgs = self.dispatch.claim_pending(fleet.free_slots())
                    if not extra_msgs:
                        break
                    claimed.extend(extra_msgs)
                    feed(extra_msgs)
            # idle cooldown: tick until the autoscaler has drained to its
            # floor (bounded — a wedged worker waits for heartbeat eviction,
            # which happens outside this invocation)
            floor = (fleet.min_workers if fleet.scale_to_zero
                     else max(fleet.min_workers, 1))
            budget = fleet.drain_idle_steps + 2 * fleet.max_workers + 4
            while (budget and fleet.live_workers() > floor
                   and not fleet.busy()):
                fleet.step()
                budget -= 1
                yield from self._bill_worker_events()
                yield from self._bill_offload_ops()
            yield from self._bill_worker_events()
            yield from self._bill_offload_ops()   # tail ops of the last step
        except BaseException:
            # controller crash: the workers die with the invocation —
            # fail-stop each one (requeue + GC, durable metas survive) and
            # hand claimed messages back for redelivery
            fleet.abort()
            self.dispatch.requeue(
                [m for m in claimed if m.body["request_id"] not in self._done_ids])
            raise
        return None
