"""Serving frontend = the paper's queue/batcher, reused verbatim.

Inference requests take the exact path the paper built for write requests:
per-client session FIFO queues -> batched event-function invocation (the
"writer" slot is filled by the model's decode step) -> results pushed back on
the client channel, completions ordered per session.  Batching, FIFO order,
single-instance concurrency, and retry semantics all come from core/queues.py
unchanged — demonstrating the paper's claim that its components are generic
serverless building blocks, not ZooKeeper-specific.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Generator, List


from ..core import FifoQueue, SimCloud
from ..core.functions import FunctionRuntime
from ..core.simcloud import Sleep


@dataclass
class InferenceRequest:
    session: str
    request_id: str
    prompt: Any
    max_tokens: int = 8


class ServingFrontend:
    """Queue-fed batched inference over SimCloud.

    ``model_fn(prompts: list) -> list`` is the jitted decode/generate entry;
    its (real) wall time is folded into the simulated function runtime so the
    cost accounting stays meaningful.
    """

    def __init__(self, cloud: SimCloud, model_fn: Callable[[List[Any]], List[Any]],
                 batch_size: int = 10, function_memory_mb: int = 2048):
        self.cloud = cloud
        self.model_fn = model_fn
        self.runtime = FunctionRuntime(cloud, memory_mb=function_memory_mb)
        self._fn = self.runtime.wrap("serve", self._body)
        self.queues: Dict[str, FifoQueue] = {}
        self.batch_size = batch_size
        self.results: Dict[str, List[Any]] = {}
        self.completions: Dict[str, List[str]] = {}

    def queue_for(self, session: str) -> FifoQueue:
        q = self.queues.get(session)
        if q is None:
            q = FifoQueue(self.cloud, f"serve:{session}", handler=self._fn,
                          batch_size=self.batch_size)
            self.queues[session] = q
        return q

    # -- client side ---------------------------------------------------------------

    def submit(self, req: InferenceRequest) -> Generator:
        yield from self.queue_for(req.session).push(
            {"session": req.session, "request_id": req.request_id,
             "prompt": req.prompt, "max_tokens": req.max_tokens},
            size_kb=0.5,
        )
        return req.request_id

    def submit_sync(self, req: InferenceRequest) -> str:
        return self.cloud.run_task(self.submit(req), name=f"submit:{req.request_id}")

    # -- event function (the 'writer' of the serving plane) --------------------------

    def _body(self, ctx, batch) -> Generator:
        prompts = [m.body["prompt"] for m in batch]
        outputs = self.model_fn(prompts)
        # one storage-write-equivalent latency per batch (result persistence)
        yield Sleep(self.cloud.sample("kv_write", size_kb=1.0))
        for msg, out in zip(batch, outputs):
            body = msg.body
            self.results.setdefault(body["session"], []).append(out)
            self.completions.setdefault(body["session"], []).append(body["request_id"])
            yield Sleep(self.cloud.sample("tcp_rtt"))
        return None
