"""Transactional checkpoint manifests through FaaSKeeper.

The bulk tensor shards go to the object store (checkpoint/store.py); the
*manifest* is committed as a FaaSKeeper write, which makes the checkpoint
atomic and totally ordered (txid): a restart issues one strongly consistent
read of ``/ckpt/latest`` and never observes a half-written checkpoint —
exactly the paper's atomicity guarantee (Appendix B-A) applied to training
state.  This is the "most representative of the paper's technique" coupling:
writer lock -> validate -> distributor replicate -> commit, with the
manifest as the znode payload.
"""

from __future__ import annotations

import json
from typing import Dict, Optional

from ..core import FaaSKeeperService, NodeExistsError

CKPT_DIR = "/ckpt"
LATEST = "/ckpt/latest"


class CoordinatedManifest:
    """Drop-in (committer, latest_resolver) pair for CheckpointStore."""

    def __init__(self, service: FaaSKeeperService, job: str = "job0"):
        self.client = service.connect_sync(f"ckpt:{job}")
        for path in (CKPT_DIR,):
            try:
                self.client.create(path, b"")
            except NodeExistsError:
                pass
        try:
            self.client.create(LATEST, json.dumps({"step": None}).encode())
        except NodeExistsError:
            pass

    # CheckpointStore committer hook: atomic manifest publish.
    def commit(self, step: int, manifest: Dict) -> None:
        payload = json.dumps({"step": step, "n_leaves": len(manifest["leaves"])}).encode()
        # per-step manifest node (historical record, totally ordered by txid)
        self.client.create(f"{CKPT_DIR}/step_{step:08d}",
                           json.dumps(manifest).encode())
        # move the 'latest' pointer — single atomic znode update
        self.client.set_data(LATEST, payload)

    # CheckpointStore latest_resolver hook: strongly consistent read.
    def latest(self) -> Optional[int]:
        data, _ = self.client.get_data(LATEST)
        return json.loads(data or b"{}").get("step")

    def manifest_for(self, step: int) -> Dict:
        data, _ = self.client.get_data(f"{CKPT_DIR}/step_{step:08d}")
        return json.loads(data)

    def history(self):
        children, _ = self.client.get_children(CKPT_DIR)
        return sorted(c for c in children if c.startswith("step_"))
