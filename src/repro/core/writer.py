"""The writer event function — paper Algorithm 1.

FaaSKeeper replaces ZooKeeper's single elected leader with *concurrent* writer
functions, one per session queue (concurrency limit 1 per queue keeps session
FIFO order; different sessions proceed in parallel).  Per request:

  1. LOCK       — timed-lock the target node (and the parent for create/
                  delete: multi-node transaction, §4.2),
  2. ISVALID    — validate against the locked snapshot; on failure NOTIFY
                  the client and continue,
  3. DISTRIBUTORPUSH — push the outcome to the distributor queue; the queue's
                  monotone sequence number *is* the transaction id (txid),
  4. COMMITUNLOCK — apply the mutation to system storage and release the
                  lock in one conditional update (fenced on the lease
                  timestamp: "no changes are made if the lock expires").

Crash points between every step model Lambda failures; the distributor's
TryCommit (Alg. 2 step 2) completes or rejects half-done requests.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, List, Optional

from . import znode
from .primitives import Lock, Primitives
from .queues import FifoQueue, Message
from .simcloud import Sleep
from .storage import KVStore

STATE = "state"
LOCK_RETRIES = 40
LOCK_BACKOFF = 0.02


class WriterCore:
    """Shared by the per-session event writer functions."""

    def __init__(self, kv: KVStore, prim: Primitives, distributor_queue: FifoQueue, notify):
        self.kv = kv
        self.prim = prim
        self.distq = distributor_queue
        self.notify = notify  # (session, payload) -> Generator

    # -- helpers ---------------------------------------------------------------

    def _acquire(self, path: str, cloud) -> Generator:
        """Timed-lock with bounded retry (lease expiry bounds the wait).

        ``cloud.now`` is re-read per attempt: a crashed holder's lease ages
        out against *current* time, so a redelivered batch can reclaim the
        lock once MAX_LOCK_TIME passes."""
        for attempt in range(LOCK_RETRIES):
            lock, item = yield from self.prim.lock_acquire(
                znode.node_key(path), cloud.now)
            if lock is not None:
                return lock, item
            yield Sleep(LOCK_BACKOFF * (1 + attempt))
        raise RuntimeError(f"lock starvation on {path}")

    # -- Algorithm 1 --------------------------------------------------------------

    def handle_batch(self, ctx, batch: List[Message]) -> Generator:
        for msg in batch:
            req = msg.body
            yield from self.handle_request(ctx, req)
        return None

    def handle_request(self, ctx, req: Dict[str, Any]) -> Generator:
        op: str = req["op"]
        args: Dict[str, Any] = dict(req["args"])
        session: str = req["session"]
        request_id = req["request_id"]

        if op == "deregister_session":
            yield from self._deregister(ctx, req)
            return None

        path: str = args["path"]
        parent = znode.parent_path(path)
        needs_parent = op in ("create", "delete") and path != "/"

        # (1) LOCK — parent first (stable order prevents deadlock), then node.
        t_start = ctx.cloud.now
        locks: Dict[str, Lock] = {}
        parent_item: Optional[Dict[str, Any]] = None
        if needs_parent:
            plock, parent_item = yield from self._acquire(parent, ctx.cloud)
            locks[parent] = plock
        ctx.crash_point("after_parent_lock")

        if op == "create" and args.get("sequence"):
            # resolve sequential suffix under the parent lock (cseq is stable)
            cseq = (parent_item or {}).get("cseq", 0)
            path = znode.sequential_name(path, cseq)
            args["path"] = path

        nlock, node_item = yield from self._acquire(path, ctx.cloud)
        locks[path] = nlock
        ctx.cloud.record("writer_lock", ctx.cloud.now - t_start)
        ctx.crash_point("after_lock")

        # Exactly-once guard: the commit transaction records request_id ->
        # txid (atomically).  On an at-least-once redelivery after a crash,
        # an already-committed request is skipped here — without this, a
        # writer crash between DISTRIBUTORPUSH and batch completion would
        # re-apply the op under a fresh txid.
        dedup = yield from self.kv.get("dedup", session)
        if dedup is not None and request_id in dedup.get("done", {}):
            yield from self._release_all(locks)
            return None

        # (2) ISVALID — against the locked snapshot.
        err = znode.validate_op(op, args, node_item, parent_item)
        if err is not None:
            yield from self._release_all(locks)
            yield from self.notify(
                session,
                {"kind": "result", "request_id": request_id, "ok": False, "code": err},
            )
            return None
        ctx.crash_point("after_validate")

        # (3) DISTRIBUTORPUSH — sequence number is the global txid.  The
        # update carries the *pre-state* snapshots taken under the locks;
        # materialization is deterministic, so writer-commit, TryCommit and
        # every regional DATAUPDATE apply identical transitions.
        update = {
            "session": session,
            "request_id": request_id,
            "op": op,
            "args": args,
            "path": path,
            "parent": parent if needs_parent else None,
            "node_pre": node_item,
            "parent_pre": parent_item,
            "locks": {p: l.timestamp for p, l in locks.items()},
        }
        t_push = ctx.cloud.now
        txid = yield from self.distq.push(update, size_kb=0.25 + _data_kb(args))
        ctx.cloud.record("writer_push", ctx.cloud.now - t_push)
        ctx.crash_point("after_push")

        # (4) COMMITUNLOCK — fenced multi-item transaction (includes the
        # dedup marker and ephemeral-ownership bookkeeping atomically).
        t_commit = ctx.cloud.now
        committed = yield from commit_unlock(self.kv, update, txid)
        ctx.cloud.record("writer_commit", ctx.cloud.now - t_commit)
        ctx.cloud.record("writer_total", ctx.cloud.now - t_start)
        ctx.crash_point("after_commit")
        if not committed:
            # Either the distributor's TryCommit beat us (routine race — it
            # will notify SUCCESS), or the lease truly expired and nobody
            # committed.  Distinguish via the dedup marker, which commits
            # atomically with the transaction.
            dedup2 = yield from self.kv.get("dedup", session)
            if dedup2 is None or request_id not in dedup2.get("done", {}):
                yield from self.notify(
                    session,
                    {"kind": "result", "request_id": request_id, "ok": False,
                     "code": "lost_lease", "txid": txid},
                )
        return None

    def _release_all(self, locks: Dict[str, Lock]) -> Generator:
        for path, lock in locks.items():
            yield from self.prim.lock_release(znode.node_key(path), lock)
        return None

    # -- session eviction (heartbeat path) ---------------------------------------

    def _deregister(self, ctx, req: Dict[str, Any]) -> Generator:
        """Evict a session: delete its ephemerals (full write path), mark dead."""
        target = req["args"]["target_session"]
        sess = yield from self.kv.get("sessions", target)
        if sess is None or not sess.get("alive", False):
            return None
        ephemerals = sorted(sess.get("ephemerals", []))
        for path in ephemerals:
            sub = {
                "op": "delete",
                "args": {"path": path, "version": -1},
                "session": req["session"],
                "request_id": f"{req['request_id']}:evict:{path}",
            }
            yield from self.handle_request(ctx, sub)
        ctx.crash_point("after_evict_deletes")

        def update(item: Dict[str, Any]) -> None:
            item["alive"] = False
            item["ephemerals"] = []

        yield from self.kv.update("sessions", target, update)
        return None


def _data_kb(args: Dict[str, Any]) -> float:
    data = args.get("data", b"")
    return (len(data) if isinstance(data, (bytes, str)) else 0) / 1024.0


def _system_view(node_post: Dict[str, Any]) -> Dict[str, Any]:
    """System-store node items hold METADATA ONLY (paper Table 3: writer lock
    and commit stay ~8 ms even for 250 kB writes — the payload travels
    client -> queue -> distributor -> user store, never through DynamoDB).
    The conditional-update latency growth with item size (Table 6a) is
    exactly why the paper disaggregates this."""
    view = dict(node_post)
    data = view.pop("data", b"")
    view["data_len"] = len(data) if isinstance(data, (bytes, str)) else 0
    return view


# --------------------------------------------------------------------------
# Commit application — shared verbatim by writer (step 4) and the
# distributor's TryCommit so both produce identical state transitions.
# --------------------------------------------------------------------------


def commit_unlock(kv: KVStore, update: Dict[str, Any], txid: int) -> Generator:
    """Apply ``update`` to system storage + release locks, all-or-nothing.

    Conditional on every lease timestamp still being ours (fencing).  Appends
    ``txid`` to the node's pending ``transactions`` — that is the commit
    marker the distributor checks.  Returns True iff committed.
    """
    op = update["op"]
    args = update["args"]
    path = update["path"]
    parent = update["parent"]
    locks: Dict[str, float] = update["locks"]
    node_post, parent_post = znode.materialize(
        op, args, update.get("node_pre"), update.get("parent_pre"), txid
    )

    def node_cond(item: Dict[str, Any]) -> bool:
        return item.get("lock_ts") == locks[path]

    def node_update(item: Dict[str, Any]) -> None:
        txs = item.get("transactions", [])
        item.clear()
        item.update(_system_view(node_post))
        item["transactions"] = txs + [txid]
        item["lock_ts"] = None

    items = [(STATE, znode.node_key(path), node_update, node_cond)]

    if parent is not None:

        def parent_cond(item: Dict[str, Any]) -> bool:
            return item.get("lock_ts") == locks[parent]

        def parent_update(item: Dict[str, Any]) -> None:
            txs = item.get("transactions", [])
            item.clear()
            item.update(_system_view(parent_post))
            item["transactions"] = txs
            item["lock_ts"] = None

        items.append((STATE, znode.node_key(parent), parent_update, parent_cond))

    # exactly-once marker (see WriterCore.handle_request)
    session = update["session"]
    request_id = update["request_id"]

    def dedup_update(item: Dict[str, Any]) -> None:
        done = item.setdefault("done", {})
        order = item.setdefault("order", [])
        done[request_id] = txid
        order.append(request_id)
        while len(order) > 128:
            done.pop(order.pop(0), None)

    items.append(("dedup", session, dedup_update, None))

    # ephemeral-ownership bookkeeping, atomic with the commit
    if op == "create" and args.get("ephemeral"):

        def eph_add(item: Dict[str, Any]) -> None:
            eph = item.setdefault("ephemerals", [])
            if path not in eph:
                eph.append(path)

        items.append(("sessions", session, eph_add, None))
    elif op == "delete":
        owner = (update.get("node_pre") or {}).get("ephemeral_owner")
        if owner:

            def eph_rm(item: Dict[str, Any]) -> None:
                eph = item.setdefault("ephemerals", [])
                if path in eph:
                    eph.remove(path)

            items.append(("sessions", owner, eph_rm, None))

    from .simcloud import ConditionFailed

    try:
        yield from kv.transact(items)
        return True
    except ConditionFailed:
        return False
