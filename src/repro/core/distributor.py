"""The distributor event function — paper Algorithm 2.

A single FIFO-serialized distributor (concurrency 1 on the distributor queue)
replays committed transactions, in txid order, onto every regional user data
store, fans out watch notifications, and maintains the *epoch* counter that
keeps the disjoint read path consistent with the notification path:

  per update (client, lock, node, data, txid):
    1. GETNODE; if txid is not the node's next pending transaction,
       TryCommit on the writer's behalf (writer may have crashed between
       DISTRIBUTORPUSH and COMMITUNLOCK); reject -> NOTIFY(FAILURE),
    2. DATAUPDATE(region, data, txid, epoch) for every region, in parallel
       across regions, serialized within one,
    3. consume triggered watch instances; append (watch_id, txid) pairs to
       each region's epoch list *before* any later transaction's DATAUPDATE
       can be written (the distributor is serialized, so order holds),
    4. INVOKEWATCH — free functions deliver notifications in parallel,
    5. NOTIFY(client, SUCCESS),
    6. POPTRANSACTION — removes txid from the node's pending list; from here
       on the queue retry no longer redoes this update,
    WAITALL(watch callbacks) — each callback removes its epoch pair.

Every step is idempotent (epoch pairs, guarded pops, whole-object PUTs), so
at-least-once queue retries preserve exactly-once *effects*.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, List, Optional, Tuple

from . import znode
from .primitives import Primitives
from .queues import Message
from .simcloud import Task, Wait
from .storage import KVStore, ObjectStore
from .watches import WatchRegistry, triggered_watches
from .writer import STATE, commit_unlock


def epoch_key(region: str) -> str:
    return f"epoch:{region}"


class DistributorCore:
    def __init__(
        self,
        kv: KVStore,
        prim: Primitives,
        watches: WatchRegistry,
        data_stores: Dict[str, ObjectStore],
        notify,  # (session, payload) -> Generator
        invoke_watch_fn,  # (region, watch_id, clients, event) -> Task
    ):
        self.kv = kv
        self.prim = prim
        self.watches = watches
        self.data_stores = data_stores
        self.notify = notify
        self.invoke_watch_fn = invoke_watch_fn

    # -- Algorithm 2 -----------------------------------------------------------

    def handle_batch(self, ctx, batch: List[Message]) -> Generator:
        # Function-instance state: epoch cache read once per invocation.
        epochs: Dict[str, List[List[int]]] = {}
        for region in self.data_stores:
            epochs[region] = yield from self.prim.list_get(epoch_key(region))
        watch_tasks: List[Task] = []

        for msg in batch:
            update = msg.body
            txid = msg.seq
            yield from self.handle_update(ctx, update, txid, epochs, watch_tasks)

        # WAITALL(WATCHCALLBACK)
        yield Wait(tuple(watch_tasks))
        return None

    def handle_update(
        self,
        ctx,
        update: Dict[str, Any],
        txid: int,
        epochs: Dict[str, List[List[int]]],
        watch_tasks: List[Task],
    ) -> Generator:
        session = update["session"]
        request_id = update["request_id"]
        op = update["op"]
        path = update["path"]
        parent = update["parent"]

        # (1) verify the writer committed this txid.
        t_start = ctx.cloud.now
        node = yield from self.kv.get(STATE, znode.node_key(path))
        ctx.cloud.record("dist_get_node", ctx.cloud.now - t_start)
        ctx.crash_point("after_getnode")
        pending = [] if node is None else node.get("transactions", [])
        if txid not in pending:
            already = node is not None and node.get("modified_txid", 0) >= txid
            if already:
                # Retried batch, pop already happened — effects are complete.
                return None
            # Writer crashed before COMMITUNLOCK: try to commit on its behalf.
            committed = yield from commit_unlock(self.kv, update, txid)
            ctx.crash_point("after_trycommit")
            if not committed:
                # The fence can fail because the *writer's own* commit landed
                # between our GETNODE and the TryCommit (writer pushes before
                # committing, so this race is routine).  Re-read: if the txid
                # is in fact committed, continue distributing; only a provably
                # uncommitted update is rejected.  (The writer's commit is
                # fenced on the same lease timestamp, so once the fence moved
                # on, no late writer commit can slip in after this re-read.)
                node2 = yield from self.kv.get(STATE, znode.node_key(path))
                pending2 = [] if node2 is None else node2.get("transactions", [])
                done2 = node2 is not None and node2.get("modified_txid", 0) >= txid
                if txid not in pending2 and not done2:
                    yield from self.notify(
                        session,
                        {"kind": "result", "request_id": request_id, "ok": False,
                         "code": "commit_failed", "txid": txid},
                    )
                    return None

        # (2) DATAUPDATE — replicate the *pushed* update (never a fresh read
        # of the system store, which may already contain later pending
        # transactions) to each region's user store.
        node_post, parent_post = znode.materialize(
            op, update["args"], update.get("node_pre"), update.get("parent_pre"), txid
        )
        t_upd = ctx.cloud.now
        for region, store in self.data_stores.items():
            yield from self._data_update(store, node_post, parent_post, op, path, txid, epochs[region])
        ctx.cloud.record("dist_update_node", ctx.cloud.now - t_upd)
        ctx.crash_point("after_dataupdate")

        # (3) consume triggered watches; extend epoch lists.
        t_watch = ctx.cloud.now
        notifications: List[Tuple[str, int, List[str], Dict[str, Any]]] = []
        for wtype, wpath, event in triggered_watches(op, path, parent or znode.parent_path(path)):
            wid, clients = yield from self.watches.fetch_and_consume(wtype, wpath)
            if wid is not None and clients:
                notifications.append(
                    (wtype, wid, clients,
                     {"kind": "watch", "watch_id": wid, "path": wpath,
                      "event": event, "txid": txid})
                )
        for region in self.data_stores:
            pairs = [[wid, txid] for _, wid, _, _ in notifications]
            new_pairs = [p for p in pairs if p not in epochs[region]]
            if new_pairs:
                epochs[region] = yield from self.prim.list_append(epoch_key(region), new_pairs)
        ctx.cloud.record("dist_watch_query", ctx.cloud.now - t_watch)
        ctx.crash_point("after_epoch_add")

        # (4) INVOKEWATCH — parallel free functions; the callback removes the
        # epoch pair once every client got the notification (WATCHCALLBACK).
        for region in self.data_stores:
            for _, wid, clients, payload in notifications:
                task = self.invoke_watch_fn(region, wid, clients, payload, txid)
                watch_tasks.append(task)
        ctx.crash_point("after_invoke")

        # (5) NOTIFY(client, SUCCESS)
        yield from self.notify(
            session,
            {"kind": "result", "request_id": request_id, "ok": True,
             "txid": txid, "path": path,
             "version": node_post.get("version", 0)},
        )
        ctx.crash_point("after_notify")

        # (6) POPTRANSACTION — idempotent removal.
        def pop(item: Dict[str, Any]) -> None:
            txs = item.setdefault("transactions", [])
            if txid in txs:
                txs.remove(txid)

        yield from self.kv.update(STATE, znode.node_key(path), pop, size_kb=0.05)
        ctx.cloud.record("dist_total", ctx.cloud.now - t_start)
        ctx.crash_point("after_pop")
        return None

    # -- user-store replication ---------------------------------------------------

    def _data_update(
        self,
        store: ObjectStore,
        node_post: Dict[str, Any],
        parent_post: Optional[Dict[str, Any]],
        op: str,
        path: str,
        txid: int,
        epoch: List[List[int]],
    ) -> Generator:
        """Whole-object PUTs (S3 semantics — no partial updates, §4.3).

        For create/delete the parent object is rewritten too; S3's lack of
        partial updates forces the full-object rewrite the paper calls out
        ("the distributor function needs to download user node data to
        conduct the update operation" — here the pre-state travelled in the
        queue message, trading queue bytes for the S3 GET).
        """
        if op == "delete":
            yield from store.delete(path)
        else:
            yield from store.put(path, _user_object(node_post, epoch))
        if parent_post is not None and parent_post.get("exists"):
            # S3 cannot update children in place: download the parent object,
            # merge the child-list change, re-upload whole ("even if a change
            # involves only the node's children, the distributor function
            # needs to download user node data", §4.3).  The system store
            # holds metadata only, so the payload must come from this GET.
            existing = yield from store.get(parent_post["path"])
            merged = _user_object(parent_post, epoch)
            if existing is not None:
                merged["data"] = existing.get("data", b"")
            yield from store.put(parent_post["path"], merged)
        return None


def _user_object(node: Dict[str, Any], epoch: List[List[int]]) -> Dict[str, Any]:
    return {
        "path": node["path"],
        "data": node.get("data", b""),
        "version": node.get("version", 0),
        "cversion": node.get("cversion", 0),
        "created_txid": node.get("created_txid", 0),
        "modified_txid": node.get("modified_txid", 0),
        "children": list(node.get("children", [])),
        "ephemeral_owner": node.get("ephemeral_owner"),
        "epoch": [list(p) for p in epoch],
    }
