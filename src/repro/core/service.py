"""FaaSKeeper service wiring (paper Fig. 4/5, Table 2 mapping).

Components:
  * system store        — KVStore  ("DynamoDB tables": state, sessions, watch)
  * user data stores    — ObjectStore per region ("S3 buckets")
  * session queues      — one FIFO queue per session -> writer event function
  * distributor queue   — single FIFO queue -> distributor event function
                          (its sequence numbers are the global txids)
  * watch function      — free function fanning out notifications
  * heartbeat function  — scheduled
"""

from __future__ import annotations

from typing import Any, Dict, Generator, List, Optional

from .client import FaaSKeeperClient, SyncClient
from .distributor import DistributorCore, epoch_key
from .functions import FunctionRuntime
from .heartbeat import HeartbeatCore
from .primitives import Primitives
from .queues import FifoQueue
from .simcloud import SimCloud, Sleep, Task
from .storage import KVStore, ObjectStore
from .watches import WatchRegistry
from .writer import WriterCore

SYSTEM_SESSION = "system"


class FaaSKeeperService:
    def __init__(
        self,
        cloud: SimCloud,
        regions: tuple = ("region-0",),
        function_memory_mb: int = 2048,
        heartbeat_period: float = 60.0,
        heartbeat_timeout: float = 1.0,
        queue_batch_size: int = 10,
        max_lock_time: float = 5.0,
    ):
        self.cloud = cloud
        self.kv = KVStore(cloud, "system")
        self.data_stores: Dict[str, ObjectStore] = {
            r: ObjectStore(cloud, name=f"data-{r}", region=r) for r in regions
        }
        self.prim = Primitives(self.kv, max_lock_time=max_lock_time)
        self.watches = WatchRegistry(self.kv, self.prim)
        self.runtime = FunctionRuntime(cloud, memory_mb=function_memory_mb)
        self.heartbeat_timeout = heartbeat_timeout
        self.heartbeat_period = heartbeat_period
        self.queue_batch_size = queue_batch_size

        self.clients: Dict[str, FaaSKeeperClient] = {}
        self.session_queues: Dict[str, FifoQueue] = {}

        # distributor pipeline
        self.distq = FifoQueue(
            cloud, "distributor", batch_size=queue_batch_size, trigger_kind="fifo_trigger"
        )
        self.writer_core = WriterCore(self.kv, self.prim, self.distq, self._notify)
        self.dist_core = DistributorCore(
            self.kv, self.prim, self.watches, self.data_stores,
            self._notify, self._invoke_watch,
        )
        self._writer_fn = self.runtime.wrap("writer", self.writer_core.handle_batch)
        self._dist_fn = self.runtime.wrap("distributor", self.dist_core.handle_batch)
        self._watch_fn = self.runtime.wrap("watch", self._watch_body)
        self.heartbeat_core = HeartbeatCore(self)
        self._heartbeat_fn = self.runtime.wrap("heartbeat", self.heartbeat_core.body)
        self.distq.set_handler(self._dist_fn)

        # bootstrap: root node + epoch counters + system session
        root = _root_node()
        self.kv._apply_put("state", "node:/", root)
        for r in regions:
            self.kv._apply_put("state", epoch_key(r), {"items": []})
            self.data_stores[r].objects["/"] = {
                "path": "/", "data": b"", "version": 0, "cversion": 0,
                "created_txid": 0, "modified_txid": 0, "children": [],
                "ephemeral_owner": None, "epoch": [],
            }
        self.kv._apply_put("sessions", SYSTEM_SESSION, {"alive": True, "ephemerals": []})

    # -- sessions -------------------------------------------------------------------

    def session_queue(self, session_id: str) -> FifoQueue:
        q = self.session_queues.get(session_id)
        if q is None:
            q = FifoQueue(
                self.cloud, f"writer:{session_id}",
                handler=self._writer_fn, batch_size=self.queue_batch_size,
            )
            self.session_queues[session_id] = q
        return q

    def register_client(self, client: FaaSKeeperClient) -> None:
        self.clients[client.session_id] = client
        self.session_queue(client.session_id)

    def make_client(self, session_id: str, region: Optional[str] = None) -> FaaSKeeperClient:
        region = region or next(iter(self.data_stores))
        return FaaSKeeperClient(self, session_id, region)

    def connect_sync(self, session_id: str, region: Optional[str] = None) -> SyncClient:
        client = self.make_client(session_id, region)
        self.cloud.run_task(client.connect(), name=f"connect:{session_id}")
        return SyncClient(client)

    def enqueue_deregistration(self, session_id: str) -> Generator:
        req = {
            "op": "deregister_session",
            "args": {"target_session": session_id},
            "session": SYSTEM_SESSION,
            "request_id": f"evict:{session_id}:{self.cloud.now:.6f}",
        }
        yield from self.session_queue(SYSTEM_SESSION).push(req, size_kb=0.1)
        return None

    # -- channels ----------------------------------------------------------------------

    def _notify(self, session: str, payload: Dict[str, Any]) -> Generator:
        """Push a result to a client (warm TCP channel, §5.2)."""
        yield Sleep(self.cloud.sample("tcp_rtt"))
        client = self.clients.get(session)
        if client is not None:
            client.inbox.deliver(dict(payload))
        return None

    def _watch_body(self, ctx, region: str, wid: int, clients: List[str],
                    payload: Dict[str, Any], txid: int) -> Generator:
        """Free watch function: fan out one watch instance's notifications,
        then remove the epoch pair (Alg. 2 WATCHCALLBACK)."""
        tasks = []
        for sid in clients:
            tasks.append(self.cloud.spawn(self._notify(sid, payload), name=f"watch->{sid}"))
        from .simcloud import Wait

        yield Wait(tuple(tasks))
        ctx.crash_point("after_deliveries")
        yield from self.prim.list_remove(epoch_key(region), [[wid, txid]])
        return None

    def _invoke_watch(self, region: str, wid: int, clients: List[str],
                      payload: Dict[str, Any], txid: int) -> Task:
        delay = self.cloud.sample("direct_invoke")
        return self.cloud.spawn(
            self._watch_fn(region, wid, clients, payload, txid),
            name=f"watch:{wid}", delay=delay,
        )

    # -- heartbeat ---------------------------------------------------------------------

    def start_heartbeat(self, period: Optional[float] = None, max_runs: Optional[int] = None) -> None:
        self.runtime.schedule_every(
            period or self.heartbeat_period,
            lambda: self._heartbeat_fn(),
            max_runs=max_runs,
        )

    # -- storage durability ------------------------------------------------------------
    #
    # The *services* are durable even though functions are ephemeral (that is
    # the paper's shutdown story: "we can shut down the processing components
    # while not losing any data", §6).  Snapshot/load serialize exactly the
    # storage layer — a process restart with a fresh FaaSKeeperService plus
    # ``load_storage`` is the simulation of new Lambdas attaching to the same
    # DynamoDB tables and S3 buckets.

    def snapshot_storage(self) -> bytes:
        import pickle

        return pickle.dumps({
            "kv_tables": self.kv.tables,
            "objects": {r: s.objects for r, s in self.data_stores.items()},
        })

    def load_storage(self, blob: bytes) -> None:
        import pickle

        state = pickle.loads(blob)
        self.kv.tables = state["kv_tables"]
        for region, objs in state["objects"].items():
            if region in self.data_stores:
                self.data_stores[region].objects = objs

    # -- accounting ---------------------------------------------------------------------

    def cost_summary(self) -> Dict[str, float]:
        from .cost import service_cost_summary

        return service_cost_summary(self)


def _root_node() -> Dict[str, Any]:
    return {
        "path": "/", "exists": True, "data": b"", "version": 0, "cversion": 0,
        "cseq": 0, "children": [], "ephemeral_owner": None,
        "created_txid": 0, "modified_txid": 0, "lock_ts": None, "transactions": [],
    }
