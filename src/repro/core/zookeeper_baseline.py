"""In-process ZooKeeper model — the paper's comparison baseline (§5, §6).

A leader + N-server ensemble with ZAB-style total ordering: the leader
assigns zxids, a quorum acknowledges, every server applies committed
transactions in zxid order, clients read their own server's replica over a
warm TCP connection.  Latency constants follow the paper's measured series
(sub-millisecond in-memory reads; ~2 ms quorum writes on t3-class VMs).

This is deliberately a *model*, not a reimplementation of Apache ZooKeeper —
it exists so every benchmark can compare FaaSKeeper and ZooKeeper under the
same simulated network, exactly like the paper's Figures 8, 9 and 12.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, List

from .simcloud import SimCloud, Sleep
from .znode import NoNodeError


class ZooKeeperModel:
    def __init__(self, cloud: SimCloud, n_servers: int = 3):
        self.cloud = cloud
        self.n_servers = n_servers
        self.zxid = 0
        self.tree: Dict[str, Dict[str, Any]] = {
            "/": {"data": b"", "version": 0, "children": [], "mzxid": 0}
        }
        self.watch_clients: Dict[str, List[Any]] = {}

    # quorum = majority of ensemble
    @property
    def quorum(self) -> int:
        return self.n_servers // 2 + 1

    def read(self, path: str, size_kb: float = 1.0) -> Generator:
        yield Sleep(self.cloud.sample("zk_read", size_kb))
        node = self.tree.get(path)
        if node is None:
            raise NoNodeError(path)
        return node["data"], node["mzxid"]

    def write(self, path: str, data: bytes) -> Generator:
        size_kb = len(data) / 1024.0
        # leader proposal + quorum acks (parallel, wait for majority) + commit
        yield Sleep(self.cloud.sample("zk_write", size_kb))
        acks = sorted(
            self.cloud.sample("zk_write", size_kb) for _ in range(self.n_servers - 1)
        )
        if acks:
            yield Sleep(acks[self.quorum - 2] if self.quorum >= 2 else 0.0)
        self.zxid += 1
        node = self.tree.setdefault(
            path, {"data": b"", "version": -1, "children": [], "mzxid": 0}
        )
        node["data"] = data
        node["version"] += 1
        node["mzxid"] = self.zxid
        # watch dispatch
        for cb in self.watch_clients.pop(path, []):
            cb(path, self.zxid)
        return self.zxid
