"""FaaSKeeper client — kazoo-modelled API (paper §4.1, §4.6).

Write operations travel through the session's FIFO queue to the writer
function; results arrive on the push channel after the distributor replicated
the change (so SUCCESS implies read-your-write on the regional store).
Read operations go *directly* to the regional user data store — eliminating
the ZooKeeper server from the read path is the paper's core cost win — and
enforce consistency client-side via the MRD / epoch stall rule (Appendix B).

All methods are SimCloud coroutines; ``SyncClient`` wraps them for
synchronous use (examples, coord/ layer).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Generator, List, Optional

from .sessions import Inbox, SessionState
from .znode import (
    BadVersionError,
    FKError,
    NodeExistsError,
    NoNodeError,
    NotEmptyError,
    validate_path,
)

_ERRORS = {
    "no_node": NoNodeError,
    "node_exists": NodeExistsError,
    "bad_version": BadVersionError,
    "not_empty": NotEmptyError,
}


@dataclass
class Stat:
    version: int
    cversion: int
    created_txid: int
    modified_txid: int
    ephemeral_owner: Optional[str]
    num_children: int


class FaaSKeeperClient:
    def __init__(self, service, session_id: str, region: str = "region-0"):
        self.service = service
        self.cloud = service.cloud
        self.session_id = session_id
        self.region = region
        self.state = SessionState(session_id)
        self.inbox = Inbox(self.cloud, session_id)
        self.inbox.on_event = self._on_event
        self.failed = False  # heartbeat responsiveness (tests flip this)
        self.read_latencies: List[float] = []
        self.write_latencies: List[float] = []

    # -- push-channel bookkeeping ------------------------------------------------

    def _on_event(self, payload: Dict[str, Any]) -> None:
        kind = payload.get("kind")
        if kind == "watch":
            self.state.note_watch_delivery(payload["watch_id"], payload["txid"])
        elif kind == "result" and payload.get("ok"):
            self.state.observe(payload.get("txid", 0))

    # -- session lifecycle ----------------------------------------------------------

    def connect(self) -> Generator:
        yield from self.service.kv.put(
            "sessions",
            self.session_id,
            {"alive": True, "ephemerals": [], "connected_at": self.cloud.now},
        )
        # a (re)connect is a new session incarnation: its request-id space
        # restarts, so the previous incarnation's exactly-once markers must
        # not swallow this one's requests (matters after restoring durable
        # storage in a new process — launch/train.py --resume).
        yield from self.service.kv.delete("dedup", self.session_id)
        self.service.register_client(self)
        return self

    def close(self) -> Generator:
        yield from self.service.enqueue_deregistration(self.session_id)
        return None

    # -- write path -------------------------------------------------------------------

    def _submit(self, op: str, args: Dict[str, Any], size_kb: float) -> Generator:
        request_id = self.state.next_request_id()
        req = {"op": op, "args": args, "session": self.session_id, "request_id": request_id}
        queue = self.service.session_queue(self.session_id)
        yield from queue.push(req, size_kb=size_kb)
        return request_id

    def _await_result(self, request_id: str) -> Generator:
        # 'commit_failed' is NOT final: it means the distributor found a
        # half-done request whose lease had moved on — the session queue's
        # at-least-once redelivery will produce the authoritative outcome.
        result = yield from self.inbox.wait_for(
            lambda ev: ev.get("kind") == "result"
            and ev.get("request_id") == request_id
            and ev.get("code") != "commit_failed"
        )
        if not result.get("ok"):
            exc = _ERRORS.get(result.get("code"), FKError)
            raise exc(f"{result.get('code')} (request {request_id})")
        self.state.observe(result.get("txid", 0))
        return result

    def create(
        self,
        path: str,
        data: bytes = b"",
        ephemeral: bool = False,
        sequence: bool = False,
    ) -> Generator:
        """Returns the created path (sequential suffix resolved)."""
        validate_path(path)
        t0 = self.cloud.now
        rid = yield from self._submit(
            "create",
            {"path": path, "data": data, "ephemeral": ephemeral,
             "sequence": sequence, "session": self.session_id},
            size_kb=len(data) / 1024.0 + 0.1,
        )
        result = yield from self._await_result(rid)
        self.write_latencies.append(self.cloud.now - t0)
        return result["path"]

    def set_data(self, path: str, data: bytes, version: int = -1) -> Generator:
        validate_path(path)
        t0 = self.cloud.now
        rid = yield from self._submit(
            "set_data", {"path": path, "data": data, "version": version},
            size_kb=len(data) / 1024.0 + 0.1,
        )
        result = yield from self._await_result(rid)
        self.write_latencies.append(self.cloud.now - t0)
        return result["version"]

    def delete(self, path: str, version: int = -1) -> Generator:
        validate_path(path)
        t0 = self.cloud.now
        rid = yield from self._submit(
            "delete", {"path": path, "version": version}, size_kb=0.1
        )
        result = yield from self._await_result(rid)
        self.write_latencies.append(self.cloud.now - t0)
        return result["txid"]

    # pipelined (async) variants — the paper pipelines requests over the
    # session channel; FIFO order is preserved by the queue.
    def submit_set_data(self, path: str, data: bytes, version: int = -1) -> Generator:
        rid = yield from self._submit(
            "set_data", {"path": path, "data": data, "version": version},
            size_kb=len(data) / 1024.0 + 0.1,
        )
        return rid

    def wait_result(self, request_id: str) -> Generator:
        result = yield from self._await_result(request_id)
        return result

    # -- read path --------------------------------------------------------------------

    def _store(self):
        return self.service.data_stores[self.region]

    def _register_watch(self, wtype: str, path: str) -> Generator:
        wid = yield from self.service.watches.register(wtype, path, self.session_id)
        self.state.active_watches[wid] = (wtype, path)
        return wid

    def _stall_on_epoch(self, obj: Dict[str, Any]) -> Generator:
        """Appendix B Ⓝ: reads newer than MRD must wait for any of *my*
        pending watch notifications recorded in the object's epoch set."""
        v = obj.get("modified_txid", 0)
        if v <= self.state.mrd:
            return None
        for wid, txid in self.state.pending_epoch_pairs(obj.get("epoch", [])):
            yield from self.inbox.wait_for(
                lambda ev, w=wid, t=txid: ev.get("kind") == "watch"
                and ev.get("watch_id") == w and ev.get("txid") == t
            )
        return None

    def get_data(self, path: str, watch: bool = False) -> Generator:
        validate_path(path)
        t0 = self.cloud.now
        if watch:
            yield from self._register_watch("data", path)
        obj = yield from self._store().get(path)
        if obj is None:
            raise NoNodeError(path)
        yield from self._stall_on_epoch(obj)
        self.state.observe(obj.get("modified_txid", 0))
        self.read_latencies.append(self.cloud.now - t0)
        return obj["data"], _stat(obj)

    def get_children(self, path: str, watch: bool = False) -> Generator:
        validate_path(path)
        if watch:
            yield from self._register_watch("children", path)
        obj = yield from self._store().get(path)
        if obj is None:
            raise NoNodeError(path)
        yield from self._stall_on_epoch(obj)
        self.state.observe(obj.get("modified_txid", 0))
        return sorted(obj.get("children", [])), _stat(obj)

    def exists(self, path: str, watch: bool = False) -> Generator:
        validate_path(path)
        if watch:
            yield from self._register_watch("data", path)
        obj = yield from self._store().get(path)
        if obj is None:
            return None
        yield from self._stall_on_epoch(obj)
        self.state.observe(obj.get("modified_txid", 0))
        return _stat(obj)

    # -- notifications ------------------------------------------------------------------

    def wait_watch(self, path: str, timeout: float = 120.0) -> Generator:
        ev = yield from self.inbox.wait_for(
            lambda ev: ev.get("kind") == "watch" and ev.get("path") == path,
            timeout=timeout,
        )
        return ev


def _stat(obj: Dict[str, Any]) -> Stat:
    return Stat(
        version=obj.get("version", 0),
        cversion=obj.get("cversion", 0),
        created_txid=obj.get("created_txid", 0),
        modified_txid=obj.get("modified_txid", 0),
        ephemeral_owner=obj.get("ephemeral_owner"),
        num_children=len(obj.get("children", [])),
    )


class SyncClient:
    """Blocking facade: runs the event loop until each op completes."""

    def __init__(self, client: FaaSKeeperClient):
        self.client = client
        self.cloud = client.cloud

    def __getattr__(self, name: str):
        target = getattr(self.client, name)
        if not callable(target):
            return target

        def call(*args: Any, **kwargs: Any):
            return self.cloud.run_task(target(*args, **kwargs), name=f"sync:{name}")

        return call
