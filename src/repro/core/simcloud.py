"""Deterministic simulated-cloud substrate for FaaSKeeper.

The paper builds FaaSKeeper from AWS services (Lambda, SQS FIFO, DynamoDB,
S3).  This module provides the same *semantics* — the paper's explicit goal is
cloud-agnosticity ("we specify expectations on serverless services at the
level of semantics and guarantees", §3.2) — as a deterministic discrete-event
simulation:

  * a virtual clock and an event heap,
  * generator-coroutine "functions" that interleave at storage-operation
    granularity (this is what lets us property-test the consistency model
    under adversarial schedules, which the paper only argues on paper),
  * latency models calibrated against the paper's AWS measurements
    (Table 6a, Table 7a, Fig. 8/9/11),
  * fault injection at named crash points with at-least-once retry semantics
    for event functions.

Coroutine protocol
------------------
Cloud code is written as generators that ``yield`` effects:

  * ``Sleep(dt)``      — resume after ``dt`` virtual seconds,
  * ``Wait(tasks)``    — resume once every task in ``tasks`` completed,
  * ``yield from service.op(...)`` — services compose via sub-generators.

Storage operations apply *atomically* at ``now + latency``; between two
operations of one function any other runnable task may interleave, exactly as
concurrent Lambdas interleave against DynamoDB.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Generator, Iterable, List, Optional, Tuple

import numpy as np

# --------------------------------------------------------------------------
# Effects
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Sleep:
    """Resume the coroutine after ``dt`` virtual seconds."""

    dt: float


@dataclass(frozen=True)
class Wait:
    """Resume once all tasks have completed."""

    tasks: Tuple["Task", ...]


class SimulatedCrash(Exception):
    """Raised inside a function body by fault injection."""


class ConditionFailed(Exception):
    """A conditional storage update's condition did not hold."""


# --------------------------------------------------------------------------
# Latency models
# --------------------------------------------------------------------------


@dataclass
class LatencyModel:
    """Lognormal latency in *seconds* with an optional per-kB linear term.

    Calibrated from the paper's percentile tables: ``median`` is the p50 and
    ``sigma`` is chosen so that exp(mu + 2.326 sigma) ~ p99.
    """

    median: float
    sigma: float = 0.25
    per_kb: float = 0.0
    floor: float = 0.0

    def sample(self, rng: np.random.Generator, size_kb: float = 0.0) -> float:
        base = self.median * float(np.exp(self.sigma * rng.standard_normal()))
        return max(self.floor, base + self.per_kb * size_kb)

    def p(self, q: float, size_kb: float = 0.0) -> float:
        """Analytic quantile (for cost/latency reporting without sampling)."""

        # inverse CDF of standard normal via numpy
        z = float(np.sqrt(2.0) * _erfinv(2.0 * q - 1.0))
        return self.median * float(np.exp(self.sigma * z)) + self.per_kb * size_kb


def _erfinv(x: float) -> float:
    # Winitzki approximation — adequate for reporting quantiles.
    a = 0.147
    ln = np.log(1.0 - x * x)
    first = 2.0 / (np.pi * a) + ln / 2.0
    return float(np.sign(x) * np.sqrt(np.sqrt(first**2 - ln / a) - first))


def default_latency_profile() -> Dict[str, LatencyModel]:
    """Latency constants calibrated to the paper's AWS measurements.

    Sources (all times converted ms -> s):
      * Table 6a — DynamoDB regular write p50 4.35 ms @1 kB, 66.3 ms @64 kB
        => per-kB slope ~ (66.31-4.35)/63 ~ 0.98 ms/kB;
        timed lock acquire p50 6.8 ms (conditional update adds ~2.5 ms);
        atomic counter p50 5.59 ms; list append p50 5.89 ms.
      * Table 7a — SQS FIFO end-to-end invocation p50 24.2 ms; standard SQS
        39.8 ms; direct Lambda 39.0 ms; DynamoDB Streams 242 ms.
      * §5.2 — warm TCP round trip to client 0.864 ms.
      * Fig. 8/9 — S3 GET ~12 ms small objects, PUT ~25 ms (+ size terms);
        these two are stated only graphically in the paper, we pick values
        consistent with the figures and note them as calibration assumptions.
      * Fig. 11 — heartbeat function ~100 ms at small memory allocations.
      * ZooKeeper baseline: sub-ms in-region TCP read, ~2 ms quorum write
        (Fig. 8/9 "ZooKeeper" series).
    """
    return {
        # -- DynamoDB-like system store -------------------------------------
        # medians are the 0 kB intercepts: paper p50 @1 kB minus the per-kB
        # slope fitted between the 1 kB and 64 kB rows of Table 6a.
        "kv_read": LatencyModel(0.00250, 0.22, per_kb=0.00020),
        "kv_write": LatencyModel(0.00337, 0.20, per_kb=0.00098),
        "kv_cond_update": LatencyModel(0.00584, 0.28, per_kb=0.00096),
        "kv_counter": LatencyModel(0.00559, 0.25),
        "kv_list_append": LatencyModel(0.00589, 0.30, per_kb=0.00007),
        "kv_scan": LatencyModel(0.01200, 0.30, per_kb=0.00050),
        # -- S3-like user data store ----------------------------------------
        "obj_read": LatencyModel(0.01200, 0.30, per_kb=0.00008),
        "obj_write": LatencyModel(0.02500, 0.32, per_kb=0.00030),
        # -- queues / invocation ---------------------------------------------
        # SQS push: Table 3 writer-push row, 13.35 ms @4 B -> 72.18 ms @250 kB
        "queue_push": LatencyModel(0.01335, 0.25, per_kb=0.000235),
        "fifo_trigger": LatencyModel(0.02422, 0.45),  # push->function start
        "std_trigger": LatencyModel(0.03983, 0.45),
        "stream_trigger": LatencyModel(0.24265, 0.20),
        "direct_invoke": LatencyModel(0.03900, 0.40),
        "cold_start": LatencyModel(0.25000, 0.40),
        "fn_overhead": LatencyModel(0.00100, 0.30),
        # -- client channel ---------------------------------------------------
        "tcp_rtt": LatencyModel(0.000864, 0.30, per_kb=0.00001),
        # -- serving compute (calibration assumption, not a paper number):
        # autoregressive decode is weight-streaming-bound, so one batched
        # step costs ~the batch-1 step plus a small per-slot term
        # (size_kb carries the batch width); prefill is compute-bound per
        # prompt token (size_kb carries the token count).
        "decode_step": LatencyModel(0.02000, 0.05, per_kb=0.00050),
        "prefill": LatencyModel(0.00200, 0.05, per_kb=0.00020),
        # -- ZooKeeper baseline ----------------------------------------------
        "zk_read": LatencyModel(0.00080, 0.30, per_kb=0.00002),
        "zk_write": LatencyModel(0.00220, 0.30, per_kb=0.00004),
    }


# --------------------------------------------------------------------------
# Fault injection
# --------------------------------------------------------------------------


@dataclass
class FaultPlan:
    """Crash the ``occurrence``-th arrival (0-based) at ``(function, point)``.

    FaaSKeeper functions call ``ctx.crash_point(label)`` between storage
    operations; the plan decides whether that call raises
    :class:`SimulatedCrash`.  Event functions are then retried by their queue
    (at-least-once), which is exactly the paper's failure model.
    """

    crashes: Dict[Tuple[str, str], int] = field(default_factory=dict)
    _seen: Dict[Tuple[str, str], int] = field(default_factory=dict)

    def should_crash(self, function: str, point: str) -> bool:
        key = (function, point)
        if key not in self.crashes:
            return False
        n = self._seen.get(key, 0)
        self._seen[key] = n + 1
        if n == self.crashes[key]:
            return True
        return False


# --------------------------------------------------------------------------
# Tasks and the event loop
# --------------------------------------------------------------------------


class Task:
    """A running coroutine inside the simulation."""

    __slots__ = ("gen", "name", "done", "result", "error", "waiters")

    def __init__(self, gen: Generator, name: str):
        self.gen = gen
        self.name = name
        self.done = False
        self.result: Any = None
        self.error: Optional[BaseException] = None
        self.waiters: List[Callable[[], None]] = []


class Future(Task):
    """A Task that is resolved externally (no coroutine behind it).

    Used for push-channel deliveries: a client coroutine can ``yield
    Wait((future,))`` and a service resolves it when the message arrives.
    """

    def __init__(self, name: str = "future"):
        super().__init__(gen=None, name=name)  # type: ignore[arg-type]

    def resolve(self, value: Any = None) -> None:
        if self.done:
            return
        self.done = True
        self.result = value
        for w in self.waiters:
            w()
        self.waiters.clear()


class SimCloud:
    """Deterministic discrete-event cloud."""

    def __init__(
        self,
        seed: int = 0,
        latencies: Optional[Dict[str, LatencyModel]] = None,
        faults: Optional[FaultPlan] = None,
        latency_scale: float = 1.0,
    ):
        self.rng = np.random.default_rng(seed)
        self.lat = latencies or default_latency_profile()
        self.faults = faults or FaultPlan()
        self.latency_scale = latency_scale
        self._now = 0.0
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self.metrics: Dict[str, List[float]] = {}
        self.op_counts: Dict[str, int] = {}

    # -- clock / scheduling -------------------------------------------------

    @property
    def now(self) -> float:
        return self._now

    def sample(self, kind: str, size_kb: float = 0.0) -> float:
        dt = self.lat[kind].sample(self.rng, size_kb) * self.latency_scale
        self.op_counts[kind] = self.op_counts.get(kind, 0) + 1
        return dt

    def record(self, metric: str, value: float) -> None:
        self.metrics.setdefault(metric, []).append(value)

    def schedule(self, delay: float, cb: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (self._now + delay, next(self._seq), cb, None))

    def schedule_cancellable(self, delay: float, cb: Callable[[], None]) -> Dict[str, bool]:
        """Like schedule, but returns a token; set token['cancelled'] = True
        and the entry is skipped *without advancing the clock* (stale timeout
        timers must not drag virtual time forward)."""
        token = {"cancelled": False}
        heapq.heappush(self._heap, (self._now + delay, next(self._seq), cb, token))
        return token

    def spawn(self, gen: Generator, name: str = "task", delay: float = 0.0) -> Task:
        task = Task(gen, name)
        self.schedule(delay, lambda: self._step(task, None, None))
        return task

    def _finish(self, task: Task, result: Any, error: Optional[BaseException]) -> None:
        task.done = True
        task.result = result
        task.error = error
        for w in task.waiters:
            w()
        task.waiters.clear()

    def _step(self, task: Task, value: Any, exc: Optional[BaseException]) -> None:
        try:
            if exc is not None:
                effect = task.gen.throw(exc)
            else:
                effect = task.gen.send(value)
        except StopIteration as stop:
            self._finish(task, stop.value, None)
            return
        except SimulatedCrash as crash:
            self._finish(task, None, crash)
            return
        if isinstance(effect, Sleep):
            self.schedule(effect.dt, lambda: self._step(task, None, None))
        elif isinstance(effect, Wait):
            pending = [t for t in effect.tasks if not t.done]
            if not pending:
                self._step(task, None, None)
                return
            remaining = {"n": len(pending)}

            def one_done() -> None:
                remaining["n"] -= 1
                if remaining["n"] == 0:
                    self.schedule(0.0, lambda: self._step(task, None, None))

            for t in pending:
                t.waiters.append(one_done)
        else:  # pragma: no cover - defensive
            raise TypeError(f"unknown effect {effect!r} from task {task.name}")

    # -- run ------------------------------------------------------------------

    def run(self, until: Optional[float] = None, max_events: int = 2_000_000) -> None:
        """Process events until the heap empties (or a horizon is reached)."""
        events = 0
        while self._heap:
            t, _, cb, token = self._heap[0]
            if token is not None and token.get("cancelled"):
                heapq.heappop(self._heap)
                continue
            if until is not None and t > until:
                self._now = until
                return
            heapq.heappop(self._heap)
            self._now = max(self._now, t)
            cb()
            events += 1
            if events >= max_events:
                raise RuntimeError("SimCloud.run exceeded max_events — livelock?")

    def run_task(self, gen: Generator, name: str = "driver") -> Any:
        """Spawn ``gen`` and run the loop until it finishes; return its value."""
        task = self.spawn(gen, name)
        self.run()
        if not task.done:
            raise RuntimeError(f"task {name} did not finish (deadlock?)")
        if task.error is not None:
            raise task.error
        return task.result


def percentiles(samples: Iterable[float]) -> Dict[str, float]:
    xs = np.asarray(list(samples), dtype=np.float64)
    if xs.size == 0:
        return {"min": 0.0, "p50": 0.0, "p90": 0.0, "p95": 0.0, "p99": 0.0, "max": 0.0}
    return {
        "min": float(xs.min()),
        "p50": float(np.percentile(xs, 50)),
        "p90": float(np.percentile(xs, 90)),
        "p95": float(np.percentile(xs, 95)),
        "p99": float(np.percentile(xs, 99)),
        "max": float(xs.max()),
    }
