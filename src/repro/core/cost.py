"""FaaSKeeper cost model — paper §6, Table 4, Fig. 12.

Analytic model (USD):
  R_S3(s)  = 4e-7                        per read (billed per access)
  W_S3(s)  = 5e-6                        per write
  R_DD(s)  = ceil(s/4) * 0.25e-6         per read  (4 kB units)
  W_DD(s)  = ceil(s)   * 1.25e-6         per write (1 kB units)
  Q(s)     = ceil(s/64) * 0.5e-6         per queue push (64 kB increments)
  F(t,mem) = t * mem/1024 * 1.66667e-5 + 2e-7   Lambda GB-s + invoke

  COST_R = R_S3(s)
  COST_W = 2 Q(s) + 3 W_DD(1) + R_DD(1) + W_S3(s) + F_W + F_D

The paper fits linear models for F_W/F_D against payload size from the §5.4
measurements (R² 0.98 / 0.84); we do the same regression against the
simulated function runtimes in ``benchmarks/bench_cost.py`` and also provide
the paper's deployment constants here for the break-even analysis.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

from .functions import LAMBDA_GBS_PRICE, LAMBDA_INVOKE_PRICE

# -- storage / queue unit prices (Table 4) -----------------------------------
R_S3 = 4e-7
W_S3 = 5e-6
R_DD_UNIT = 0.25e-6  # per 4 kB read unit
W_DD_UNIT = 1.25e-6  # per 1 kB write unit
Q_UNIT = 0.5e-6  # per 64 kB SQS message unit

# -- storage retention (USD per GB-month) -------------------------------------
S3_GB_MONTH = 0.023
DDB_GB_MONTH = 0.25
EBS_GP3_GB_MONTH = 0.08  # ZooKeeper block storage

# -- ZooKeeper VM constants (§6) -----------------------------------------------
VM_DAILY = {"t3.small": 0.4992, "t3.medium": 0.9984, "t3.large": 1.9968}
ZK_MIN_VMS = 3   # 2f+1 with f=1
ZK_S3_DURABILITY_VMS = 9  # to match S3's 11 nines (§6)
ZK_DISK_GB = 20


def r_dd(s_kb: float) -> float:
    return math.ceil(max(s_kb, 1e-9) / 4.0) * R_DD_UNIT


def w_dd(s_kb: float) -> float:
    return math.ceil(max(s_kb, 1e-9)) * W_DD_UNIT


def q(s_kb: float) -> float:
    return math.ceil(max(s_kb, 1e-9) / 64.0) * Q_UNIT


def f(runtime_s: float, memory_mb: int) -> float:
    return runtime_s * (memory_mb / 1024.0) * LAMBDA_GBS_PRICE + LAMBDA_INVOKE_PRICE


@dataclass
class WriteCostModel:
    """COST_W with linear function-runtime models  t = a + b * s_kb."""

    writer_a: float = 0.030   # seconds @ 4 B   (Table 3: writer total p50 31.8 ms)
    writer_b: float = 0.00029  # s/kB            (p50 102.5 ms @ 250 kB)
    dist_a: float = 0.060     # (Table 3: distributor total p50 62.2 ms)
    dist_b: float = 0.00028   # (132.6 ms @ 250 kB)
    memory_mb: int = 512

    def cost_write(self, s_kb: float) -> float:
        f_w = f(self.writer_a + self.writer_b * s_kb, self.memory_mb)
        f_d = f(self.dist_a + self.dist_b * s_kb, self.memory_mb)
        return 2 * q(s_kb) + 3 * w_dd(1.0) + r_dd(1.0) + W_S3 + f_w + f_d

    def cost_read(self, s_kb: float) -> float:
        return R_S3


def faaskeeper_daily_cost(
    requests_per_day: float,
    read_fraction: float,
    s_kb: float = 1.0,
    model: WriteCostModel = None,
    stored_gb: float = 1.0,
) -> float:
    m = model or WriteCostModel()
    reads = requests_per_day * read_fraction
    writes = requests_per_day * (1.0 - read_fraction)
    storage_daily = stored_gb * S3_GB_MONTH / 30.0
    return reads * m.cost_read(s_kb) + writes * m.cost_write(s_kb) + storage_daily


def zookeeper_daily_cost(
    vm: str = "t3.small", n_vms: int = ZK_MIN_VMS, disk_gb: int = ZK_DISK_GB
) -> float:
    return n_vms * VM_DAILY[vm] + n_vms * disk_gb * EBS_GP3_GB_MONTH / 30.0


def break_even_requests_per_day(
    read_fraction: float, s_kb: float = 1.0,
    vm: str = "t3.small", n_vms: int = ZK_MIN_VMS,
) -> float:
    """Requests/day at which FaaSKeeper cost equals the ZooKeeper deployment."""
    m = WriteCostModel()
    zk = zookeeper_daily_cost(vm, n_vms)
    per_req = read_fraction * m.cost_read(s_kb) + (1 - read_fraction) * m.cost_write(s_kb)
    storage_daily = 1.0 * S3_GB_MONTH / 30.0
    return max(0.0, (zk - storage_daily) / per_req)


def cost_savings_factor(requests_per_day: float, read_fraction: float = 0.99,
                        s_kb: float = 1.0, vm: str = "t3.small",
                        n_vms: int = ZK_MIN_VMS) -> float:
    fk = faaskeeper_daily_cost(requests_per_day, read_fraction, s_kb)
    return zookeeper_daily_cost(vm, n_vms) / fk


# -- KV page offload (storage-backed preemption) -------------------------------


def page_blob_op_cost(op: str) -> float:
    """Per-op cost of a KV page-blob storage operation (Table 4 S3 rates:
    billed per access regardless of size; deletes are free, as on S3)."""
    return {"put": W_S3, "get": R_S3, "delete": 0.0}[op]


def page_blob_cost(puts: int, gets: int, stored_gb_days: float = 0.0) -> float:
    """Total storage-side cost of an offload trajectory: op charges plus
    S3 retention for blob-days actually stored (the pay-as-you-go half of
    the preemption tradeoff — compute freed now, transfer+storage paid)."""
    return (puts * W_S3 + gets * R_S3
            + stored_gb_days * S3_GB_MONTH / 30.0)


def page_blob_retention_cost(byte_seconds: float) -> float:
    """S3 retention for a byte-seconds integral (Table 4 GB-month rate).

    This is the parked-session trade: retaining an offloaded session's KV
    blob costs ``bytes * seconds`` of storage; dropping it costs the next
    request a full re-prefill.  At Table-4 rates retention is ~1e-13
    USD/KB-s, so parking wins whenever the session returns within hours."""
    return page_blob_cost(0, 0, stored_gb_days=byte_seconds / 1e9 / 86400.0)


# -- metered (simulation) accounting ------------------------------------------


def service_cost_summary(service) -> Dict[str, float]:
    """USD totals from the SimCloud meters (ops actually performed)."""
    kv = service.kv
    queue_cost = 0.0
    for queues in [service.session_queues.values(), [service.distq]]:
        for qu in queues:
            queue_cost += qu.pushes * Q_UNIT  # messages < 64 kB in tests
    s3_cost = sum(st.reads * R_S3 + st.writes * W_S3 for st in service.data_stores.values())
    dd_cost = kv.read_units * R_DD_UNIT + kv.write_units * W_DD_UNIT
    fn_cost = service.runtime.cost_usd()
    total = queue_cost + s3_cost + dd_cost + fn_cost
    return {
        "queue_usd": queue_cost,
        "s3_usd": s3_cost,
        "dynamodb_usd": dd_cost,
        "functions_usd": fn_cost,
        "total_usd": total,
    }
