"""Serverless synchronization primitives (paper §2.2, §4.4, Table 6a).

All three primitives are single conditional-update expressions against the
key-value system store — one round trip each, atomicity guaranteed by the
store's per-item atomic updates.

* **Timed lock** — a lease [Gray & Cheriton '89]: acquired if no timestamp is
  present or the holder's lease aged out; every later mutation of the locked
  item *fences* on the stored timestamp so an expired holder cannot commit
  ("to prevent accidental overwriting after losing the lock, each update to a
  locked resource compares the stored timestamp with the user value").
* **Atomic counter** — single-step add, returns the new value.
* **Atomic list** — safe append / truncation.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Generator, List

from .simcloud import ConditionFailed
from .storage import KVStore

# Maximum lease duration in virtual seconds; the paper leaves the constant a
# deployment parameter — we default to 5 s (several writer p99 latencies).
MAX_LOCK_TIME = 5.0


@dataclass(frozen=True)
class Lock:
    """A held timed lock: ``timestamp`` is the fencing token."""

    key: str
    timestamp: float


class Primitives:
    def __init__(self, kv: KVStore, table: str = "state", max_lock_time: float = MAX_LOCK_TIME):
        self.kv = kv
        self.table = table
        self.max_lock_time = max_lock_time

    # -- timed lock -----------------------------------------------------------

    def lock_acquire(self, key: str, now: float) -> Generator:
        """Try to acquire; returns ``(lock | None, item_snapshot)``."""

        def cond(item: Dict[str, Any]) -> bool:
            ts = item.get("lock_ts")
            return ts is None or (now - ts) > self.max_lock_time

        def update(item: Dict[str, Any]) -> None:
            item["lock_ts"] = now

        try:
            # size_kb=None: the conditional update touches the whole stored
            # item, so latency grows with item size even though only 8 bytes
            # change — the Table 6a effect that motivates disaggregating
            # system from user data.
            item = yield from self.kv.update(
                self.table, key, update, cond, kind="kv_cond_update", size_kb=None
            )
            return Lock(key, now), item
        except ConditionFailed:
            snapshot = yield from self.kv.get(self.table, key)
            return None, snapshot

    def lock_release(self, key: str, lock: Lock) -> Generator:
        """Release without mutating the protected item (fenced)."""

        def cond(item: Dict[str, Any]) -> bool:
            return item.get("lock_ts") == lock.timestamp

        def update(item: Dict[str, Any]) -> None:
            item["lock_ts"] = None

        try:
            yield from self.kv.update(
                self.table, key, update, cond, kind="kv_cond_update", size_kb=None
            )
            return True
        except ConditionFailed:
            return False

    def fenced_update(self, key: str, lock: Lock, mutate, size_kb: float = 0.1) -> Generator:
        """Mutate the locked item and release the lock in one atomic update.

        This is the paper's commit-with-unlock (Alg. 1 step 4): applied
        conditionally on the fencing timestamp; "no changes are made if the
        lock expires".  Returns the new item, or ``None`` if fencing failed.
        """

        def cond(item: Dict[str, Any]) -> bool:
            return item.get("lock_ts") == lock.timestamp

        def update(item: Dict[str, Any]) -> None:
            mutate(item)
            item["lock_ts"] = None

        try:
            item = yield from self.kv.update(
                self.table, key, update, cond, kind="kv_cond_update", size_kb=size_kb
            )
            return item
        except ConditionFailed:
            return None

    # -- atomic counter ---------------------------------------------------------

    def counter_add(self, key: str, delta: int = 1, field: str = "value") -> Generator:
        def update(item: Dict[str, Any]) -> None:
            item[field] = item.get(field, 0) + delta

        item = yield from self.kv.update(
            self.table, key, update, kind="kv_counter", size_kb=0.008
        )
        return item[field]

    def counter_get(self, key: str, field: str = "value") -> Generator:
        item = yield from self.kv.get(self.table, key)
        return 0 if item is None else item.get(field, 0)

    # -- atomic list -------------------------------------------------------------

    def list_append(self, key: str, values: List[Any], field: str = "items") -> Generator:
        def update(item: Dict[str, Any]) -> None:
            item.setdefault(field, []).extend(values)

        kb = 0.008 + 1.0 * len(values) / 1024.0 * 1024.0 * 0.001
        item = yield from self.kv.update(
            self.table, key, update, kind="kv_list_append", size_kb=kb
        )
        return list(item[field])

    def list_remove(self, key: str, values: List[Any], field: str = "items") -> Generator:
        def update(item: Dict[str, Any]) -> None:
            existing = item.setdefault(field, [])
            for v in values:
                if v in existing:
                    existing.remove(v)

        item = yield from self.kv.update(
            self.table, key, update, kind="kv_list_append", size_kb=0.05
        )
        return list(item[field])

    def list_get(self, key: str, field: str = "items") -> Generator:
        item = yield from self.kv.get(self.table, key)
        return [] if item is None else list(item.get(field, []))
