"""FIFO queues with event-function triggers (paper §4.2 requirements a–e).

The writer/distributor pipeline requires a queue that
  (a) invokes functions on messages,
  (b) upholds FIFO order,
  (c) limits function concurrency to a single instance,
  (d) batches items (SQS FIFO caps batches at 10),
  (e) assigns monotonically increasing sequence numbers (txids).

Delivery is at-least-once: if the consumer function crashes, the *same batch*
is redelivered in order (visibility timeout), up to ``max_retries`` — this is
the failure model FaaSKeeper's idempotent distributor relies on (§4.3).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, Callable, Generator, List, Optional

from .simcloud import SimCloud, Sleep, Wait


@dataclass
class Message:
    seq: int
    body: Any
    size_kb: float = 0.064


class FifoQueue:
    """SQS-FIFO-semantics queue bound to one event function."""

    def __init__(
        self,
        cloud: SimCloud,
        name: str,
        handler: Optional[Callable[[List[Message]], Generator]] = None,
        batch_size: int = 10,
        max_retries: int = 5,
        trigger_kind: str = "fifo_trigger",
        retry_backoff: float = 0.05,
    ):
        self.cloud = cloud
        self.name = name
        self.handler = handler
        self.batch_size = batch_size
        self.max_retries = max_retries
        self.trigger_kind = trigger_kind
        self.retry_backoff = retry_backoff
        self._seq = itertools.count(1)
        self._pending: List[Message] = []
        self._consumer_active = False
        self._inflight = 0  # leading _pending entries delivered to the consumer
        self.pushes = 0
        self.push_kb = 0.0
        self.deliveries = 0
        self.redeliveries = 0
        self.claims = 0
        self.requeues = 0
        self.dropped = 0
        self.dead_letters: List[Message] = []

    def set_handler(self, handler: Callable[[List[Message]], Generator]) -> None:
        self.handler = handler

    # -- producer side ----------------------------------------------------------

    def push(self, body: Any, size_kb: float = 0.064) -> Generator:
        """Append a message; returns its monotone sequence number (txid)."""
        yield Sleep(self.cloud.sample("queue_push", size_kb))
        msg = Message(next(self._seq), body, size_kb)
        self._pending.append(msg)
        self.pushes += 1
        self.push_kb += max(size_kb, 0.064)
        self._maybe_trigger()
        return msg.seq

    def push_immediate(self, body: Any, size_kb: float = 0.064) -> int:
        """Zero-latency push (used by in-cloud services, e.g. heartbeat)."""
        msg = Message(next(self._seq), body, size_kb)
        self._pending.append(msg)
        self.pushes += 1
        self.push_kb += max(size_kb, 0.064)
        self._maybe_trigger()
        return msg.seq

    def claim_pending(self, max_n: int) -> List[Message]:
        """Hand up to ``max_n`` not-yet-delivered messages to the running
        consumer (long-poll receive inside an active invocation — the hook
        continuous batching uses to refill free decode slots).

        Claimed messages leave the queue, so a crash-redelivery of the
        current batch does not include them; the claimer must :meth:`requeue`
        any it did not finish.
        """
        if max_n <= 0:
            return []
        take = self._pending[self._inflight : self._inflight + max_n]
        del self._pending[self._inflight : self._inflight + max_n]
        self.claims += len(take)
        return take

    def requeue(self, msgs: List[Message]) -> None:
        """Return claimed-but-unfinished messages to the head of the queue
        (behind the in-flight batch), preserving FIFO order."""
        if not msgs:
            return
        self._pending[self._inflight : self._inflight] = list(msgs)
        self.requeues += len(msgs)

    # -- consumer side ------------------------------------------------------------

    def _maybe_trigger(self) -> None:
        if self.handler is None or self._consumer_active or not self._pending:
            return
        self._consumer_active = True
        delay = self.cloud.sample(self.trigger_kind)
        self.cloud.spawn(self._consume(), name=f"queue:{self.name}", delay=delay)

    def _consume(self) -> Generator:
        while self._pending:
            batch = self._pending[: self.batch_size]
            self._inflight = len(batch)
            attempts = 0
            while True:
                self.deliveries += 1
                task = self.cloud.spawn(
                    self.handler(list(batch)), name=f"{self.name}:handler"
                )
                yield Wait((task,))
                if task.error is None:
                    break
                attempts += 1
                if attempts > self.max_retries:
                    # poison batch: route to the dead-letter list after max
                    # retries so DLQ semantics are observable, not silent
                    self.dropped += len(batch)
                    self.dead_letters.extend(batch)
                    break
                self.redeliveries += 1
                yield Sleep(self.retry_backoff * attempts)
            del self._pending[: len(batch)]
            self._inflight = 0
            if self._pending:
                yield Sleep(self.cloud.sample(self.trigger_kind) * 0.25)
        self._consumer_active = False
        # messages may have raced in while we flipped the flag
        if self._pending:
            self._maybe_trigger()
        return None
