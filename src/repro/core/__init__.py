"""FaaSKeeper core — the paper's contribution, faithfully reproduced.

Public surface:
  * :class:`SimCloud` — deterministic simulated cloud substrate,
  * :class:`FaaSKeeperService` — the wired service (Fig. 4/5),
  * :class:`FaaSKeeperClient` / :class:`SyncClient` — kazoo-like API,
  * :mod:`cost` — §6 cost model,
  * :class:`ZooKeeperModel` — the paper's baseline.
"""

from .client import FaaSKeeperClient, Stat, SyncClient
from .primitives import Lock, Primitives
from .queues import FifoQueue
from .service import FaaSKeeperService
from .simcloud import FaultPlan, SimCloud, SimulatedCrash, percentiles
from .storage import KVStore, ObjectStore
from .znode import (
    BadVersionError,
    FKError,
    NodeExistsError,
    NoNodeError,
    NotEmptyError,
)
from .zookeeper_baseline import ZooKeeperModel

__all__ = [
    "FaaSKeeperClient",
    "FaaSKeeperService",
    "FaultPlan",
    "FifoQueue",
    "KVStore",
    "Lock",
    "ObjectStore",
    "Primitives",
    "SimCloud",
    "SimulatedCrash",
    "Stat",
    "SyncClient",
    "ZooKeeperModel",
    "percentiles",
    "FKError",
    "NoNodeError",
    "NodeExistsError",
    "BadVersionError",
    "NotEmptyError",
]
