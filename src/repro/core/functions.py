"""Function runtime: free / event / scheduled functions (paper §2.2).

* **Free functions** — invoked via API request (RPC semantics); used by the
  distributor to fan out watch notifications.
* **Event functions** — bound to a queue trigger (see ``queues.py``); used by
  the writer and distributor.
* **Scheduled functions** — cron semantics; used by the heartbeat.

The runtime models cold/warm starts and GB-second billing (the §6 cost model
charges function time at AWS Lambda rates).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Generator, List, Optional

from .simcloud import SimCloud, SimulatedCrash, Sleep

LAMBDA_GBS_PRICE = 1.66667e-5  # USD per GB-second (AWS Lambda, us-east-1)
LAMBDA_INVOKE_PRICE = 2.0e-7  # USD per invocation


@dataclass
class FunctionStats:
    invocations: int = 0
    cold_starts: int = 0
    crashes: int = 0
    billed_seconds: float = 0.0
    runtimes: List[float] = field(default_factory=list)


class FunctionContext:
    """Passed to every function body: crash points + metering."""

    def __init__(self, runtime: "FunctionRuntime", name: str):
        self.runtime = runtime
        self.cloud = runtime.cloud
        self.name = name
        self.start_time = runtime.cloud.now

    def crash_point(self, label: str) -> None:
        if self.cloud.faults.should_crash(self.name, label):
            self.runtime.stats[self.name].crashes += 1
            raise SimulatedCrash(f"{self.name}@{label}")


class FunctionRuntime:
    def __init__(self, cloud: SimCloud, memory_mb: int = 2048, warm_window: float = 600.0):
        self.cloud = cloud
        self.memory_mb = memory_mb
        self.warm_window = warm_window
        self.stats: Dict[str, FunctionStats] = {}
        self._last_end: Dict[str, float] = {}

    def _stats(self, name: str) -> FunctionStats:
        return self.stats.setdefault(name, FunctionStats())

    def wrap(
        self,
        name: str,
        body: Callable[..., Generator],
        memory_mb: Optional[int] = None,
    ) -> Callable[..., Generator]:
        """Wrap a function body with start latency, billing, crash accounting."""
        mem = memory_mb or self.memory_mb

        def invoke(*args: Any, **kwargs: Any) -> Generator:
            st = self._stats(name)
            st.invocations += 1
            last = self._last_end.get(name)
            cold = last is None or (self.cloud.now - last) > self.warm_window
            if cold:
                st.cold_starts += 1
                yield Sleep(self.cloud.sample("cold_start"))
            yield Sleep(self.cloud.sample("fn_overhead"))
            ctx = FunctionContext(self, name)
            t0 = self.cloud.now
            try:
                result = yield from body(ctx, *args, **kwargs)
            finally:
                dt = self.cloud.now - t0
                st.billed_seconds += dt * (mem / 1024.0)
                st.runtimes.append(dt)
                self._last_end[name] = self.cloud.now
            return result

        return invoke

    def invoke_free(self, fn: Callable[..., Generator], *args: Any, **kwargs: Any):
        """Fire a free function asynchronously (RPC-style); returns the Task."""
        delay = self.cloud.sample("direct_invoke")
        return self.cloud.spawn(fn(*args, **kwargs), name="free-fn", delay=delay)

    def schedule_every(
        self,
        period: float,
        fn: Callable[..., Generator],
        stop_when: Optional[Callable[[], bool]] = None,
        jitter: float = 0.0,
        max_runs: Optional[int] = None,
    ) -> None:
        """Cron semantics: invoke ``fn`` every ``period`` virtual seconds."""
        runs = {"n": 0}

        def tick() -> None:
            if stop_when is not None and stop_when():
                return
            if max_runs is not None and runs["n"] >= max_runs:
                return
            runs["n"] += 1
            self.cloud.spawn(fn(), name="scheduled-fn")
            j = float(self.cloud.rng.uniform(-jitter, jitter)) if jitter else 0.0
            self.cloud.schedule(period + j, tick)

        self.cloud.schedule(period, tick)

    def cost_usd(self) -> float:
        total = 0.0
        for st in self.stats.values():
            total += st.billed_seconds * LAMBDA_GBS_PRICE
            total += st.invocations * LAMBDA_INVOKE_PRICE
        return total
