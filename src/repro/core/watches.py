"""Watch registry (paper §4.3 *Notifications*).

Watches live in the system store: one item per ``(type, path)``; "each watch
is assigned a unique identifier, and multiple clients can be assigned to a
single watch instance".  Registration is an atomic list-append; triggering
consumes the instance (ZooKeeper watches are one-shot) — a later registration
creates a fresh instance with a fresh id.

Epoch entries are ``[watch_id, txid]`` pairs, which makes the distributor's
append/remove idempotent under at-least-once retries.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, List, Tuple

from .primitives import Primitives
from .storage import KVStore

WATCH_TABLE = "watch"
DATA = "data"
CHILDREN = "children"


def watch_key(wtype: str, path: str) -> str:
    return f"{wtype}:{path}"


class WatchRegistry:
    def __init__(self, kv: KVStore, prim: Primitives):
        self.kv = kv
        self.prim = prim

    def register(self, wtype: str, path: str, session: str) -> Generator:
        """Register ``session`` on the watch instance; returns its watch_id."""
        wid = yield from self.prim.counter_add("watch_counter")

        state = {}

        def update(item: Dict[str, Any]) -> None:
            if not item.get("watch_id"):
                item["watch_id"] = wid
            if session not in item.setdefault("clients", []):
                item["clients"].append(session)
            state["watch_id"] = item["watch_id"]

        yield from self.kv.update(
            WATCH_TABLE, watch_key(wtype, path), update, kind="kv_list_append", size_kb=0.05
        )
        return state["watch_id"]

    def fetch_and_consume(self, wtype: str, path: str) -> Generator:
        """Read + atomically consume the watch instance for a trigger.

        Returns ``(watch_id, clients)`` or ``(None, [])``.
        """
        result = {}

        def update(item: Dict[str, Any]) -> None:
            result["watch_id"] = item.get("watch_id")
            result["clients"] = list(item.get("clients", []))
            item["watch_id"] = None
            item["clients"] = []

        yield from self.kv.update(
            WATCH_TABLE, watch_key(wtype, path), update, kind="kv_cond_update", size_kb=0.05
        )
        return result.get("watch_id"), result.get("clients", [])


def triggered_watches(op: str, path: str, parent: str) -> List[Tuple[str, str, str]]:
    """Which watch instances does a committed op trigger?

    Returns ``(wtype, watch_path, event)`` triples, matching ZooKeeper:
      * set_data  -> data watch on the node (``changed``)
      * create    -> data/exists watch on the node (``created``) +
                     children watch on the parent
      * delete    -> data watch on the node (``deleted``) +
                     children watch on the parent
    """
    if op == "set_data":
        return [(DATA, path, "changed")]
    if op == "create":
        return [(DATA, path, "created"), (CHILDREN, parent, "child")]
    if op == "delete":
        return [(DATA, path, "deleted"), (CHILDREN, parent, "child")]
    return []
