"""Cloud storage services with DynamoDB / S3 semantics.

The paper's *map* step (§3.2) assigns frequently-modified control data to a
key-value store with conditional update expressions (DynamoDB) and large
read-mostly user data to an object store (S3).  Both are strongly consistent
(§4.4 — eventual consistency would break Linearized Writes and Single System
Image).

All mutating operations apply atomically at a single virtual-time instant;
between two operations of one function any concurrent function may run, which
is the faithful concurrency model of Lambdas against DynamoDB.
"""

from __future__ import annotations

import copy
from typing import Any, Callable, Dict, Generator, Optional

from .simcloud import ConditionFailed, SimCloud, Sleep


def _size_kb(value: Any) -> float:
    """Rough serialized size in kB (drives latency + cost models)."""
    if value is None:
        return 0.0
    if isinstance(value, (bytes, bytearray, str)):
        return len(value) / 1024.0
    if isinstance(value, (int, float, bool)):
        return 8 / 1024.0
    if isinstance(value, (list, tuple, set)):
        return sum(_size_kb(v) for v in value) + len(value) / 1024.0
    if isinstance(value, dict):
        return sum(_size_kb(k) + _size_kb(v) for k, v in value.items())
    return 0.064


class KVStore:
    """DynamoDB-semantics table store.

    * per-item atomic updates,
    * conditional *update expressions* (the substrate for the paper's
      synchronization primitives, §2.2 / §4.4),
    * strongly consistent reads,
    * pay-per-operation metering in 1 kB write / 4 kB read units (Table 4).
    """

    def __init__(self, cloud: SimCloud, name: str = "system"):
        self.cloud = cloud
        self.name = name
        self.tables: Dict[str, Dict[str, Dict[str, Any]]] = {}
        self.write_units = 0
        self.read_units = 0

    # -- immediate (atomic) appliers -----------------------------------------

    def _table(self, table: str) -> Dict[str, Dict[str, Any]]:
        return self.tables.setdefault(table, {})

    def _apply_get(self, table: str, key: str) -> Optional[Dict[str, Any]]:
        item = self._table(table).get(key)
        return copy.deepcopy(item) if item is not None else None

    def _apply_put(self, table: str, key: str, item: Dict[str, Any]) -> None:
        self._table(table)[key] = copy.deepcopy(item)

    def _apply_delete(self, table: str, key: str) -> None:
        self._table(table).pop(key, None)

    def _apply_update(
        self,
        table: str,
        key: str,
        update: Callable[[Dict[str, Any]], None],
        condition: Optional[Callable[[Dict[str, Any]], bool]] = None,
        create_if_missing: bool = True,
    ) -> Dict[str, Any]:
        tbl = self._table(table)
        if key not in tbl:
            if not create_if_missing:
                raise ConditionFailed(f"{table}/{key} missing")
            tbl[key] = {}
        item = tbl[key]
        if condition is not None and not condition(item):
            raise ConditionFailed(f"condition failed on {table}/{key}")
        update(item)
        return copy.deepcopy(item)

    # -- coroutine API ---------------------------------------------------------

    def get(self, table: str, key: str, consistent: bool = True) -> Generator:
        kb = _size_kb(self._table(table).get(key))
        # eventually consistent reads are ~2x cheaper/faster but FaaSKeeper
        # never uses them (they break Linearized Writes, §4.4)
        yield Sleep(self.cloud.sample("kv_read", kb) * (1.0 if consistent else 0.5))
        item = self._apply_get(table, key)
        self.read_units += max(1, int(kb / 4) + 1)
        return item

    def put(self, table: str, key: str, item: Dict[str, Any]) -> Generator:
        kb = _size_kb(item)
        yield Sleep(self.cloud.sample("kv_write", kb))
        self._apply_put(table, key, item)
        self.write_units += max(1, int(kb) + 1)
        return None

    def delete(self, table: str, key: str) -> Generator:
        yield Sleep(self.cloud.sample("kv_write", 0.1))
        self._apply_delete(table, key)
        self.write_units += 1
        return None

    def update(
        self,
        table: str,
        key: str,
        update: Callable[[Dict[str, Any]], None],
        condition: Optional[Callable[[Dict[str, Any]], bool]] = None,
        kind: str = "kv_cond_update",
        size_kb: Optional[float] = None,
        create_if_missing: bool = True,
    ) -> Generator:
        """Atomic conditional update expression.

        Raises :class:`ConditionFailed` *after* the round trip — a failed
        conditional update still costs a round trip and a write unit, exactly
        like DynamoDB.
        """
        existing = self._table(table).get(key)
        kb = size_kb if size_kb is not None else _size_kb(existing)
        yield Sleep(self.cloud.sample(kind, kb))
        self.write_units += max(1, int(kb) + 1)
        return self._apply_update(table, key, update, condition, create_if_missing)

    def transact(
        self,
        items: "list[tuple[str, str, Callable[[Dict[str, Any]], None], Optional[Callable[[Dict[str, Any]], bool]]]]",
        kind: str = "kv_cond_update",
    ) -> Generator:
        """Multi-item conditional transaction (DynamoDB TransactWriteItems).

        The paper uses this for ops that lock more than one node ("the commit
        creates a transaction from multiple atomic operations that will fail
        or succeed simultaneously", §4.2).  Items are ``(table, key, update,
        condition)``.  All conditions are checked first; only if every one
        holds are all updates applied — atomically, at one virtual-time
        instant.
        """
        total_kb = sum(_size_kb(self._table(t).get(k)) for t, k, _, _ in items)
        yield Sleep(self.cloud.sample(kind, total_kb) * (1.0 + 0.15 * (len(items) - 1)))
        self.write_units += max(1, int(total_kb) + 1) * 2  # txn writes cost 2x
        for t, k, _, cond in items:
            item = self._table(t).get(k, {})
            if cond is not None and not cond(item):
                raise ConditionFailed(f"txn condition failed on {t}/{k}")
        results = []
        for t, k, update, _ in items:
            tbl = self._table(t)
            if k not in tbl:
                tbl[k] = {}
            update(tbl[k])
            results.append(copy.deepcopy(tbl[k]))
        return results

    def scan(self, table: str) -> Generator:
        tbl = self._table(table)
        kb = _size_kb(tbl)
        yield Sleep(self.cloud.sample("kv_scan", kb))
        self.read_units += max(1, int(kb / 4) + 1)
        return copy.deepcopy(tbl)


class ObjectStore:
    """S3-semantics bucket store: whole-object PUT/GET, strong consistency.

    §4.3 *Implementation*: "the update operation of S3 requires the complete
    replacement of data" — partial updates are impossible, so the distributor
    must rewrite full objects (this is Requirement #6 in §7.1).
    """

    def __init__(self, cloud: SimCloud, name: str = "data", region: str = "region-0"):
        self.cloud = cloud
        self.name = name
        self.region = region
        self.objects: Dict[str, Dict[str, Any]] = {}
        self.reads = 0
        self.writes = 0
        self.bytes_stored = 0.0

    def get(self, key: str) -> Generator:
        kb = _size_kb(self.objects.get(key))
        yield Sleep(self.cloud.sample("obj_read", kb))
        self.reads += 1
        obj = self.objects.get(key)
        return copy.deepcopy(obj) if obj is not None else None

    def put(self, key: str, obj: Dict[str, Any]) -> Generator:
        kb = _size_kb(obj)
        yield Sleep(self.cloud.sample("obj_write", kb))
        self.writes += 1
        self.objects[key] = copy.deepcopy(obj)
        self.bytes_stored = sum(_size_kb(o) for o in self.objects.values()) * 1024.0
        return None

    def delete(self, key: str) -> Generator:
        yield Sleep(self.cloud.sample("obj_write", 0.05))
        self.writes += 1
        self.objects.pop(key, None)
        return None

    def list(self, prefix: str = "") -> Generator:
        yield Sleep(self.cloud.sample("obj_read", 1.0))
        self.reads += 1
        return sorted(k for k in self.objects if k.startswith(prefix))


class PageBlobStore:
    """Object-store bucket for offloaded KV page blobs (S3 semantics:
    whole-blob PUT/GET, strong consistency, pay-per-operation).

    The decode scheduler is synchronous — it cannot yield into the SimCloud
    event loop mid-``step()`` — so blob data applies immediately (the put is
    durable the moment it returns, exactly as a blocking S3 client would
    behave) while every operation is journaled with its payload size.  The
    serving frontend drains the journal between decode steps and replays it
    against the calibrated ``obj_write``/``obj_read`` latency and Table-4
    cost models, so offload traffic is billed like any other storage op.

    Metering: ``puts/gets/deletes`` op counts, ``bytes_out`` (offloaded to
    storage), ``bytes_in`` (restored from storage), ``bytes_stored`` /
    ``high_water_bytes`` (retention gauges).
    """

    def __init__(self, name: str = "kv-offload"):
        self.name = name
        self.blobs: Dict[str, Any] = {}
        self._nbytes: Dict[str, int] = {}
        self.puts = 0
        self.gets = 0
        self.deletes = 0
        self.bytes_out = 0
        self.bytes_in = 0
        self.high_water_bytes = 0
        self.ops: list = []          # journal of (op, key, kb) for billing

    @property
    def bytes_stored(self) -> int:
        return sum(self._nbytes.values())

    def put(self, key: str, blob: Any, nbytes: int) -> None:
        self.blobs[key] = blob
        self._nbytes[key] = int(nbytes)
        self.puts += 1
        self.bytes_out += int(nbytes)
        self.high_water_bytes = max(self.high_water_bytes, self.bytes_stored)
        self.ops.append(("put", key, nbytes / 1024.0))

    def get(self, key: str) -> Any:
        if key not in self.blobs:
            raise KeyError(f"page blob {key!r} not in store {self.name!r}")
        self.gets += 1
        nbytes = self._nbytes[key]
        self.bytes_in += nbytes
        self.ops.append(("get", key, nbytes / 1024.0))
        return self.blobs[key]

    def delete(self, key: str) -> None:
        if self.blobs.pop(key, None) is not None:
            self._nbytes.pop(key, None)
            self.deletes += 1
            self.ops.append(("delete", key, 0.05))

    def drain_ops(self) -> list:
        """Hand the billing journal to the driver (frontend) and clear it."""
        ops, self.ops = self.ops, []
        return ops

    def clear(self) -> None:
        """Crash recovery: orphaned blobs are garbage — a reset scheduler
        replays every admission from its prompt, never from a blob."""
        self.blobs.clear()
        self._nbytes.clear()
        self.ops = []
