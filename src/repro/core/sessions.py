"""Client-side session machinery (paper §4.1).

The paper's client runs three background threads (send requests, manage
responses, order results).  Here the same roles are: the session's FIFO queue
(send), the :class:`Inbox` push channel (responses/notifications), and the
MRD + epoch stall rule in ``client.py`` (ordering).

The client stores the **MRD** — "the timestamp for the most recent data seen
for all reads, writes, and notifications" — and a set of delivered
``(watch_id, txid)`` pairs used by the Ordered Notifications stall rule
(Appendix B Ⓝ).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Generator, List, Optional, Set, Tuple

from .simcloud import Future, SimCloud, Wait


class Inbox:
    """Push channel from the service to one client (replaces TCP push)."""

    def __init__(self, cloud: SimCloud, session_id: str):
        self.cloud = cloud
        self.session_id = session_id
        self.events: List[Dict[str, Any]] = []
        self._futures: List[Tuple[Callable[[Dict[str, Any]], bool], Future]] = []
        self.on_event: Optional[Callable[[Dict[str, Any]], None]] = None

    def deliver(self, payload: Dict[str, Any]) -> None:
        self.events.append(payload)
        if self.on_event is not None:
            self.on_event(payload)
        still = []
        for pred, fut in self._futures:
            if not fut.done and pred(payload):
                fut.resolve(payload)
            elif not fut.done:
                still.append((pred, fut))
        self._futures = still

    def wait_for(self, pred: Callable[[Dict[str, Any]], bool], timeout: float = 120.0) -> Generator:
        """Wait (virtual time) until an event matching ``pred`` arrives."""
        for ev in self.events:
            if pred(ev):
                return ev
        fut = Future(f"inbox:{self.session_id}")
        self._futures.append((pred, fut))
        token = self.cloud.schedule_cancellable(
            timeout, lambda: fut.resolve({"kind": "timeout"}))
        yield Wait((fut,))
        token["cancelled"] = True
        if fut.result is not None and fut.result.get("kind") == "timeout":
            raise TimeoutError(f"session {self.session_id}: inbox wait timed out")
        return fut.result


class SessionState:
    """Consistency bookkeeping for one session."""

    def __init__(self, session_id: str):
        self.session_id = session_id
        self.mrd: int = 0  # most-recent-data txid
        self.active_watches: Dict[int, Tuple[str, str]] = {}  # wid -> (type, path)
        self.delivered_pairs: Set[Tuple[int, int]] = set()  # (wid, txid)
        self.request_counter = 0
        self.observed_txids: List[int] = []  # for single-system-image checks

    def next_request_id(self) -> str:
        self.request_counter += 1
        return f"{self.session_id}:{self.request_counter}"

    def observe(self, txid: int) -> None:
        if txid > 0:
            self.mrd = max(self.mrd, txid)
            self.observed_txids.append(txid)

    def note_watch_delivery(self, wid: int, txid: int) -> None:
        self.delivered_pairs.add((wid, txid))
        self.active_watches.pop(wid, None)  # one-shot
        self.observe(txid)

    def pending_epoch_pairs(self, epoch: List[List[int]]) -> List[Tuple[int, int]]:
        """Epoch pairs that block a read: my active watch, not yet delivered."""
        out = []
        for wid, txid in epoch:
            if wid in self.active_watches and (wid, txid) not in self.delivered_pairs:
                out.append((wid, txid))
        return out
