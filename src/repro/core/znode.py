"""ZooKeeper data-node (znode) model: paths, validation, op application.

System-store node items (key ``node:<path>``) carry:

  * ``data``            — authoritative payload (the user store holds replicas),
  * ``version``         — per-node monotone version (ZooKeeper ``dataVersion``),
  * ``cversion`` / ``cseq`` — children version / sequential-suffix counter,
  * ``children``        — list of child names,
  * ``ephemeral_owner`` — session id or ``None``,
  * ``created_txid`` / ``modified_txid`` — global txids (FaaSKeeper timestamps),
  * ``lock_ts``         — the timed-lock lease timestamp,
  * ``transactions``    — pending distributor txids (the writer's commit marker),
  * ``exists``          — tombstone flag (items persist so locks can be taken
    on paths that are being created/deleted, exactly like the paper's node
    list "to allow lock operations by writer functions", §4.4).

The mutators here are shared by the writer's commit-unlock and the
distributor's TryCommit so both apply byte-identical state transitions.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple


class FKError(Exception):
    code = "error"


class NoNodeError(FKError):
    code = "no_node"


class NodeExistsError(FKError):
    code = "node_exists"


class BadVersionError(FKError):
    code = "bad_version"


class NotEmptyError(FKError):
    code = "not_empty"


def validate_path(path: str) -> None:
    if not path.startswith("/") or (path != "/" and path.endswith("/")):
        raise FKError(f"invalid path {path!r}")
    if "//" in path:
        raise FKError(f"invalid path {path!r}")


def parent_path(path: str) -> str:
    if path == "/":
        return "/"
    p = path.rsplit("/", 1)[0]
    return p if p else "/"


def node_name(path: str) -> str:
    return path.rsplit("/", 1)[1]


def node_key(path: str) -> str:
    return f"node:{path}"


def fresh_node(path: str) -> Dict[str, Any]:
    return {
        "path": path,
        "exists": False,
        "data": b"",
        "version": -1,
        "cversion": 0,
        "cseq": 0,
        "children": [],
        "ephemeral_owner": None,
        "created_txid": 0,
        "modified_txid": 0,
        "lock_ts": None,
        "transactions": [],
    }


def live(item: Optional[Dict[str, Any]]) -> bool:
    return item is not None and bool(item.get("exists"))


# --------------------------------------------------------------------------
# Operation validation (writer step 2) and application (steps 4 / TryCommit)
# --------------------------------------------------------------------------


def sequential_name(path: str, cseq: int) -> str:
    return f"{path}{cseq:010d}"


def validate_op(
    op: str,
    args: Dict[str, Any],
    node: Optional[Dict[str, Any]],
    parent: Optional[Dict[str, Any]],
) -> Optional[str]:
    """Return an error code, or ``None`` if the operation is valid."""
    if op == "create":
        if live(node) and not args.get("sequence"):
            return NodeExistsError.code
        if not live(parent) and args["path"] != "/":
            return NoNodeError.code
        if parent is not None and parent.get("ephemeral_owner"):
            return "no_children_for_ephemerals"
        return None
    if op == "set_data":
        if not live(node):
            return NoNodeError.code
        v = args.get("version", -1)
        if v >= 0 and node["version"] != v:
            return BadVersionError.code
        return None
    if op == "delete":
        if not live(node):
            return NoNodeError.code
        v = args.get("version", -1)
        if v >= 0 and node["version"] != v:
            return BadVersionError.code
        if node.get("children"):
            return NotEmptyError.code
        return None
    if op == "deregister_session":
        return None
    raise FKError(f"unknown op {op}")


def apply_create(node: Dict[str, Any], args: Dict[str, Any], txid: int) -> None:
    node["exists"] = True
    node["data"] = args.get("data", b"")
    node["version"] = 0
    node["cversion"] = 0
    node["children"] = []
    node["ephemeral_owner"] = args.get("session") if args.get("ephemeral") else None
    node["created_txid"] = txid
    node["modified_txid"] = txid
    node["transactions"] = node.get("transactions", [])


def apply_parent_create(parent: Dict[str, Any], child: str, txid: int, sequence: bool) -> None:
    children = parent.setdefault("children", [])
    if child not in children:
        children.append(child)
    parent["cversion"] = parent.get("cversion", 0) + 1
    if sequence:
        parent["cseq"] = parent.get("cseq", 0) + 1
    parent["modified_txid"] = max(parent.get("modified_txid", 0), txid)


def apply_set_data(node: Dict[str, Any], args: Dict[str, Any], txid: int) -> None:
    node["data"] = args.get("data", b"")
    node["version"] = node.get("version", -1) + 1
    node["modified_txid"] = txid


def apply_delete(node: Dict[str, Any], txid: int) -> None:
    node["exists"] = False
    node["data"] = b""
    node["version"] = -1
    node["children"] = []
    node["ephemeral_owner"] = None
    node["modified_txid"] = txid


def apply_parent_delete(parent: Dict[str, Any], child: str, txid: int) -> None:
    children = parent.setdefault("children", [])
    if child in children:
        children.remove(child)
    parent["cversion"] = parent.get("cversion", 0) + 1
    parent["modified_txid"] = max(parent.get("modified_txid", 0), txid)


def materialize(
    op: str,
    args: Dict[str, Any],
    node_pre: Optional[Dict[str, Any]],
    parent_pre: Optional[Dict[str, Any]],
    txid: int,
) -> Tuple[Dict[str, Any], Optional[Dict[str, Any]]]:
    """Deterministically compute post-op node/parent state from pre-state.

    The writer pushes the *pre*-state snapshots (taken under the timed locks)
    to the distributor queue; both the writer's COMMITUNLOCK and the
    distributor's DATAUPDATE/TryCommit derive the post-state through this one
    function, so the system store and every regional user store apply
    byte-identical transitions — the substance of Single System Image (Ⓢ).
    """
    import copy as _copy

    path = args["path"]
    # Items created as a side effect of locking a not-yet-existing path carry
    # only the lease timestamp — normalize against fresh-node defaults.
    node = fresh_node(path)
    node.update(_copy.deepcopy(node_pre) or {})
    node["path"] = path
    parent = None
    if parent_pre is not None:
        parent = fresh_node(parent_path(path))
        parent.update(_copy.deepcopy(parent_pre))
    if op == "create":
        apply_create(node, args, txid)
        if parent is not None:
            apply_parent_create(parent, node_name(path), txid, bool(args.get("sequence")))
    elif op == "set_data":
        apply_set_data(node, args, txid)
    elif op == "delete":
        apply_delete(node, txid)
        if parent is not None:
            apply_parent_delete(parent, node_name(path), txid)
    else:  # pragma: no cover
        raise FKError(f"cannot materialize op {op}")
    for it in (node, parent) if parent is not None else (node,):
        it.pop("lock_ts", None)
        it.pop("transactions", None)
    return node, parent
