"""Scheduled heartbeat function (paper §4.5, Fig. 11).

ZooKeeper's per-session TCP heartbeats become one *scheduled* function that
(1) scans the session table, (2) pings every live client in parallel, and
(3) enqueues a deregistration request for each non-responder — the writer
then deletes the session's ephemeral nodes through the normal write path, so
ephemeral deletion is ordered/watched like any other transaction.

The function is parameterized by the heartbeat frequency H_fr; its cost is
the DynamoDB scan plus GB-seconds of function time (reproduced in
``benchmarks/bench_heartbeat.py``).
"""

from __future__ import annotations

from typing import Generator, List

from .simcloud import Sleep, Task, Wait


class HeartbeatCore:
    def __init__(self, service):
        self.service = service
        self.kv = service.kv
        self.cloud = service.cloud
        self.evictions = 0

    def body(self, ctx) -> Generator:
        sessions = yield from self.kv.scan("sessions")
        ctx.crash_point("after_scan")
        live = [sid for sid, item in sessions.items()
                if item.get("alive") and sid != "system"]

        # ping all clients in parallel
        pings: List[Task] = []
        for sid in live:
            pings.append(self.cloud.spawn(self._ping(sid), name=f"ping:{sid}"))
        yield Wait(tuple(pings))
        ctx.crash_point("after_pings")

        for sid, task in zip(live, pings, strict=True):
            if task.result is False:
                self.evictions += 1
                yield from self.service.enqueue_deregistration(sid)
        return len(live)

    def _ping(self, sid: str) -> Generator:
        yield Sleep(self.cloud.sample("tcp_rtt"))
        client = self.service.clients.get(sid)
        if client is None or client.failed:
            # wait out the response timeout
            yield Sleep(self.service.heartbeat_timeout)
            return False
        yield Sleep(self.cloud.sample("tcp_rtt"))
        return True
