"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=163840, MoE 64e top-6,
plus a deepseek-style shared expert (2x1408) — toggled by the name prefix in
models/moe.py.  Full attention -> long_500k skipped.
"""

from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,        # expert width (shared expert = 2x)
    vocab=163840,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=50000.0,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, capacity_factor=1.25),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)
