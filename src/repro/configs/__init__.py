"""Assigned architecture registry: ``--arch <id>`` resolves here.

Each module defines ``CONFIG`` (the exact assigned full-scale config) and the
registry exposes ``get(name)`` / ``list_archs()`` plus ``input_specs`` for the
dry-run (ShapeDtypeStruct stand-ins — no allocation ever happens for the full
configs; they are exercised only via ``launch/dryrun.py``).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ArchConfig

_ARCHS = [
    "internvl2_2b",
    "mamba2_1p3b",
    "starcoder2_3b",
    "qwen3_14b",
    "qwen1p5_110b",
    "minicpm_2b",
    "moonshot_v1_16b_a3b",
    "qwen3_moe_235b_a22b",
    "whisper_base",
    "recurrentgemma_2b",
]

_ALIAS = {
    "internvl2-2b": "internvl2_2b",
    "mamba2-1.3b": "mamba2_1p3b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen3-14b": "qwen3_14b",
    "qwen1.5-110b": "qwen1p5_110b",
    "minicpm-2b": "minicpm_2b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "whisper-base": "whisper_base",
    "recurrentgemma-2b": "recurrentgemma_2b",
}


def get(name: str) -> ArchConfig:
    mod_name = _ALIAS.get(name, name.replace("-", "_").replace(".", "p"))
    if mod_name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {list(_ALIAS)}")
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(_ALIAS.keys())


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get(n) for n in list_archs()}
