"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=2048 attention-free, ssm_state=128.  d_inner = 2*d_model = 4096,
64 heads of dim 64.  O(1) decode state -> runs long_500k.
"""

from ..models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=64,       # ssd heads (d_inner / head_dim)
    n_kv_heads=64,
    d_ff=0,           # attention/MLP-free: the ssd block is the whole layer
    vocab=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
