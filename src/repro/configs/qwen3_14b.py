"""qwen3-14b [dense] — qk_norm, GQA kv=8 [hf:Qwen/Qwen3-8B family].

40L d_model=5120 40H d_ff=17408 vocab=151936, head_dim=128.
Full attention -> long_500k skipped.
"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab=151936,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    qk_norm=True,
    rope_theta=1_000_000.0,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)
