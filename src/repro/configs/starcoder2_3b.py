"""starcoder2-3b [dense] — GQA kv=2, RoPE, sliding window 4096
[arXiv:2402.19173; hf].  30L d_model=3072 24H d_ff=12288 vocab=49152.
LayerNorm + standard gelu MLP, attention bias (per the HF config).
Sliding-window attention is sub-quadratic -> runs long_500k.
"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    mlp="gelu",
    norm="layernorm",
    qkv_bias=True,
    rope_theta=999_999.4420358813,
    sliding_window=4096,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
