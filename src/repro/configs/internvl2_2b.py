"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The InternViT-300M
frontend is a stub: ``input_specs`` provides precomputed 1024-dim patch
embeddings (256 patches = one 448px tile).  Backbone is full attention ->
long_500k is skipped (DESIGN.md §Arch-applicability).
"""

from ..models.config import ArchConfig, VLMConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    vlm=VLMConfig(n_patches=256, patch_dim=1024),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)
