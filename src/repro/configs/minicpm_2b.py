"""minicpm-2b [dense] — WSD schedule, mup-style scaling [arXiv:2404.06395; hf].

40L d_model=2304 36H (kv=36, i.e. MHA) d_ff=5760 vocab=122753.
Llama-like block; the MiniCPM specifics are the WSD learning-rate schedule
(implemented in train/optim.py and selected by this config) and the
depth/width scaling factors: scale_emb=12, scale_depth=1.4 (residual scale
1.4/sqrt(40)), logit scale = 1/(2304/256).
"""

import math

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
    mlp="swiglu",
    norm="rmsnorm",
    emb_scale=12.0,
    residual_scale=1.4 / math.sqrt(40),
    logit_scale=256.0 / 2304.0,
    tie_embeddings=True,
    rope_theta=10000.0,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

# WSD (warmup-stable-decay) schedule preset consumed by train/optim.py
WSD = {"warmup_steps": 0.01, "stable_frac": 0.9, "min_ratio": 0.1}
