"""whisper-base [audio] — enc-dec, conv frontend stub [arXiv:2212.04356].

6L decoder (+6L encoder) d_model=512 8H d_ff=2048 vocab=51865.
``input_specs`` provides precomputed 1500-frame embeddings (the output of
whisper's two conv layers over a 30 s mel spectrogram).  Enc-dec with a
decoder -> decode shapes run; full attention -> long_500k skipped.
"""

from ..models.config import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,               # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    mlp="gelu",
    norm="layernorm",
    tie_embeddings=True,
    encdec=EncDecConfig(n_encoder_layers=6, n_frames=1500, frame_dim=512),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)
