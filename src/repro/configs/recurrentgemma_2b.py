"""recurrentgemma-2b [hybrid] — RG-LRU + local attn 1:2 [arXiv:2402.19427; hf].

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, lru_width=2560,
local attention window 2048, pattern rra (2 recurrent : 1 attention).
O(1) recurrent state + bounded window -> runs long_500k.
"""

from ..models.config import ArchConfig, HybridConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    mlp="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    rope_theta=10000.0,
    hybrid=HybridConfig(pattern="rra", lru_width=2560, local_window=2048, d_conv=4),
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
