"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3 MoE family].

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936, qk_norm.
Deepest assigned arch: scan-over-layers is mandatory (94 layers).
Full attention -> long_500k skipped.
"""

from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,        # expert width
    vocab=151936,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536, capacity_factor=1.25),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)
