"""qwen1.5-110b [dense] — QKV bias [hf:Qwen/Qwen1.5 family].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
The largest assigned cell: fp32 masters + Adam state only fit 256 chips with
2-D (FSDP x TP) parameter sharding.  Full attention -> long_500k skipped.
"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152064,
    mlp="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)
