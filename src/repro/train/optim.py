"""AdamW + LR schedules (hand-rolled: optax is not a dependency).

Includes the WSD (warmup-stable-decay) schedule from MiniCPM
(arXiv:2404.06395), selected by the minicpm-2b config, alongside the standard
cosine schedule.  Optimizer state mirrors the parameter sharding (each moment
tensor inherits its parameter's NamedSharding under pjit) — ZeRO comes for
free from the 2-D param sharding.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"        # cosine | wsd | constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    stable_frac: float = 0.9        # wsd: fraction of post-warmup steps at peak
    min_ratio: float = 0.1


def wsd_schedule(step: jnp.ndarray, cfg: AdamWConfig) -> jnp.ndarray:
    """Warmup-Stable-Decay: linear warmup, long flat stage, short decay tail."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    stable_end = cfg.warmup_steps + cfg.stable_frac * (cfg.total_steps - cfg.warmup_steps)
    decay_len = jnp.maximum(cfg.total_steps - stable_end, 1.0)
    decay = 1.0 - (1.0 - cfg.min_ratio) * jnp.clip((step - stable_end) / decay_len, 0.0, 1.0)
    return warm * jnp.where(step <= stable_end, 1.0, decay)


def cosine_schedule(step: jnp.ndarray, cfg: AdamWConfig) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_ratio + (1 - cfg.min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * cos


def lr_at_step(step: jnp.ndarray, cfg: AdamWConfig) -> jnp.ndarray:
    if cfg.schedule == "wsd":
        mult = wsd_schedule(step, cfg)
    elif cfg.schedule == "constant":
        mult = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    else:
        mult = cosine_schedule(step, cfg)
    return cfg.lr * mult


def adamw_init(params: Any) -> Dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    sq = jax.tree_util.tree_map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def _is_matrix(p: jnp.ndarray) -> bool:
    # weight decay only on weight matrices (>=2 trailing dims), not norms/bias
    return p.ndim >= 2


def adamw_update(grads: Any, opt_state: Dict, params: Any, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict, Dict[str, jnp.ndarray]]:
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9)) if cfg.grad_clip else 1.0
    lr = lr_at_step(step.astype(jnp.float32), cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mu_hat = mu / bc1
        nu_hat = nu / bc2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if _is_matrix(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu, strict=True)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gn, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
