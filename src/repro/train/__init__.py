from .optim import AdamWConfig, adamw_init, adamw_update, lr_at_step, wsd_schedule
from .step import TrainStepConfig, make_eval_step, make_train_step

__all__ = [
    "AdamWConfig",
    "TrainStepConfig",
    "adamw_init",
    "adamw_update",
    "lr_at_step",
    "make_eval_step",
    "make_train_step",
    "wsd_schedule",
]
