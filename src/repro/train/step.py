"""Train / eval step builders.

``make_train_step(model, optim_cfg, step_cfg)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with explicit in/out shardings (launch/dryrun.py, launch/train.py).

Distributed-optimization levers (all config-selectable; §Perf hillclimbs flip
them):
  * microbatching / gradient accumulation (``accum_steps``) — lax.scan over
    microbatches, which also overlaps the per-microbatch backward collective
    with the next microbatch's compute under XLA's async scheduling;
  * int8 error-feedback gradient compression for the DP all-reduce
    (``compress_grads``) — 4x fewer bytes on the wire, residual carried in
    the optimizer state (Seide et al. / 1-bit-Adam lineage);
  * rematerialization policy comes from the model config (scan-over-layers).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from .optim import AdamWConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class TrainStepConfig:
    accum_steps: int = 1
    compress_grads: bool = False
    z_loss: float = 0.0              # logit-norm regularizer (stability)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 0.0) -> jnp.ndarray:
    """Mean next-token CE.  logits (B,S,V) fp-any; labels (B,S) int32.

    The gold logit is extracted with a masked reduction (iota == label)
    rather than take_along_axis: under vocab-sharded logits the gather would
    force an all-gather of the logits, while the masked reduction stays local
    + one tiny per-token all-reduce."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], lg, 0.0), axis=-1)
    loss = (lse - gold).mean()
    if z_loss:
        loss = loss + z_loss * jnp.square(lse).mean()
    return loss


# -- int8 error-feedback compression ------------------------------------------


def _compress_decompress(g: jnp.ndarray, residual: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Simulate int8 quantization with error feedback: returns (g_hat, new_res).

    The all-reduce then moves int8 (4x compression); here the quantization is
    mathematically applied so training dynamics are faithful, and the dry-run
    HLO carries the int8 tensors through the collective.
    """
    gf = g.astype(jnp.float32) + residual
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    g_hat = q.astype(jnp.float32) * scale
    return g_hat, gf - g_hat


def make_loss_fn(model, step_cfg: TrainStepConfig) -> Callable:
    def loss_fn(params, batch):
        logits, aux = model.loss_aux(params, batch)
        labels = batch["labels"]
        loss = cross_entropy(logits, labels, step_cfg.z_loss) + aux
        return loss, {"loss": loss, "aux_loss": aux}

    return loss_fn


def make_train_step(model, optim_cfg: AdamWConfig,
                    step_cfg: TrainStepConfig = TrainStepConfig()) -> Callable:
    loss_fn = make_loss_fn(model, step_cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if step_cfg.accum_steps <= 1:
            (_, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        n = step_cfg.accum_steps

        def reshape(x):  # (B, ...) -> (n, B/n, ...)
            return x.reshape((n, x.shape[0] // n) + x.shape[1:])

        micro = jax.tree_util.tree_map(reshape, batch)

        def body(carry, mb):
            acc, msum = carry
            (_, metrics), grads = grad_fn(params, mb)
            acc = jax.tree_util.tree_map(jnp.add, acc, grads)
            msum = jax.tree_util.tree_map(jnp.add, msum, metrics)
            return (acc, msum), None

        zeros_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        zeros_m = {"loss": jnp.zeros((), jnp.float32), "aux_loss": jnp.zeros((), jnp.float32)}
        (grads, msum), _ = jax.lax.scan(body, (zeros_g, zeros_m), micro)
        grads = jax.tree_util.tree_map(lambda g: g / n, grads)
        metrics = jax.tree_util.tree_map(lambda m: m / n, msum)
        return grads, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = compute_grads(params, batch)
        if step_cfg.compress_grads:
            res = opt_state["compress_residual"]
            pairs = jax.tree_util.tree_map(_compress_decompress, grads, res)
            grads = jax.tree_util.tree_map(lambda pr: pr[0], pairs,
                                           is_leaf=lambda x: isinstance(x, tuple))
            new_res = jax.tree_util.tree_map(lambda pr: pr[1], pairs,
                                             is_leaf=lambda x: isinstance(x, tuple))
        inner = {k: opt_state[k] for k in ("mu", "nu", "step")}
        params, inner, opt_metrics = adamw_update(grads, inner, params, optim_cfg)
        metrics = dict(metrics, **opt_metrics)
        new_state = dict(inner)
        if step_cfg.compress_grads:
            new_state["compress_residual"] = new_res
        return params, new_state, metrics

    return train_step


def init_train_state(model, params, step_cfg: TrainStepConfig = TrainStepConfig()):
    state = adamw_init(params)
    if step_cfg.compress_grads:
        state["compress_residual"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def make_eval_step(model, step_cfg: TrainStepConfig = TrainStepConfig()) -> Callable:
    loss_fn = make_loss_fn(model, step_cfg)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step
