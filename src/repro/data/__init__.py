from .pipeline import DataConfig, SyntheticPipeline, make_batch_specs

__all__ = ["DataConfig", "SyntheticPipeline", "make_batch_specs"]
