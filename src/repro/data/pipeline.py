"""Deterministic sharded synthetic-token pipeline.

Every batch is a pure function of ``(seed, step)`` — restart-safe (a resumed
job regenerates the identical stream from the checkpointed step, giving
bit-identical training curves) and host-shardable (each host materializes
only its slice; slicing is by global batch index so any host layout yields
the same global batch).

The token stream is a order-2 Markov chain over the vocab rather than i.i.d.
noise so that the cross-entropy actually *decreases* during the example runs
— a learnable signal with known optimal loss (the chain's conditional
entropy), which the examples assert against.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ArchConfig, ShapeSpec


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    markov_states: int = 64        # structure size of the synthetic chain
    host_index: int = 0
    host_count: int = 1


class SyntheticPipeline:
    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, data_cfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.shape = shape
        self.data = data_cfg
        m = min(data_cfg.markov_states, cfg.vocab)
        rng = np.random.default_rng(data_cfg.seed)
        # sparse-ish transition matrix with a few high-probability successors
        logits = rng.normal(size=(m, m)).astype(np.float32) * 2.0
        self._trans = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
        self._m = m

    # -- batch generation -----------------------------------------------------

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        B, S = self.shape.global_batch, self.shape.seq_len
        rng = np.random.default_rng((self.data.seed, step))
        states = rng.integers(0, self._m, size=B)
        seq = np.empty((B, S + 1), np.int32)
        seq[:, 0] = states
        # vectorized chain sampling via inverse-CDF
        cdf = np.cumsum(self._trans, axis=-1)
        u = rng.random(size=(B, S))
        for t in range(S):
            seq[:, t + 1] = (u[:, t, None] < cdf[seq[:, t]]).argmax(-1)
        batch = {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
        extra = self._frontend_stub(rng, B)
        batch.update(extra)
        return batch

    def host_batch(self, step: int) -> Dict[str, jnp.ndarray]:
        g = self.global_batch(step)
        B = self.shape.global_batch
        lo = B * self.data.host_index // self.data.host_count
        hi = B * (self.data.host_index + 1) // self.data.host_count
        return {k: jnp.asarray(v[lo:hi]) for k, v in g.items()}

    def _frontend_stub(self, rng, B: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        if cfg.family == "audio":
            F = cfg.encdec.n_frames
            d = cfg.encdec.frame_dim or cfg.d_model
            return {"frames": rng.normal(size=(B, F, d)).astype(np.float32)}
        if cfg.family == "vlm":
            Np = cfg.vlm.n_patches
            d = cfg.vlm.patch_dim or cfg.d_model
            return {"patch_embeds": rng.normal(size=(B, Np, d)).astype(np.float32)}
        return {}

    def optimal_loss(self) -> float:
        """Conditional entropy of the chain (nats) — floor for CE on tokens<m."""
        p = self._trans
        stationary = np.linalg.matrix_power(p, 512)[0]
        h = -(p * np.log(p + 1e-12)).sum(-1)
        return float((stationary * h).sum())


def make_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one training batch (dry-run)."""
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family == "audio":
        F = cfg.encdec.n_frames
        d = cfg.encdec.frame_dim or cfg.d_model
        specs["frames"] = jax.ShapeDtypeStruct((B, F, d), jnp.float32)
    if cfg.family == "vlm":
        Np = cfg.vlm.n_patches
        d = cfg.vlm.patch_dim or cfg.d_model
        specs["patch_embeds"] = jax.ShapeDtypeStruct((B, Np, d), jnp.float32)
    return specs
