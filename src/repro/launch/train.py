"""End-to-end training driver with FaaSKeeper coordination.

This is the runnable (CPU-scale) counterpart of the dry-run: it trains a
reduced config for real while exercising the full control plane —
ephemeral-znode membership, transactional checkpoint manifests, progress
reporting, straggler scanning, and crash/restart recovery.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --steps 50 \
      --smoke --ckpt-dir /tmp/ckpt [--resume] [--simulate-failure 20]
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Optional

import jax

from .. import configs
from ..checkpoint import CheckpointStore
from ..coord import CoordinatedManifest, MembershipService, StragglerDetector
from ..core import FaaSKeeperService, SimCloud
from ..data import DataConfig, SyntheticPipeline
from ..models import build_model
from ..models.config import ShapeSpec
from ..train import AdamWConfig, make_train_step
from ..train.step import TrainStepConfig, init_train_state


def build_control_plane():
    cloud = SimCloud(seed=0)
    service = FaaSKeeperService(cloud)
    return cloud, service


def run_training(arch: str, steps: int = 50, *, smoke: bool = True,
                 ckpt_dir: Optional[str] = None, resume: bool = False,
                 ckpt_every: int = 20, simulate_failure: Optional[int] = None,
                 seq_len: int = 64, global_batch: int = 8,
                 lr: float = 3e-3, log_every: int = 10,
                 schedule: Optional[str] = None):
    cfg = configs.get(arch)
    if smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    shape = ShapeSpec("driver", seq_len, global_batch, "train")
    pipe = SyntheticPipeline(cfg, shape, DataConfig(seed=0))

    # -- control plane ---------------------------------------------------------
    cloud, service = build_control_plane()
    cp_state = os.path.join(ckpt_dir, "control_plane.pkl") if ckpt_dir else None
    if resume and cp_state and os.path.exists(cp_state):
        # fresh functions attach to the durable storage of the previous run
        with open(cp_state, "rb") as f:
            service.load_storage(f.read())
    membership = MembershipService(service)
    worker = membership.join("worker-0", {"devices": jax.device_count()})
    stragglers = StragglerDetector(service)
    manifest = CoordinatedManifest(service, job=f"train-{arch}")

    def persist_control_plane(step: int, m) -> None:
        manifest.commit(step, m)
        if cp_state:
            tmp = cp_state + ".tmp"
            with open(tmp, "wb") as f:
                f.write(service.snapshot_storage())
            os.replace(tmp, cp_state)

    store = None
    if ckpt_dir:
        store = CheckpointStore(ckpt_dir, committer=persist_control_plane,
                                latest_resolver=manifest.latest)

    # -- data plane ---------------------------------------------------------------
    schedule = schedule or ("wsd" if arch.startswith("minicpm") else "cosine")
    optim = AdamWConfig(lr=lr, total_steps=steps, warmup_steps=max(1, steps // 10),
                        schedule=schedule)
    step_cfg = TrainStepConfig()
    params = model.init(jax.random.key(0))
    state = init_train_state(model, params, step_cfg)
    start_step = 0
    if resume and store is not None:
        try:
            restored, start_step = store.restore({"params": params, "opt": state})
            params, state = restored["params"], restored["opt"]
            print(f"[coord] resumed from committed checkpoint step {start_step} "
                  f"(txid-ordered manifest via FaaSKeeper)")
        except FileNotFoundError:
            print("[coord] no committed checkpoint; starting fresh")
    train_step = jax.jit(make_train_step(model, optim, step_cfg))

    losses = []
    t0 = time.time()
    for step in range(start_step, steps):
        if simulate_failure is not None and step == simulate_failure and not resume:
            if store is not None:
                # drain in-flight async saves before injecting the fault: their
                # device->host copy already happened at save_async time, so any
                # checkpoint started >=1 step ago counts as durably committed —
                # and the background committer must not race the control-plane
                # eviction below
                store.wait()
            print(f"[fault] simulating worker crash at step {step} "
                  f"(restart with --resume to recover)")
            membership.fail(worker)
            service.start_heartbeat(period=5.0, max_runs=3)
            cloud.run()
            print(f"[coord] members after eviction: {membership.members()}")
            return {"crashed_at": step, "losses": losses}
        batch = pipe.host_batch(step)
        params, state, metrics = train_step(params, state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        stragglers.report("worker-0", step)
        if (step + 1) % log_every == 0:
            print(f"step {step+1:5d}  loss {loss:.4f}  lr {float(metrics['lr']):.2e} "
                  f" gnorm {float(metrics['grad_norm']):.3f}")
        if store is not None and (step + 1) % ckpt_every == 0:
            store.save_async(step + 1, {"params": params, "opt": state})
    if store is not None:
        store.wait()
    rep = stragglers.scan()
    dt = time.time() - t0
    print(f"done: {len(losses)} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(chain floor {pipe.optimal_loss():.3f}); stragglers: {rep.lagging}")
    membership.leave(worker)
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "optimal_loss": pipe.optimal_loss(),
            "coord_cost_usd": service.cost_summary()["total_usd"]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="full config (needs a real fleet; CPU will OOM)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--simulate-failure", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()
    run_training(args.arch, args.steps, smoke=args.smoke, ckpt_dir=args.ckpt_dir,
                 resume=args.resume, ckpt_every=args.ckpt_every,
                 simulate_failure=args.simulate_failure, seq_len=args.seq_len,
                 global_batch=args.global_batch, lr=args.lr)


if __name__ == "__main__":
    main()
