"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single-pod = (16, 16) = 256 chips (one TPU v5e pod slice);
multi-pod = (2, 16, 16) = 512 chips, with the leading ``pod`` axis used for
hierarchical data parallelism (reduce-scatter intra-pod over ICI, all-reduce
inter-pod over DCI).
"""

from __future__ import annotations

import jax

from ..dist.sharding import MESH_AXES


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = MESH_AXES if multi_pod else MESH_AXES[1:]
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh for CPU smoke tests of the sharded code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (roofline denominators; EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (~4 links/chip on a 2d torus)
HBM_PER_CHIP = 16 * 1024**3    # 16 GB
