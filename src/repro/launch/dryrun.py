import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first (before any jax import): jax locks the
device count at first init, and the production meshes need 512 placeholder
host devices.  Do not set the flag anywhere global — smoke tests and benches
see 1 device.

For each cell this driver:
  1. builds abstract params / optimizer / cache trees via ``jax.eval_shape``
     (ShapeDtypeStruct stand-ins — nothing is ever allocated),
  2. assigns NamedShardings from dist/sharding.py,
  3. ``jax.jit(step).lower(...)`` -> ``.compile()`` under the target mesh,
  4. records memory_analysis / cost_analysis / per-collective wire bytes
     (launch/hlo_analysis.py) for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun.json
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .. import configs
from ..data.pipeline import make_batch_specs
from ..dist import sharding as shd
from ..models import build_model, kvcache
from ..models.config import SHAPES_BY_NAME, ArchConfig, ShapeSpec
from ..serve.engine import make_decode_step, make_prefill
from ..train.optim import AdamWConfig
from ..train.step import TrainStepConfig, init_train_state, make_train_step
from . import hlo_analysis
from .mesh import HBM_PER_CHIP, make_production_mesh


def _sds(tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def abstract_params(model) -> Any:
    return _sds(jax.eval_shape(model.init, jax.random.key(0)))


def _extra_prefill_args(cfg: ArchConfig, shape: ShapeSpec):
    B = shape.global_batch
    if cfg.family == "audio":
        d = cfg.encdec.frame_dim or cfg.d_model
        return (jax.ShapeDtypeStruct((B, cfg.encdec.n_frames, d), jnp.float32),)
    if cfg.family == "vlm":
        d = cfg.vlm.patch_dim or cfg.d_model
        return (jax.ShapeDtypeStruct((B, cfg.vlm.n_patches, d), jnp.float32),)
    return ()


# -- paged-kernel dispatch axis ---------------------------------------------
# decode cells additionally lower through the fused Pallas paged-attention
# path (`attn_backend='paged_kernel'`): the shared page pool + per-slot page
# table replaces the ring cache, so the matrix covers BOTH decode dispatch
# modes and a sharding regression in the pool layout shows up as a named
# `...|paged` cell in the wire-bytes gate.
PAGED_KERNEL_FAMILIES = ("dense", "moe", "hybrid")
DRYRUN_PAGE_SIZE = 16

# -- speculative-verify dispatch axis ----------------------------------------
# `kernel='spec'` lowers the draft-and-verify round's target half: one
# chunked decode step scoring spec_k + 1 tokens per slot against the paged
# pool (gather dispatch — the fused kernel is S=1-only).  Same applicability
# as the paged cells: the verify chunk only exists where the pool does.
DRYRUN_SPEC_K = 3

# -- shard_map paged dispatch axis -------------------------------------------
# `kernel='shardmap'` is the paged cell with `shard_map_pool=True`: the fused
# gather runs as a per-shard kernel over the lane-sharded pool under
# `jax.shard_map` (log-sum-exp lane merge) instead of letting GSPMD place
# the gather.  The wire-bytes gate pins that the merge costs only the
# per-shard softmax statistics — a full-pool all-gather sneaking back in
# shows up as a `...|shardmap` cell regression.


def paged_kernel_applicable(cfg: ArchConfig, shape: ShapeSpec) -> bool:
    """The fused kernel serves attention layers from the paged pool: decode
    shapes only, and only families with a KV pool (SSM decode has none;
    audio/VLM decoders ride the encoder path, not the pool)."""
    return shape.kind == "decode" and cfg.family in PAGED_KERNEL_FAMILIES


# per-device microbatch token cap: 8192 keeps every train cell's transients
# (scores, CE, MoE dispatch buffers) within HBM even under the CPU backend's
# no-donation double-counting (§Perf cell-2 iteration 3: accum 4 -> 8 cut
# qwen3-moe temp 24.7 -> 20.5 GB and wire -24 %)
TOKENS_PER_DEV_MICROBATCH = 8192


def default_accum_steps(cfg: ArchConfig, shape: ShapeSpec, dp_size: int) -> int:
    """Gradient-accumulation depth: cap per-device microbatch tokens so
    activation transients (scores, CE, dispatch buffers) fit 16 GB HBM."""
    tokens_per_dev = shape.global_batch // dp_size * shape.seq_len
    accum = max(1, tokens_per_dev // TOKENS_PER_DEV_MICROBATCH)
    while shape.global_batch // dp_size % accum != 0 and accum > 1:
        accum -= 1
    return min(accum, shape.global_batch // dp_size)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               step_cfg: Optional[TrainStepConfig] = None,
               optim_cfg: AdamWConfig = AdamWConfig(),
               cfg_overrides: Optional[Dict] = None,
               policy_kw: Optional[Dict] = None,
               donate: bool = True, kernel: str = "gather"):
    """Returns (lowered, meta) for one cell."""
    cfg = configs.get(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES_BY_NAME[shape_name]
    if shape_name not in cfg.shapes:
        raise ValueError(f"{arch} skips {shape_name} (cfg.shapes={cfg.shapes})")
    if kernel in ("paged", "spec", "shardmap"):
        if not paged_kernel_applicable(cfg, shape):
            raise ValueError(f"{arch} x {shape_name} has no paged-pool "
                             f"decode path (family={cfg.family!r})")
        if kernel in ("paged", "shardmap"):
            cfg = dataclasses.replace(cfg, attn_backend="paged_kernel")
        # spec keeps gather dispatch: the verify chunk is S = spec_k + 1
        # tokens and the fused kernel is S=1-only
    elif kernel != "gather":
        raise ValueError(f"kernel must be 'gather', 'paged', 'spec' or "
                         f"'shardmap', got {kernel!r}")
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    p_abs = abstract_params(model)
    p_sh = shd.param_shardings(p_abs, mesh)
    attn_mode = "head" if cfg.n_kv_heads % mesh.shape["model"] == 0 else "seq"
    pkw = dict(policy_kw or {})
    if shape.kind == "decode":
        pkw.setdefault("decode_stationary", True)
    if kernel == "shardmap":
        pkw.setdefault("shard_map_pool", True)
    policy = shd.ShardingPolicy.default(
        mesh, batch_shardable=shape.global_batch % _dp_size(mesh) == 0,
        attn_mode=attn_mode, **pkw)

    if step_cfg is None:
        step_cfg = TrainStepConfig(
            accum_steps=default_accum_steps(cfg, shape, _dp_size(mesh)))

    with shd.activation_sharding(policy):
        if shape.kind == "train":
            batch_abs = make_batch_specs(cfg, shape)
            b_sh = shd.batch_shardings(batch_abs, mesh)
            o_abs = _sds(jax.eval_shape(
                lambda p: init_train_state(model, p, step_cfg), p_abs))
            o_sh = _opt_shardings(o_abs, p_sh, mesh)
            step = make_train_step(model, optim_cfg, step_cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             donate_argnums=(0, 1) if donate else ())
            with mesh:
                lowered = jitted.lower(p_abs, o_abs, batch_abs)
        elif shape.kind == "prefill":
            tok_abs = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32)
            extra = _extra_prefill_args(cfg, shape)
            t_sh = shd.batch_shardings({"tokens": tok_abs}, mesh)["tokens"]
            e_sh = tuple(shd.batch_shardings({"patch_embeds": e}, mesh)["patch_embeds"]
                         for e in extra)
            step = make_prefill(model)
            jitted = jax.jit(step, in_shardings=(p_sh, t_sh) + e_sh)
            with mesh:
                lowered = jitted.lower(p_abs, tok_abs, *extra)
        else:  # decode
            B = shape.global_batch
            if kernel in ("paged", "spec"):
                # same KV capacity as the ring cell, laid out as the shared
                # pool + page table the serving scheduler actually decodes
                # against (exact-fit pool: B slots x max_pages each)
                mp = -(-shape.seq_len // DRYRUN_PAGE_SIZE)
                cache_abs = _sds(jax.eval_shape(
                    lambda: kvcache.paged_cache(
                        model, B, page_size=DRYRUN_PAGE_SIZE,
                        n_pages=B * mp, max_pages=mp)))
            else:
                cache_abs = _sds(jax.eval_shape(
                    lambda: model.init_cache(B, shape.seq_len)))
            c_sh = shd.cache_shardings(cache_abs, mesh)
            # spec lowers the verify chunk: spec_k + 1 tokens per slot in
            # one chunked decode step (the speculative round's target half)
            S = DRYRUN_SPEC_K + 1 if kernel == "spec" else 1
            tok_abs = jax.ShapeDtypeStruct((B, S), jnp.int32)
            t_sh = shd.batch_shardings({"tokens": tok_abs}, mesh)["tokens"]
            step = make_decode_step(model)
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh),
                             donate_argnums=(1,) if donate else ())
            with mesh:
                lowered = jitted.lower(p_abs, cache_abs, tok_abs)

    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            **({"kernel": kernel} if kernel != "gather" else {}),
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_chips": 512 if multi_pod else 256,
            "param_count": cfg.param_count(),
            "active_params": cfg.param_count(active_only=True),
            "accum_steps": step_cfg.accum_steps if shape.kind == "train" else None,
            "attn_mode": attn_mode,
            "seq_len": shape.seq_len, "global_batch": shape.global_batch}
    return lowered, meta


def _dp_size(mesh) -> int:
    return int(jnp.prod(jnp.array(
        [mesh.shape[a] for a in mesh.axis_names if a in ("pod", "data")])))


def _opt_shardings(o_abs, p_sh, mesh):
    """Moments mirror parameter shardings; scalars replicated."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def build(sub):
        return jax.tree_util.tree_map(lambda s: s, p_sh)

    out = {}
    for k, v in o_abs.items():
        if k in ("mu", "nu", "compress_residual"):
            out[k] = build(v)
        else:
            out[k] = jax.tree_util.tree_map(
                lambda x: NamedSharding(mesh, P()), v)
    return out


# ---------------------------------------------------------------------------
# Cell execution & reporting
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             compile_cell: bool = True, **kw) -> Dict:
    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch, shape_name, multi_pod=multi_pod, **kw)
    except Exception as e:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                **({"kernel": kw["kernel"]} if kw.get("kernel", "gather")
                   != "gather" else {}),
                "status": "LOWER_FAIL", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}
    rec = dict(meta)
    rec["lower_s"] = round(time.time() - t0, 2)
    if not compile_cell:
        rec["status"] = "LOWERED"
        return rec
    t1 = time.time()
    try:
        compiled = lowered.compile()
    except Exception as e:
        rec.update(status="COMPILE_FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        return rec
    rec["compile_s"] = round(time.time() - t1, 2)
    rec["status"] = "OK"

    # --- memory ---------------------------------------------------------------
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
        arg_b = rec["memory"]["argument_bytes"] or 0
        tmp_b = rec["memory"]["temp_bytes"] or 0
        rec["memory"]["per_device_total"] = arg_b + tmp_b
        rec["memory"]["fits_hbm"] = (arg_b + tmp_b) <= HBM_PER_CHIP
    except Exception as e:
        rec["memory"] = {"error": str(e)}

    # --- cost / flops ------------------------------------------------------------
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        rec["flops_per_device"] = float(cost.get("flops", 0.0))
        rec["hbm_bytes_per_device"] = float(cost.get("bytes accessed", 0.0))
    except Exception as e:
        rec["cost_error"] = str(e)
        rec["flops_per_device"] = 0.0
        rec["hbm_bytes_per_device"] = 0.0

    # --- collectives -----------------------------------------------------------
    try:
        text = compiled.as_text()
        stats = hlo_analysis.collective_stats(text)
        rec["collectives"] = {
            "bytes_by_kind": stats.bytes_by_kind,
            "count_by_kind": stats.count_by_kind,
            "wire_bytes_per_device": stats.wire_bytes,
        }
    except Exception as e:
        rec["collectives"] = {"error": str(e)}
    return rec


def run_matrix(mesh_mode: str = "both", archs=None, shapes=None,
               compile_cell: bool = True, kernel_mode: str = "gather", **kw):
    """``kernel_mode``: 'gather' is the classic matrix; 'paged' runs only
    the fused paged-kernel decode cells; 'spec' only the speculative
    verify-chunk decode cells; 'shardmap' only the shard_map lane-merge
    cells; 'both' appends paged + spec + shardmap to the classic matrix
    (the full 120-cell artifact)."""
    results = []
    archs = archs or configs.list_archs()
    for arch in archs:
        cfg = configs.get(arch)
        for shape_name in (shapes or cfg.shapes):
            if shape_name not in cfg.shapes:
                continue
            kernels = ({"gather": ["gather"], "paged": ["paged"],
                        "spec": ["spec"], "shardmap": ["shardmap"],
                        "both": ["gather", "paged", "spec", "shardmap"]}
                       [kernel_mode])
            for kern in kernels:
                if kern != "gather" and not paged_kernel_applicable(
                        cfg, SHAPES_BY_NAME[shape_name]):
                    continue
                for multi_pod in ([False, True] if mesh_mode == "both"
                                  else [mesh_mode == "multi"]):
                    tag = f" [{kern}]" if kern != "gather" else ""
                    print(f"=== {arch} x {shape_name} x "
                          f"{'2x16x16' if multi_pod else '16x16'}{tag} ===",
                          flush=True)
                    rec = run_cell(arch, shape_name, multi_pod=multi_pod,
                                   compile_cell=compile_cell, kernel=kern,
                                   **kw)
                    print(json.dumps(_summary(rec)), flush=True)
                    results.append(rec)
    return results


def _summary(rec: Dict) -> Dict:
    out = {k: rec.get(k) for k in ("arch", "shape", "mesh", "status",
                                   "lower_s", "compile_s")}
    if rec.get("kernel"):
        out["kernel"] = rec["kernel"]
    if rec.get("status") == "OK":
        out["flops/dev"] = f"{rec['flops_per_device']:.3e}"
        mem = rec.get("memory", {})
        if mem.get("per_device_total"):
            out["mem/dev_GB"] = round(mem["per_device_total"] / 2**30, 2)
        coll = rec.get("collectives", {})
        out["wire_MB/dev"] = round(coll.get("wire_bytes_per_device", 0) / 2**20, 1)
    else:
        out["error"] = rec.get("error")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--kernel", default="gather",
                    choices=["gather", "paged", "spec", "shardmap", "both"],
                    help="decode dispatch axis: 'paged' lowers only the "
                         "fused paged-attention decode cells, 'spec' only "
                         "the speculative verify-chunk cells, 'shardmap' "
                         "only the shard_map lane-merge cells, 'both' "
                         "appends paged + spec + shardmap to the classic "
                         "matrix")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.all:
        results = run_matrix(args.mesh, compile_cell=not args.no_compile,
                             kernel_mode=args.kernel)
    else:
        if not args.arch:
            ap.error("--arch required unless --all")
        cfg = configs.get(args.arch)
        shapes = [args.shape] if args.shape else list(cfg.shapes)
        results = run_matrix(args.mesh, archs=[args.arch], shapes=shapes,
                             compile_cell=not args.no_compile,
                             kernel_mode=args.kernel)
    n_ok = sum(1 for r in results if r.get("status") == "OK")
    print(f"\n{n_ok}/{len(results)} cells OK")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
