"""HLO-text analysis: collective-traffic accounting + roofline terms.

``compiled.as_text()`` for an SPMD-partitioned module is *per-device*: every
collective op's result shape is the per-device buffer.  We sum bytes per
collective category with a simple wire model (documented in EXPERIMENTS.md):

    all-gather          : result bytes       (each device receives ~result)
    all-to-all          : result bytes
    collective-permute  : result bytes
    all-reduce          : 2 x result bytes   (reduce-scatter + all-gather ring)
    reduce-scatter      : operand bytes      (each device sends ~input once)

Roofline terms (seconds, per step):
    compute    = HLO_FLOPs_total / (chips * peak)
    memory     = HLO_bytes_total / (chips * hbm_bw)
    collective = per_device_wire_bytes / ici_bw
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[dims]' shape string; tuples summed by caller."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dtype, dims = m.groups()
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(line: str, kind: str) -> int:
    """Sum the result-type shapes of an HLO instruction line (handles tuples).

    The result type is everything between '=' and the op name."""
    parts = line.split("=", 1)
    if len(parts) != 2:
        return 0
    rhs = parts[1]
    idx = re.search(rf"\b{kind}(-start)?\(", rhs)
    if idx is None:
        return 0
    typestr = rhs[: idx.start()]
    return sum(_shape_bytes(m.group(0)) for m in _SHAPE_RE.finditer(typestr))


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)
    wire_bytes: int = 0     # per-device, wire-model-weighted

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("%") or s.startswith("ROOT"):
            for kind in _COLLECTIVES:
                # match op name with optional '-start'/'-done' suffix
                if re.search(rf"= .*\b{kind}(-start)?\(", s):
                    if f"{kind}-done" in s:
                        continue  # avoid double-count of async pairs
                    b = _result_bytes(s, kind)
                    stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
                    stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
                    mult = 2 if kind == "all-reduce" else 1
                    stats.wire_bytes += mult * b
                    break
    return stats


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    wire_bytes_per_device: float
    n_chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def mfu_bound(self, model_flops: float) -> float:
        """Achievable MFU upper bound implied by the three terms."""
        if self.bound_s <= 0:
            return 0.0
        return model_flops / (self.n_chips * PEAK_FLOPS_BF16 * self.bound_s)


def roofline(flops_total: float, hbm_bytes_total: float,
             wire_bytes_per_device: float, n_chips: int,
             peak=PEAK_FLOPS_BF16, hbm_bw=HBM_BW, ici_bw=ICI_BW) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_total / (n_chips * peak),
        memory_s=hbm_bytes_total / (n_chips * hbm_bw),
        collective_s=wire_bytes_per_device / ici_bw,
        flops=flops_total,
        hbm_bytes=hbm_bytes_total,
        wire_bytes_per_device=wire_bytes_per_device,
        n_chips=n_chips,
    )
