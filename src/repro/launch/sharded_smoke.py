import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

"""Sharded-decode smoke: the same serving workload on one device and on an
8-device (2 data x 4 model) host mesh, asserted token-identical.

The two lines above MUST stay first (before any jax import): jax locks the
device count at first init.  Run this as its own process — never import it
from a process that wants the real device count.

For each mode the driver runs a small multi-session workload through
:class:`repro.serve.scheduler.DecodeScheduler` (chunked admission, paged
pool, the fused paged-attention backend by default — on the mesh that is
the shard_map lane/head decomposition), then measures

  * steady-state decode-step wall latency (post-warm, timed solo),
  * per-step collective wire bytes from the compiled decode step's HLO
    (``launch/hlo_analysis.py``) — the lane-sharded budget gate: the
    shard_map merge ships per-head softmax statistics, whose size is
    independent of the pool, so the decode step is compiled twice (default
    pool and 4x pool) and the wire bytes must NOT grow with the pool.  At
    this reduced scale fixed collectives (logits, embeddings) dominate the
    absolute number, so the growth — not the total — is what catches a
    full-pool all-gather regressing in.

The default arch is dense (``minicpm-2b``): dense holds the *strict*
1-device == 8-device token-parity claim (cross-shard bf16 reduction drift
stays inside its argmax margins; see tests/test_sched_differential.py's
sharded section for why moe/hybrid compare mesh-vs-mesh instead).

Usage:
  PYTHONPATH=src python -m repro.launch.sharded_smoke --out smoke.json
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..models import build_model
from ..serve.scheduler import DecodeScheduler
from . import hlo_analysis

PAGE_SIZE = 4            # divides the mesh's model axis -> lane decomposition
N_SLOTS = 4              # divides the mesh's data axis


def _drive(sched, cfg, *, n_requests, sessions, prompt_len, max_new, seed=0):
    rng = np.random.default_rng(seed)
    for i in range(n_requests):
        sched.submit(f"s{i % sessions}", f"r{i}",
                     rng.integers(0, cfg.vocab,
                                  size=prompt_len).astype(np.int32),
                     max_new)
    outputs = {}
    steps = 0
    while sched.busy():
        for fin in sched.step():
            outputs[fin.request_id] = np.asarray(fin.tokens).tolist()
        steps += 1
        assert steps < 2000, "sharded smoke failed to drain"
    return outputs, steps


def _decode_args(sched):
    return (sched.params, sched.cache, sched.last_tokens, sched.out_buf,
            sched.out_pos, jnp.ones((sched.n_slots,), bool),
            jax.random.key(0))


def _decode_wire_bytes(sched):
    stats = hlo_analysis.collective_stats(
        sched._decode.lower(*_decode_args(sched)).compile().as_text())
    return int(stats.wire_bytes), dict(stats.count_by_kind)


def _cache_bytes(sched) -> int:
    return int(sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(sched.cache)))


def _decode_step_stats(sched, *, reps=20):
    """Steady-state decode dispatch: wall latency (solo, post-warm) and the
    compiled step's per-device collective wire bytes."""
    args = _decode_args(sched)
    jax.block_until_ready(sched._decode(*args))          # warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(sched._decode(*args))
        times.append((time.perf_counter() - t0) * 1e3)
    wire, kinds = _decode_wire_bytes(sched)
    return {
        "decode_ms_p50": round(float(np.percentile(times, 50)), 3),
        "decode_ms_mean": round(float(np.mean(times)), 3),
        "wire_bytes_per_step": wire,
        "collectives_by_kind": kinds,
    }


def run_smoke(arch="minicpm-2b", *, attn_backend="paged_kernel",
              n_requests=6, sessions=3, prompt_len=12, max_new=6):
    cfg = configs.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    max_seq = prompt_len + max_new

    result = {"arch": arch, "backend": attn_backend,
              "requests": n_requests, "sessions": sessions,
              "prompt_len": prompt_len, "max_new": max_new}
    modes = {"single": None,
             "sharded": jax.make_mesh((2, 4), ("data", "model"))}
    outputs = {}
    for name, mesh in modes.items():
        sched = DecodeScheduler(model, params, n_slots=N_SLOTS,
                                max_seq=max_seq, page_size=PAGE_SIZE,
                                prefill_chunk=PAGE_SIZE, mesh=mesh,
                                attn_backend=attn_backend)
        outs, steps = _drive(sched, cfg, n_requests=n_requests,
                             sessions=sessions, prompt_len=prompt_len,
                             max_new=max_new)
        outputs[name] = outs
        row = {"steps": steps, "devices": 1 if mesh is None else mesh.size,
               **_decode_step_stats(sched)}
        if mesh is not None:
            row["mesh"] = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
            result["pool_bytes"] = _cache_bytes(sched)
            # lane-sharded wire budget: recompile against a 4x pool — the
            # merge ships softmax statistics (pool-size-independent), so
            # wire bytes growing with the pool means pages on the wire
            big = DecodeScheduler(model, params, n_slots=N_SLOTS,
                                  max_seq=max_seq, page_size=PAGE_SIZE,
                                  prefill_chunk=PAGE_SIZE, mesh=mesh,
                                  attn_backend=attn_backend,
                                  kv_pages=4 * sched.n_pages)
            wire_big, _ = _decode_wire_bytes(big)
            row["wire_bytes_per_step_4x_pool"] = wire_big
            result["pool_bytes_4x"] = _cache_bytes(big)
        result[name] = row

    result["identical_outputs"] = outputs["single"] == outputs["sharded"]
    sh = result["sharded"]
    pool_growth = result["pool_bytes_4x"] - result["pool_bytes"]
    wire_growth = (sh["wire_bytes_per_step_4x_pool"]
                   - sh["wire_bytes_per_step"])
    result["wire_growth_bytes"] = wire_growth
    result["wire_growth_budget_bytes"] = pool_growth // 2
    result["wire_within_budget"] = wire_growth < pool_growth // 2
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b",
                    choices=configs.list_archs())
    ap.add_argument("--attn-backend", default="paged_kernel",
                    choices=["gather", "paged_kernel"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--sessions", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run_smoke(args.arch, attn_backend=args.attn_backend,
                    n_requests=args.requests, sessions=args.sessions,
                    prompt_len=args.prompt_len, max_new=args.max_new)
    print(f"{res['arch']} [{res['backend']}]: "
          f"1-dev {res['single']['decode_ms_p50']} ms/step vs "
          f"{res['sharded']['devices']}-dev ({res['sharded']['mesh']}) "
          f"{res['sharded']['decode_ms_p50']} ms/step, "
          f"{res['sharded']['wire_bytes_per_step']} wire B/step "
          f"(growth over 4x pool {res['wire_growth_bytes']} B, "
          f"budget {res['wire_growth_budget_bytes']}), "
          f"identical_outputs={res['identical_outputs']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)
        print(f"wrote {args.out}")
    if not (res["identical_outputs"] and res["wire_within_budget"]):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
