"""Serving driver: FaaSKeeper queue/batcher front + jitted decode back end.

Requests enter through the paper's per-session FIFO queues, route into one
shared dispatch queue, and are served either by the continuous-batching
decode scheduler (decoder-only families: slots re-admitted across sessions
between decode steps) or by whole-batch generation (enc-dec families) — the
serverless request path with a real model behind it.  ``mode='per-session'``
runs the old one-queue-per-session batcher as the cost baseline.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --requests 12 \
      --sessions 3 --batch-size 4 --prompt-len 16
"""

from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..coord.serving_front import InferenceRequest, ServingFrontend
from ..core import SimCloud
from ..core.storage import PageBlobStore
from ..models import build_model
from ..serve.engine import make_decode_step, make_prefill
from ..serve.fleet import FleetController
from ..serve.scheduler import DecodeScheduler, supports_continuous


def _whole_batch_model_fn(model, params, max_new: int):
    decode = jax.jit(make_decode_step(model))
    prefills = {}   # per prompt length: cache sized prompt + decode budget,
    # so the decoder ring never wraps and evicts prompt keys mid-generation

    def model_fn(prompts: List[np.ndarray]) -> List[np.ndarray]:
        toks = jnp.asarray(np.stack(prompts))
        P = toks.shape[1]
        prefill = prefills.get(P)
        if prefill is None:
            prefill = prefills[P] = jax.jit(make_prefill(model, seq_len=P + max_new))
        tok, cache = prefill(params, toks)
        outs = [tok]
        for _ in range(max_new - 1):
            tok, _, cache = decode(params, cache, tok[:, None])
            outs.append(tok)
        gen = np.asarray(jnp.stack(outs, axis=1))
        return [gen[i] for i in range(gen.shape[0])]

    return model_fn


def validate_pool_sizing(*, batch_size: int, prompt_len: int, max_new: int,
                         page_size: int, kv_pages: Optional[int] = None,
                         prefill_chunk: Optional[int] = None,
                         offload: bool = False) -> int:
    """Fail fast — at startup, with the arithmetic spelled out — instead of
    letting an undersized pool stall the first admission mid-run.

    Without offload the pool must fit **one max-size admission plus one
    active decode batch**: the largest request this workload can submit
    reserves ``ceil((prompt_len + max_new - 1) / page_size)`` pages up front
    (the reservation gate), and while it chunks in, every other slot must
    still be able to map its next decode page — one more page per remaining
    slot.  With ``offload`` the preemption policy converts pool pressure
    into bounded preempt/restore cycles, so the floor relaxes to the one
    hard requirement: the largest single admission must fit on its own
    (even evicting every other slot cannot conjure more pages than the
    pool holds).  Returns the minimum page count so callers can echo it.
    """
    if page_size < 1:
        raise ValueError(f"--page-size must be >= 1, got {page_size}")
    if prefill_chunk is not None and prefill_chunk < 1:
        raise ValueError(f"--prefill-chunk must be >= 1, got {prefill_chunk}")
    admission_pages = -(-(prompt_len + max_new - 1) // page_size)
    min_pages = (admission_pages if offload
                 else admission_pages + (batch_size - 1))
    if kv_pages is not None and kv_pages < min_pages:
        if offload:
            raise ValueError(
                f"--kv-pages {kv_pages} cannot fit even one max-size "
                f"admission: a {prompt_len}-token prompt with {max_new} "
                f"decode tokens reserves "
                f"ceil(({prompt_len}+{max_new}-1)/{page_size}) = "
                f"{admission_pages} pages, and preempting every other slot "
                f"cannot make the pool larger than it is.  Raise --kv-pages, "
                f"shrink --prompt-len/--max-new, or grow --page-size.")
        raise ValueError(
            f"--kv-pages {kv_pages} cannot fit one max-size admission plus "
            f"one active decode batch: a {prompt_len}-token prompt with "
            f"{max_new} decode tokens reserves "
            f"ceil(({prompt_len}+{max_new}-1)/{page_size}) = "
            f"{admission_pages} pages, and the other {batch_size - 1} slots "
            f"need one decode page each -> minimum {min_pages} pages.  "
            f"Raise --kv-pages, shrink --prompt-len/--max-new, grow "
            f"--page-size, reduce --batch-size, or enable --offload (which "
            f"turns pool pressure into bounded preempt/restore cycles); "
            f"otherwise the first oversized request stalls in the pending "
            f"queue forever.")
    return min_pages


def build_frontend(cloud: SimCloud, cfg, model, params, *, mode: str,
                   batch_size: int, max_new: int, prompt_len: int,
                   temperature: float = 0.0, top_k: int = 0,
                   mesh=None, kv_mode: str = "paged", page_size: int = 16,
                   prefill_chunk: Optional[int] = None,
                   kv_pages: Optional[int] = None, offload: bool = False,
                   preempt_policy: Optional[str] = None,
                   idle_preempt_steps: int = 0,
                   prefix_sharing: bool = False,
                   park_sessions: bool = False,
                   park_ttl_steps: int = 0,
                   attn_backend: str = "gather",
                   draft_model=None, draft_params=None,
                   spec_k: int = 0,
                   fleet_size: int = 0, min_workers: int = 0,
                   scale_to_zero: bool = False) -> ServingFrontend:
    """Frontend for ``mode`` in {'continuous', 'shared', 'per-session'}.

    ``continuous`` falls back to the shared whole-batch flavour for families
    without a per-slot decode path (enc-dec).  ``kv_mode='paged'`` (default)
    serves from the shared paged-block KV pool with chunked prefill;
    ``'ring'`` keeps the per-slot ring + monolithic-prefill baseline.
    ``offload`` enables storage-backed preemption; ``prefix_sharing`` maps
    indexed prompt prefixes read-only with copy-on-write splits;
    ``park_sessions`` retains a completed session's KV across requests
    (``park_ttl_steps`` bounds the retention window; paged mode only).
    ``draft_model``/``draft_params`` + ``spec_k >= 1`` turn on draft-and-
    verify speculative decoding (greedy, paged, gather backend only —
    output is token-for-token what non-speculative decode produces).
    """
    if mode not in ("continuous", "shared", "per-session"):
        raise ValueError(f"unknown serving mode {mode!r}")
    if fleet_size:
        if mode != "continuous" or not supports_continuous(cfg):
            raise ValueError("--fleet needs the continuous scheduler "
                             "(decoder-only families)")
        if kv_mode != "paged" or cfg.family == "ssm":
            raise ValueError("--fleet needs the paged KV pool "
                             "(parked journals are page blobs)")
        validate_pool_sizing(batch_size=batch_size, prompt_len=prompt_len,
                             max_new=max_new, page_size=page_size,
                             kv_pages=kv_pages, prefill_chunk=prefill_chunk,
                             offload=offload)
        store = PageBlobStore()     # the fleet's shared durable substrate
        workers = [DecodeScheduler(model, params, n_slots=batch_size,
                                   max_seq=prompt_len + max_new,
                                   temperature=temperature, top_k=top_k,
                                   mesh=mesh, kv_mode="paged",
                                   page_size=page_size,
                                   prefill_chunk=prefill_chunk,
                                   kv_pages=kv_pages, offload=offload,
                                   preempt_policy=preempt_policy,
                                   idle_preempt_steps=idle_preempt_steps,
                                   prefix_sharing=prefix_sharing,
                                   park_sessions=True,
                                   park_ttl_steps=park_ttl_steps,
                                   blob_store=store, index_journal=True,
                                   attn_backend=attn_backend,
                                   draft_model=draft_model,
                                   draft_params=draft_params, spec_k=spec_k)
                   for _ in range(fleet_size)]
        ctrl = FleetController(workers, min_workers=min_workers,
                               scale_to_zero=scale_to_zero)
        return ServingFrontend(cloud, fleet=ctrl, batch_size=batch_size)
    if mode == "continuous" and supports_continuous(cfg):
        if kv_mode == "paged" and cfg.family != "ssm":
            validate_pool_sizing(batch_size=batch_size, prompt_len=prompt_len,
                                 max_new=max_new, page_size=page_size,
                                 kv_pages=kv_pages,
                                 prefill_chunk=prefill_chunk,
                                 offload=offload)
        sched = DecodeScheduler(model, params, n_slots=batch_size,
                                max_seq=prompt_len + max_new,
                                temperature=temperature, top_k=top_k,
                                mesh=mesh, kv_mode=kv_mode,
                                page_size=page_size,
                                prefill_chunk=prefill_chunk,
                                kv_pages=kv_pages, offload=offload,
                                preempt_policy=preempt_policy,
                                idle_preempt_steps=idle_preempt_steps,
                                prefix_sharing=prefix_sharing,
                                park_sessions=park_sessions,
                                park_ttl_steps=park_ttl_steps,
                                attn_backend=attn_backend,
                                draft_model=draft_model,
                                draft_params=draft_params, spec_k=spec_k)
        return ServingFrontend(cloud, scheduler=sched, batch_size=batch_size)
    if temperature or top_k:
        raise ValueError(
            "temperature/top-k sampling needs the continuous scheduler "
            f"(decoder-only families); the {cfg.family!r}/{mode!r} "
            "whole-batch path decodes greedily")
    front_mode = "per-session" if mode == "per-session" else "shared"
    model_fn = _whole_batch_model_fn(model, params, max_new)
    return ServingFrontend(cloud, model_fn, batch_size=batch_size,
                           mode=front_mode)


def spawn_workload(cloud: SimCloud, frontend: ServingFrontend, *, vocab: int,
                   n_requests: int, sessions: int, prompt_len: int,
                   max_new: int, seed: int = 0) -> None:
    """Spawn the standard serving workload: requests round-robin across
    ``sessions`` concurrent clients, each session pipelining its requests
    over its own FIFO channel (order within a session preserved — paper
    §3.2 "vertical scaling"); different sessions submit concurrently, and
    the shared dispatch queue batches across their arrivals.  The caller
    runs the cloud."""
    rng = np.random.default_rng(seed)
    per_session = {}
    for i in range(n_requests):
        sess = f"s{i % sessions}"
        per_session.setdefault(sess, []).append(InferenceRequest(
            session=sess, request_id=f"r{i}",
            prompt=rng.integers(0, vocab, size=prompt_len).astype(np.int32),
            max_tokens=max_new))

    def session_driver(reqs):
        for req in reqs:
            yield from frontend.submit(req)
        return None

    for sess, reqs in per_session.items():
        cloud.spawn(session_driver(reqs), name=f"client:{sess}")


def _parse_mesh(spec: Optional[str]):
    """``"2x4"`` -> a ``(data, model)`` device mesh; ``None`` passes through.

    The scheduler treats a mesh as the switch into its shard_map execution
    mode: slots shard over ``data``, heads/lanes over ``model``.  Fails
    loudly when the host does not expose enough devices — on CPU, spoof
    them with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    if spec is None:
        return None
    try:
        dp, mp = (int(p) for p in spec.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--mesh wants DPxMP (e.g. 2x4), got {spec!r}")
    if dp * mp > jax.device_count():
        raise SystemExit(
            f"--mesh {spec} needs {dp * mp} devices, have "
            f"{jax.device_count()} (CPU: set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={dp * mp})")
    return jax.make_mesh((dp, mp), ("data", "model"))


def run_serving(arch: str, n_requests: int = 12, *, max_new: int = 8,
                prompt_len: int = 16, sessions: int = 3, batch_size: int = 4,
                mode: str = "continuous", temperature: float = 0.0,
                top_k: int = 0, seed: int = 0, quiet: bool = False,
                kv_mode: str = "paged", page_size: int = 16,
                prefill_chunk: Optional[int] = None, kv_pages: Optional[int] = None,
                offload: bool = False, preempt_policy: Optional[str] = None,
                idle_preempt_steps: int = 0,
                prefix_sharing: bool = False, park_sessions: bool = False,
                park_ttl_steps: int = 0, attn_backend: str = "gather",
                spec_draft: Optional[str] = None, spec_k: int = 0,
                mesh: Optional[str] = None,
                fleet: int = 0, min_workers: int = 0,
                max_workers: Optional[int] = None,
                scale_to_zero: bool = False):
    cfg = configs.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    draft_model = draft_params = None
    if spec_draft is not None:
        if spec_draft == arch:              # self-draft: reuse the weights
            draft_model, draft_params = model, params
        else:
            draft_model = build_model(configs.get(spec_draft).reduced())
            draft_params = draft_model.init(jax.random.key(0))
        spec_k = spec_k or 3

    cloud = SimCloud(seed=seed)
    frontend = build_frontend(cloud, cfg, model, params, mode=mode,
                              mesh=_parse_mesh(mesh),
                              batch_size=batch_size, max_new=max_new,
                              prompt_len=prompt_len, temperature=temperature,
                              top_k=top_k, kv_mode=kv_mode,
                              page_size=page_size,
                              prefill_chunk=prefill_chunk, kv_pages=kv_pages,
                              offload=offload, preempt_policy=preempt_policy,
                              idle_preempt_steps=idle_preempt_steps,
                              prefix_sharing=prefix_sharing,
                              park_sessions=park_sessions,
                              park_ttl_steps=park_ttl_steps,
                              attn_backend=attn_backend,
                              draft_model=draft_model,
                              draft_params=draft_params, spec_k=spec_k,
                              fleet_size=(max_workers or fleet) if fleet else 0,
                              min_workers=min_workers,
                              scale_to_zero=scale_to_zero)
    t0 = time.time()
    spawn_workload(cloud, frontend, vocab=cfg.vocab, n_requests=n_requests,
                   sessions=sessions, prompt_len=prompt_len, max_new=max_new)
    cloud.run()
    served = sum(len(v) for v in frontend.completions.values())
    if not quiet:
        print(f"served {served}/{n_requests} requests in {time.time()-t0:.1f}s wall "
              f"({cloud.now:.3f}s simulated)")
        for sess, ids in sorted(frontend.completions.items()):
            print(f"  session {sess}: completions in order {ids}")
        stats = frontend.runtime.stats.get("serve")
        inv = stats.invocations if stats else 0
        dropped = frontend.dropped_requests()
        line = (f"function invocations: {inv} "
                f"(batching {served}/{inv} = "
                f"{served/inv if inv else 0.0:.1f} req/invoke); "
                f"cost ${frontend.runtime.cost_usd():.6f}; "
                f"dropped {dropped} (dead-letter {frontend.dead_letter_ids()})")
        print(line)
        if frontend.fleet is not None:
            s = frontend.serving_stats()
            print(f"fleet: {s['spawns']} spawns / {s['retires']} retires "
                  f"({s['cold_starts_from_zero']} from zero), "
                  f"{s['workers_live']}/{s['workers_max']} live at exit, "
                  f"{s['meta_puts']} park-metas committed / "
                  f"{s['meta_adoptions']} adopted, "
                  f"{s['index_journal_puts']} index blobs journaled / "
                  f"{s['index_adopted']} re-adopted")
            print(f"fleet billing: {s['worker_invocations']} worker "
                  f"invocations (${s['worker_cost_usd']:.6f}), storage "
                  f"${s['offload_storage_usd']:.6f} ops + "
                  f"${s['park_storage_usd']:.9f} retention")
        if frontend.scheduler is not None:
            s = frontend.serving_stats()
            print(f"decode scheduler: occupancy {s['occupancy']:.2f} "
                  f"slots/step over {s['steps']} steps, "
                  f"{s['decode_tokens']} decode + {s['prefill_tokens']} "
                  f"prefill tokens")
            if s.get("kv_mode") == "paged":
                print(f"kv pool: {s['kv_pages_high_water']}/{s['kv_pages']} "
                      f"pages high-water ({s['kv_high_water_bytes']/1024:.1f} "
                      f"of {s['kv_pool_bytes']/1024:.1f} KiB), "
                      f"{s['prefill_chunks']} prefill chunks")
            if "offload_bytes" in s:
                print(f"kv offload: {s['preemptions']} preemptions / "
                      f"{s['restores']} restores, "
                      f"{s['offload_bytes']/1024:.1f} KiB offloaded + "
                      f"{s['restore_bytes']/1024:.1f} KiB restored "
                      f"({s['offload_puts']} puts / {s['offload_gets']} gets, "
                      f"storage ${s.get('offload_storage_usd', 0.0):.6f})")
            if "spec_rounds" in s:
                print(f"speculation: k={s['spec_k']}, {s['spec_rounds']} "
                      f"rounds, acceptance "
                      f"{s['spec_acceptance_rate']:.2f}, "
                      f"{s['spec_steps_per_token']:.2f} steps/token "
                      f"({s['spec_emitted']} tokens emitted)")
            if "shared_prefix_tokens" in s:
                print(f"prefix sharing: {s['shared_prefix_tokens']} prompt "
                      f"tokens served from resident pages "
                      f"({s['park_hits']} park hits / {s['index_hits']} index "
                      f"hits, {s['cow_splits']} CoW splits, "
                      f"{s['parked_sessions']} sessions parked, retention "
                      f"${s.get('park_storage_usd', 0.0):.9f})")
    return frontend


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--sessions", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=4,
                    help="dispatch batch width == decode slots")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--mode", default="continuous",
                    choices=["continuous", "shared", "per-session"])
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--kv-mode", default="paged", choices=["paged", "ring"],
                    help="paged-block KV pool (default) or per-slot rings")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV pool page")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="admission chunk size in tokens (default: whole prompt)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="pool size in pages (default: slots x max_pages)")
    ap.add_argument("--offload", action="store_true",
                    help="storage-backed preemption: evict a victim slot's "
                         "KV pages to the object store under pool pressure "
                         "and restore them chunked (paged mode only)")
    ap.add_argument("--preempt-policy", default=None,
                    choices=["none", "pressure"],
                    help="victim policy (default: pressure when --offload)")
    ap.add_argument("--idle-preempt-steps", type=int, default=0,
                    help="minimum steps a slot must be resident before it "
                         "is preemptible (anti-thrash floor)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="map indexed prompt prefixes read-only from the "
                         "refcounted page pool and prefill only the tail "
                         "(copy-on-write on shared-page writes; paged only)")
    ap.add_argument("--park-sessions", action="store_true",
                    help="retain a completed session's KV pages across "
                         "requests so its next request restores instead of "
                         "re-prefilling (paged only)")
    ap.add_argument("--park-ttl-steps", type=int, default=0,
                    help="drop a parked session after this many scheduler "
                         "steps (0 = retain until evicted or reset)")
    ap.add_argument("--attn-backend", default="gather",
                    choices=["gather", "paged_kernel"],
                    help="decode attention over the paged pool: materialize "
                         "the gathered view in HBM (reference) or stream "
                         "pages through the Pallas table-indirect kernel")
    ap.add_argument("--spec-draft", default=None, choices=configs.list_archs(),
                    help="draft arch for draft-and-verify speculative "
                         "decoding (same arch = self-draft; greedy + paged + "
                         "gather backend only; output stays token-identical "
                         "to non-speculative decode)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="draft tokens proposed per verify round "
                         "(default 3 when --spec-draft is set)")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="serve with an elastic fleet of N disposable "
                         "scheduler workers behind the shared dispatch "
                         "queue (paged + parked sessions implied); 0 = one "
                         "resident scheduler (default)")
    ap.add_argument("--min-workers", type=int, default=0,
                    help="always-warm worker floor the autoscaler holds")
    ap.add_argument("--max-workers", type=int, default=None,
                    help="worker ceiling (default: the --fleet count)")
    ap.add_argument("--scale-to-zero", action="store_true",
                    help="let the fleet drain-and-park every worker when "
                         "idle; the next burst cold-starts from the blob "
                         "store (parked journals + index blobs)")
    ap.add_argument("--mesh", default=None, metavar="DPxMP",
                    help="run the decode scheduler sharded over a device "
                         "mesh, e.g. 2x4 = slots over 2-way data, "
                         "heads/KV lanes over 4-way model (CPU: spoof "
                         "devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    args = ap.parse_args()
    run_serving(args.arch, args.requests, max_new=args.max_new,
                sessions=args.sessions, batch_size=args.batch_size,
                prompt_len=args.prompt_len, mode=args.mode,
                temperature=args.temperature, top_k=args.top_k,
                kv_mode=args.kv_mode, page_size=args.page_size,
                prefill_chunk=args.prefill_chunk, kv_pages=args.kv_pages,
                offload=args.offload, preempt_policy=args.preempt_policy,
                idle_preempt_steps=args.idle_preempt_steps,
                prefix_sharing=args.prefix_sharing,
                park_sessions=args.park_sessions,
                park_ttl_steps=args.park_ttl_steps,
                attn_backend=args.attn_backend,
                spec_draft=args.spec_draft, spec_k=args.spec_k,
                mesh=args.mesh, fleet=args.fleet,
                min_workers=args.min_workers, max_workers=args.max_workers,
                scale_to_zero=args.scale_to_zero)


if __name__ == "__main__":
    main()
