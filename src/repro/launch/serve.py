"""Serving driver: FaaSKeeper queue/batcher front + jitted decode back end.

Requests enter through the paper's per-session FIFO queues (batched
event-function invocation, ordered completion) and are served by a reduced
model's prefill+decode loop — the serverless request path with a real model
behind it.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --requests 12
"""

from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..coord.serving_front import InferenceRequest, ServingFrontend
from ..core import SimCloud
from ..models import build_model
from ..serve.engine import make_decode_step, make_prefill


def run_serving(arch: str, n_requests: int = 12, *, max_new: int = 8,
                prompt_len: int = 16, sessions: int = 3, batch_size: int = 4):
    cfg = configs.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prefill = jax.jit(make_prefill(model))
    decode = jax.jit(make_decode_step(model))

    def model_fn(prompts: List[np.ndarray]) -> List[np.ndarray]:
        toks = jnp.asarray(np.stack(prompts))
        tok, cache = prefill(params, toks)
        outs = [tok]
        for _ in range(max_new - 1):
            tok, _, cache = decode(params, cache, tok[:, None])
            outs.append(tok)
        gen = np.asarray(jnp.stack(outs, axis=1))
        return [gen[i] for i in range(gen.shape[0])]

    cloud = SimCloud(seed=0)
    frontend = ServingFrontend(cloud, model_fn, batch_size=batch_size)
    rng = np.random.default_rng(0)
    t0 = time.time()
    # each session pipelines its requests over its own FIFO channel (order
    # within a session preserved — paper §3.2 "vertical scaling"); different
    # sessions submit concurrently, so the queue batches across arrivals
    per_session = {f"s{i % sessions}": [] for i in range(n_requests)}
    for i in range(n_requests):
        prompt = rng.integers(0, cfg.vocab, size=prompt_len).astype(np.int32)
        per_session[f"s{i % sessions}"].append(
            InferenceRequest(session=f"s{i % sessions}", request_id=f"r{i}",
                             prompt=prompt, max_tokens=max_new))

    def session_driver(reqs):
        for req in reqs:
            yield from frontend.submit(req)
        return None

    for sess, reqs in per_session.items():
        cloud.spawn(session_driver(reqs), name=f"client:{sess}")
    cloud.run()
    served = sum(len(v) for v in frontend.completions.values())
    print(f"served {served}/{n_requests} requests in {time.time()-t0:.1f}s wall "
          f"({cloud.now:.3f}s simulated)")
    for sess, ids in sorted(frontend.completions.items()):
        print(f"  session {sess}: completions in order {ids}")
    stats = frontend.runtime.stats.get("serve")
    print(f"function invocations: {stats.invocations} "
          f"(batching {n_requests}/{stats.invocations} = "
          f"{n_requests/stats.invocations:.1f} req/invoke); "
          f"cost ${frontend.runtime.cost_usd():.6f}")
    return frontend


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.list_archs())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--sessions", type=int, default=3)
    args = ap.parse_args()
    run_serving(args.arch, args.requests, max_new=args.max_new,
                sessions=args.sessions)


if __name__ == "__main__":
    main()
