"""The repo-aware static-analysis suite: every rule must flag its seeded
violation and pass its clean counterpart, the suppression pragma must
waive findings only when justified, the JSON report must keep its schema
(CI archives it as an artifact), and — the point of the whole exercise —
a self-run over ``src/`` must come back clean."""

from __future__ import annotations

import json
from pathlib import Path

import pytest

from repro.analysis import analyze, default_rules, render_json
from repro.analysis.__main__ import main as cli_main
from repro.analysis.engine import RepoContext

REPO = Path(__file__).resolve().parents[1]


def run(tmp_path, source, name="fixture.py", rules=None):
    f = tmp_path / name
    f.write_text(source)
    return analyze([f], rules=rules)


def rule_ids(report):
    return [f.rule for f in report.findings]


# ---------------------------------------------------------------------------
# jit-purity
# ---------------------------------------------------------------------------


BAD_JIT = """
import random
import jax

class Sched:
    def build(self):
        self._decode = jax.jit(self._step)

    def _step(self, x):
        self.log.append(1)                 # container mutation
        self._key = self._key + 1          # host-state write
        return self._helper(x) + random.random()

    def _helper(self, x, scratch=[]):      # mutable default
        import time
        return x + time.time()

def make_decode_step(model):
    def step(params, cache):
        open("/tmp/x")                     # host IO
        return params
    return step
"""

GOOD_JIT = """
import jax
import jax.numpy as jnp

class Sched:
    def build(self):
        self._decode = jax.jit(self._step)

    def _step(self, x, key):
        return self._helper(x) * jax.random.uniform(key)

    def _helper(self, x):
        return jnp.tanh(x)

    def host_side(self):
        self.counter = 1          # not jit-reachable: allowed
"""


def test_jit_purity_flags_host_effects(tmp_path):
    report = run(tmp_path, BAD_JIT)
    msgs = [f.message for f in report.findings]
    assert all(r == "jit-purity" for r in rule_ids(report))
    assert any("mutates host container" in m for m in msgs)
    assert any("writes host state through `self`" in m for m in msgs)
    assert any("random.random" in m for m in msgs)
    assert any("time.time" in m for m in msgs), \
        "call-graph closure must reach `_helper` via `self._helper(x)`"
    assert any("mutable default" in m for m in msgs)
    assert any("`step`" in m and "open()" in m for m in msgs), \
        "make_* factory inner functions are jit roots"


def test_jit_purity_passes_pure_traced_code(tmp_path):
    assert run(tmp_path, GOOD_JIT).findings == []


def test_jit_purity_resolves_dotted_cross_module_roots(tmp_path):
    pkg = tmp_path / "repro"
    (pkg / "models").mkdir(parents=True)
    (pkg / "serve").mkdir()
    for d in (pkg, pkg / "models", pkg / "serve"):
        (d / "__init__.py").write_text("")
    (pkg / "models" / "helpers.py").write_text(
        "import time\n"
        "def gather(c, rows):\n"
        "    return c + time.time()\n")    # impure, only flagged if rooted
    (pkg / "serve" / "driver.py").write_text(
        "import jax\n"
        "from ..models import helpers\n"
        "extract = jax.jit(helpers.gather)\n")
    report = analyze([pkg])
    assert any(f.rule == "jit-purity" and "helpers.py" in f.path
               for f in report.findings), \
        "jax.jit(module.fn) must root fn in the *other* module"


# ---------------------------------------------------------------------------
# allocator-discipline
# ---------------------------------------------------------------------------


BAD_ALLOC = """
def leak_on_exception(allocator, n):
    pids = allocator.alloc(n)
    try:
        validate(n)
    except ValueError:
        return None            # leaks pids
    allocator.release(pids)

def drops_result(allocator):
    allocator.alloc(2)

def frees(allocator, pids):
    allocator.free(pids)

def share_unrecorded(allocator, pid, cond):
    allocator.share([pid])
    if cond:
        return True            # reference never recorded on this path
    table[0] = pid
"""

GOOD_ALLOC = """
def clean_exception_path(allocator, slot, n):
    pids = allocator.alloc(n)
    try:
        validate(n)
    except ValueError:
        allocator.release(pids)
        return None
    slot.pages = list(pids)

def direct_consume(allocator, slot):
    slot.pages.append(allocator.alloc(1)[0])

def share_recorded(index, allocator, pid, h):
    allocator.share([pid])
    index._pages[h] = int(pid)

def transfer_to_callee(allocator, slot, n):
    pids = allocator.alloc(n)
    install(slot, pids)        # ownership handed to the callee
"""


def test_allocator_flags_leaks(tmp_path):
    report = run(tmp_path, BAD_ALLOC)
    assert all(r == "allocator-discipline" for r in rule_ids(report))
    msgs = [f.message for f in report.findings]
    assert any("exception path" in m for m in msgs), \
        "the try/except leak must be attributed to the exception path"
    assert any("dropped" in m for m in msgs)
    assert any("free(" in m and "release()" in m for m in msgs)
    assert any("share()" in m for m in msgs)
    assert len(report.findings) == 4


def test_allocator_passes_disciplined_paths(tmp_path):
    assert run(tmp_path, GOOD_ALLOC).findings == []


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------


BAD_LIFECYCLE = """
from repro.serve.lifecycle import SlotState

def bypass(slot):
    slot.state = SlotState.ACTIVE

def illegal_chain(slot):
    slot.to(SlotState.EMPTY).to(SlotState.ACTIVE)

def illegal_guarded(slot):
    if slot.state is SlotState.ACTIVE:
        slot.to(SlotState.ADMITTING)

def typo(slot):
    return slot.state is SlotState.ACTIV

def sneaky_reset(slot):
    slot.force_empty()
"""

GOOD_LIFECYCLE = """
from repro.serve.lifecycle import SlotState

def admit(slot):
    slot.to(SlotState.ADMITTING).to(SlotState.ACTIVE)

def drain(slot):
    if slot.state is SlotState.ACTIVE:
        slot.to(SlotState.DRAINED)

def reset(slots):
    return [s.force_empty() for s in slots]

def record_state(rec, value):
    rec.state = value      # some other .state attribute, not a SlotState
"""


def test_lifecycle_flags_bypass_and_illegal_edges(tmp_path):
    report = run(tmp_path, BAD_LIFECYCLE)
    assert all(r == "lifecycle" for r in rule_ids(report))
    msgs = [f.message for f in report.findings]
    assert any("bypasses the transition table" in m for m in msgs)
    assert any("EMPTY -> ACTIVE" in m for m in msgs)
    assert any("ACTIVE -> ADMITTING" in m for m in msgs)
    assert any("SlotState.ACTIV" in m for m in msgs)
    assert any("force_empty() outside reset()" in m for m in msgs)


def test_lifecycle_passes_table_conforming_code(tmp_path):
    assert run(tmp_path, GOOD_LIFECYCLE).findings == []


def test_lifecycle_table_parsed_from_source():
    ctx = RepoContext()
    from repro.serve.lifecycle import TRANSITIONS, SlotState
    assert ctx.states == {s.name for s in SlotState}
    assert ctx.transitions == {
        src.name: {d.name for d in dsts} for src, dsts in TRANSITIONS.items()}


# ---------------------------------------------------------------------------
# kernel-rules
# ---------------------------------------------------------------------------


BAD_KERNEL = """
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

def _kernel(pt_ref, q_ref, k_ref, o_ref, acc_ref):
    page = pt_ref[0, 0]
    s = jax.lax.dot_general(q_ref[0, 0], k_ref[0, 0],
                            (((1,), (1,)), ((), ())))
    o_ref[0, 0] = s

def run(q, k, pt):
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((8, 8), jnp.bfloat16)],
        interpret=True,
    )(pt, q, k)
"""

GOOD_KERNEL = """
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.kernels.runtime import resolve_interpret

def _kernel(pt_ref, q_ref, k_ref, o_ref, acc_ref):
    mask = pt_ref[0, 0] >= 0
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
    o_ref[0, 0] = jnp.where(mask, s, 0.0)

def _index(pt, b, j):
    return jnp.maximum(pt[b, j], 0)

def run(q, k, pt, interpret=None):
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((8, 8), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(pt, q, k)
"""


def test_kernel_rules_flag_hygiene_violations(tmp_path):
    report = run(tmp_path, BAD_KERNEL)
    assert all(r == "kernel-rules" for r in rule_ids(report))
    msgs = [f.message for f in report.findings]
    assert any("interpret=True" in m for m in msgs)
    assert any("VMEM scratch dtype" in m and "bfloat16" in m for m in msgs)
    assert any("raw ref load" in m for m in msgs)
    assert any("page-table load" in m for m in msgs)


def test_kernel_rules_pass_hygienic_kernel(tmp_path):
    assert run(tmp_path, GOOD_KERNEL).findings == []


# ---------------------------------------------------------------------------
# sharding-registry
# ---------------------------------------------------------------------------


BAD_SHARDING = """
import jax
from jax.sharding import PartitionSpec as P

SPEC = P("modle", None)
HIER = P(("pod", "dta"), "model")

def mesh():
    return jax.make_mesh((2, 2), ("data", "modell"))
"""

GOOD_SHARDING = """
import jax
from jax.sharding import PartitionSpec as P

P2 = P
SPEC = P("model", None)
HIER = P2(("pod", "data"), "model")

def mesh():
    return jax.make_mesh((2, 2), ("data", "model"))
"""


def test_sharding_flags_unregistered_axes(tmp_path):
    report = run(tmp_path, BAD_SHARDING)
    assert all(r == "sharding-registry" for r in rule_ids(report))
    flagged = {f.message.split("'")[1] for f in report.findings}
    assert flagged == {"modle", "dta", "modell"}


def test_sharding_passes_registered_axes(tmp_path):
    assert run(tmp_path, GOOD_SHARDING).findings == []


def test_registry_matches_runtime():
    from repro.dist.sharding import MESH_AXES
    assert RepoContext().mesh_axes == set(MESH_AXES)


BAD_SHARD_MAP = """
import jax
from jax.sharding import PartitionSpec as P

def sharded_gather(body, mesh):
    # bare axis string bypassing P(...), plus a typo'd axis inside P
    return jax.shard_map(body, mesh=mesh,
                         in_specs=("modle", P(None, "mdoel")),
                         out_specs=P("data"))
"""

GOOD_SHARD_MAP = """
import jax
from jax.sharding import PartitionSpec as P

def sharded_gather(body, mesh):
    return jax.shard_map(body, mesh=mesh,
                         in_specs=(P(None, "model"), P()),
                         out_specs=P("data"), check_vma=False)

def sharded_gather_legacy(body, mesh):
    return jax.shard_map(body, mesh=mesh, in_specs=(P(),),
                         out_specs=P(), check_rep=False)
"""


def test_shard_map_axis_names_and_missing_check(tmp_path):
    report = run(tmp_path, BAD_SHARD_MAP)
    assert all(r == "sharding-registry" for r in rule_ids(report))
    axis_findings = [f for f in report.findings if "axis name" in f.message]
    flagged = {f.message.split("'")[1] for f in axis_findings}
    assert flagged == {"modle", "mdoel"}
    # the bare string is attributed to the shard_map spec, the P() literal
    # to the PartitionSpec branch — each exactly once (no double report)
    assert len(axis_findings) == 2
    assert sum("in_specs" in f.message for f in axis_findings) == 1
    check_findings = [f for f in report.findings
                      if "check_vma/check_rep" in f.message]
    assert len(check_findings) == 1


def test_shard_map_clean_call_sites_pass(tmp_path):
    assert run(tmp_path, GOOD_SHARD_MAP).findings == []


# ---------------------------------------------------------------------------
# suppression pragma
# ---------------------------------------------------------------------------


def test_pragma_suppresses_with_reason(tmp_path):
    report = run(tmp_path, (
        "def f(allocator, pids):\n"
        "    allocator.free(pids)"
        "  # repro: allow(allocator-discipline) -- teardown of a test pool\n"))
    assert report.findings == []
    assert len(report.suppressed) == 1
    assert report.suppressed[0].reason == "teardown of a test pool"
    assert report.ok


def test_pragma_on_preceding_line(tmp_path):
    report = run(tmp_path, (
        "def f(allocator, pids):\n"
        "    # repro: allow(allocator-discipline) -- teardown\n"
        "    allocator.free(pids)\n"))
    assert report.findings == [] and len(report.suppressed) == 1


def test_pragma_without_reason_does_not_suppress(tmp_path):
    report = run(tmp_path, (
        "def f(allocator, pids):\n"
        "    allocator.free(pids)  # repro: allow(allocator-discipline)\n"))
    rules = rule_ids(report)
    assert "allocator-discipline" in rules, "unjustified pragma must not waive"
    assert "pragma" in rules, "the malformed pragma is itself reported"


def test_pragma_for_other_rule_does_not_suppress(tmp_path):
    report = run(tmp_path, (
        "def f(allocator, pids):\n"
        "    allocator.free(pids)  # repro: allow(lifecycle) -- wrong rule\n"))
    assert "allocator-discipline" in rule_ids(report)


def test_stale_pragma_is_flagged(tmp_path):
    report = run(tmp_path,
                 "X = 1  # repro: allow(lifecycle) -- excuses nothing\n")
    assert rule_ids(report) == ["pragma"]
    assert "stale" in report.findings[0].message


# ---------------------------------------------------------------------------
# JSON schema + CLI surface
# ---------------------------------------------------------------------------


def test_json_report_schema(tmp_path):
    f = tmp_path / "bad.py"
    f.write_text(BAD_SHARDING)
    doc = json.loads(render_json(analyze([f])))
    assert doc["version"] == 1 and doc["tool"] == "repro.analysis"
    assert doc["files_scanned"] == 1 and doc["ok"] is False
    assert {r["id"] for r in doc["rules"]} == {
        "jit-purity", "allocator-discipline", "lifecycle", "kernel-rules",
        "sharding-registry"}
    for finding in doc["findings"]:
        assert set(finding) >= {"rule", "path", "line", "col", "message"}
        assert isinstance(finding["line"], int) and finding["line"] > 0
    assert doc["suppressed"] == []


def test_cli_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(BAD_SHARDING)
    good = tmp_path / "good.py"
    good.write_text(GOOD_SHARDING)
    assert cli_main([str(good)]) == 0
    assert cli_main([str(bad)]) == 1, "seeded violation must fail the CI gate"
    assert cli_main([str(tmp_path / "missing.py")]) == 2
    assert cli_main(["--rules", "no-such-rule", str(good)]) == 2
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    assert "jit-purity" in out


def test_cli_rule_selection(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(BAD_SHARDING)
    assert cli_main([str(bad), "--rules", "lifecycle"]) == 0
    assert cli_main([str(bad), "--rules", "sharding-registry"]) == 1


def test_syntax_error_is_a_finding(tmp_path):
    report = run(tmp_path, "def broken(:\n")
    assert rule_ids(report) == ["parse-error"]


# ---------------------------------------------------------------------------
# the gate itself: src/ is clean
# ---------------------------------------------------------------------------


def test_src_tree_is_clean():
    report = analyze([REPO / "src"])
    assert len(report.files) > 80
    assert report.findings == [], "\n".join(
        f.render() for f in report.findings)


def test_rule_table_is_stable():
    assert [r.id for r in default_rules()] == [
        "jit-purity", "allocator-discipline", "lifecycle", "kernel-rules",
        "sharding-registry"]
    assert all(r.summary for r in default_rules())


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
