"""Synchronization primitives (paper §2.2 / §5.1) and queue semantics (§4.2)."""


from repro.core import FifoQueue, SimCloud
from repro.core.primitives import Primitives
from repro.core.storage import KVStore


def make_prim(seed=0, max_lock_time=5.0):
    cloud = SimCloud(seed=seed)
    kv = KVStore(cloud)
    return cloud, kv, Primitives(kv, max_lock_time=max_lock_time)


def test_timed_lock_mutual_exclusion():
    cloud, kv, prim = make_prim()

    def driver():
        l1, _ = yield from prim.lock_acquire("k", cloud.now)
        assert l1 is not None
        l2, _ = yield from prim.lock_acquire("k", cloud.now)
        assert l2 is None, "second acquire must fail while lease held"
        ok = yield from prim.lock_release("k", l1)
        assert ok
        l3, _ = yield from prim.lock_acquire("k", cloud.now)
        assert l3 is not None
        return True

    assert cloud.run_task(driver())


def test_timed_lock_expiry_and_fencing():
    cloud, kv, prim = make_prim(max_lock_time=1.0)

    def driver():
        l1, _ = yield from prim.lock_acquire("k", cloud.now)
        assert l1 is not None
        # lease ages out -> steal
        from repro.core.simcloud import Sleep

        yield Sleep(1.5)
        l2, _ = yield from prim.lock_acquire("k", cloud.now)
        assert l2 is not None, "expired lease must be stealable"
        # the original holder's fenced update must now fail
        res = yield from prim.fenced_update("k", l1, lambda item: item.update(x=1))
        assert res is None, "fencing must reject the expired holder"
        res2 = yield from prim.fenced_update("k", l2, lambda item: item.update(x=2))
        assert res2 is not None and res2["x"] == 2
        return True

    assert cloud.run_task(driver())


def test_atomic_counter_concurrent():
    cloud, kv, prim = make_prim()
    N, K = 8, 25

    def incr():
        for _ in range(K):
            yield from prim.counter_add("c")
        return True

    tasks = [cloud.spawn(incr(), name=f"incr{i}") for i in range(N)]
    cloud.run()
    assert all(t.done and t.error is None for t in tasks)
    assert cloud.run_task(prim.counter_get("c")) == N * K


def test_atomic_list_concurrent_append():
    cloud, kv, prim = make_prim()

    def appender(i):
        yield from prim.list_append("l", [f"v{i}"])
        return True

    for i in range(20):
        cloud.spawn(appender(i))
    cloud.run()
    final = cloud.run_task(prim.list_get("l"))
    assert sorted(final) == sorted(f"v{i}" for i in range(20))


def test_lock_protects_read_modify_write():
    """The Fig. 6b experiment's correctness side: locked RMW never loses
    updates; unlocked RMW does under concurrency."""
    cloud, kv, prim = make_prim()
    N, K = 6, 10

    def locked_rmw(i):
        for _ in range(K):
            while True:
                lock, item = yield from prim.lock_acquire("shared", cloud.now)
                if lock is not None:
                    break
                from repro.core.simcloud import Sleep

                yield Sleep(0.01)
            val = (item or {}).get("v", 0)
            res = yield from prim.fenced_update("shared", lock,
                                                lambda it, v=val: it.update(v=v + 1))
            assert res is not None
        return True

    tasks = [cloud.spawn(locked_rmw(i)) for i in range(N)]
    cloud.run()
    assert all(t.error is None for t in tasks)
    item = cloud.run_task(kv.get("state", "shared"))
    assert item["v"] == N * K, "locked RMW must not lose updates"


def test_fifo_queue_order_and_batching():
    cloud = SimCloud(seed=1)
    seen = []

    def handler(batch):
        seen.extend(m.seq for m in batch)
        if False:
            yield
        return None

    q = FifoQueue(cloud, "q", handler=handler, batch_size=10)

    def producer():
        for i in range(35):
            yield from q.push(i)
        return True

    cloud.run_task(producer())
    cloud.run()
    assert seen == sorted(seen) and len(seen) == 35
    assert q.deliveries >= 4  # batched, not per-message


def test_fifo_queue_redelivery_on_crash():
    from repro.core import SimulatedCrash

    cloud = SimCloud(seed=1)
    state = {"fail_next": 1}
    processed = []

    def handler(batch):
        if state["fail_next"] > 0:
            state["fail_next"] -= 1
            raise SimulatedCrash("boom")
        processed.extend(m.seq for m in batch)
        if False:
            yield
        return None

    q = FifoQueue(cloud, "q", handler=handler, batch_size=10)
    cloud.run_task(q.push("a"))
    cloud.run()
    assert processed == [1], "crashed batch must be redelivered in order"
    assert q.redeliveries == 1


def test_push_immediate_accounts_wire_kb():
    """In-cloud pushes (heartbeat, distributor, serve routing) must count
    wire KB exactly like latency-bearing pushes — ``push_kb`` is the queue
    wire meter (SQS bills per 64 kB unit), so skipping it under-counts."""
    cloud = SimCloud(seed=0)
    q = FifoQueue(cloud, "q", handler=None)
    cloud.run_task(q.push("a", size_kb=0.5))
    kb_after_push = q.push_kb
    assert kb_after_push == 0.5
    q.push_immediate("b", size_kb=0.5)
    assert q.push_kb == 2 * kb_after_push
    # both paths clamp to the 64-byte SQS minimum billable size
    q.push_immediate("c", size_kb=0.001)
    assert q.push_kb == 2 * kb_after_push + 0.064
    assert q.pushes == 3


def test_retry_then_drop_lands_in_dead_letter():
    """A poison batch is retried ``max_retries`` times, then dropped to the
    dead-letter list (observable DLQ semantics) — and the queue moves on to
    later messages instead of livelocking."""
    from repro.core import SimulatedCrash

    cloud = SimCloud(seed=1)
    processed = []

    def handler(batch):
        if any(m.body == "poison" for m in batch):
            raise SimulatedCrash("poison")
        processed.extend(m.body for m in batch)
        if False:
            yield
        return None

    q = FifoQueue(cloud, "q", handler=handler, batch_size=1, max_retries=2)
    cloud.run_task(q.push("poison"))
    cloud.run_task(q.push("ok"))
    cloud.run()
    assert q.dropped == 1
    assert [m.body for m in q.dead_letters] == ["poison"]
    assert q.redeliveries == 2  # 3 deliveries = initial + max_retries redeliveries
    assert processed == ["ok"], "queue must advance past the poison batch"


def test_claim_pending_and_requeue_preserve_fifo():
    """``claim_pending`` hands not-yet-delivered messages to the running
    consumer (continuous batching's long-poll receive); ``requeue`` returns
    them behind the in-flight batch, preserving FIFO order."""
    cloud = SimCloud(seed=2)
    batches, claims = [], []

    def handler(batch):
        batches.append([m.seq for m in batch])
        extra = q.claim_pending(2)
        claims.append([m.seq for m in extra])
        q.requeue(extra[1:])     # keep one, hand the rest back
        if False:
            yield
        return None

    q = FifoQueue(cloud, "q", handler=handler, batch_size=2)
    for i in range(6):
        q.push_immediate(i)      # all queued before the trigger fires
    cloud.run()
    # invocation 1: batch [1,2], claims [3,4], requeues 4;
    # invocation 2: batch [4,5] (requeued 4 redelivered first), claims [6]
    assert batches == [[1, 2], [4, 5]]
    assert claims == [[3, 4], [6]]
    assert q.claims == 3 and q.requeues == 1


def test_queue_sequence_numbers_monotone():
    cloud = SimCloud(seed=2)
    q = FifoQueue(cloud, "q", handler=None)

    def producer():
        seqs = []
        for i in range(10):
            s = yield from q.push(i)
            seqs.append(s)
        return seqs

    seqs = cloud.run_task(producer())
    assert seqs == list(range(1, 11))
