"""Continuous-batching decode scheduler: per-slot cache correctness against
the whole-batch reference, cross-session FIFO through the shared dispatch
queue, crash/redelivery idempotence, sampling semantics, and the 16x16 mesh
placement of the live decode cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.dist  # noqa: F401  (installs the AbstractMesh compat shim)
from repro import configs
from repro.core import SimCloud
from repro.core.simcloud import FaultPlan
from repro.launch.serve import build_frontend, run_serving
from repro.models import build_model
from repro.serve import sampling
from repro.serve.engine import generate
from repro.serve.scheduler import DecodeScheduler


def tiny(arch="minicpm-2b"):
    cfg = configs.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# Scheduler-level correctness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["minicpm-2b", "mamba2-1.3b", "recurrentgemma-2b"])
def test_staggered_admission_matches_solo_decode(arch):
    """Requests admitted into a shared decode batch at *different* steps must
    generate exactly what they'd generate alone — the per-slot ring (and the
    recurrent states) cannot leak across slots or across admission times."""
    cfg, model, params = tiny(arch)
    P, N = 12, 5
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=P).astype(np.int32) for _ in range(3)]
    # seq_len sizes the reference's ring for prompt+decode: the legacy
    # prompt-sized default silently evicts once decode wraps it
    ref = {i: np.asarray(generate(model, params, jnp.asarray(p)[None], N,
                                  seq_len=P + N))[0]
           for i, p in enumerate(prompts)}

    sched = DecodeScheduler(model, params, n_slots=3, max_seq=P + N)
    got = {}
    sched.submit("a", "r0", prompts[0], N)
    for _ in range(2):                      # r0 decodes alone for two steps
        for fin in sched.step():
            got[int(fin.request_id[1:])] = fin.tokens
    sched.submit("b", "r1", prompts[1], N)  # joins mid-flight
    sched.step()
    sched.submit("c", "r2", prompts[2], N)
    while sched.busy():
        for fin in sched.step():
            got[int(fin.request_id[1:])] = fin.tokens
    assert sorted(got) == [0, 1, 2]
    for i in range(3):
        np.testing.assert_array_equal(got[i], ref[i],
                                      err_msg=f"slot {i} diverged from solo decode")


def test_overbudget_request_clamped_to_ring_capacity():
    """A decode budget that would wrap the full-attention KV ring past the
    prompt is clamped at admission; what IS generated matches solo decode."""
    cfg, model, params = tiny()          # dense: full-attention ring
    P, fit = 16, 8
    prompt = np.arange(P, dtype=np.int32) % cfg.vocab
    ref = np.asarray(generate(model, params, jnp.asarray(prompt)[None], fit,
                              seq_len=P + fit))[0]

    sched = DecodeScheduler(model, params, n_slots=2, max_seq=P + fit)
    sched.submit("s0", "r0", prompt, max_new=20)   # asks past the ring
    done = []
    while sched.busy():
        done.extend(sched.step())
    assert len(done) == 1
    assert done[0].tokens.shape == (fit,), "budget must clamp to max_seq - prompt"
    np.testing.assert_array_equal(done[0].tokens, ref)

    # a prompt that leaves no decode room in a full-attention ring is
    # rejected loudly — clamping would silently drop its leading tokens
    with pytest.raises(ValueError, match="no decode room"):
        sched.submit("s1", "r1", np.zeros(P + fit, np.int32), max_new=4)

    # SSM states have no ring: only the output buffer bounds the budget
    _, m2, p2 = tiny("mamba2-1.3b")
    s2 = DecodeScheduler(m2, p2, n_slots=2, max_seq=12)
    s2.submit("s0", "r0", np.zeros(8, np.int32), max_new=999)
    assert s2.slots[0].req.max_new == 12


def test_sampling_flags_rejected_on_greedy_fallback():
    """The whole-batch fallback decodes greedily — sampling knobs must fail
    loudly instead of being silently dropped."""
    with pytest.raises(ValueError, match="continuous scheduler"):
        run_serving("whisper-base", n_requests=2, max_new=3, sessions=1,
                    temperature=0.8, quiet=True)


def test_session_fifo_gate_and_slot_reuse():
    """A session's second request is admitted only after its first completes,
    and freed slots are re-admitted from the pending list."""
    cfg, model, params = tiny()
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=24)
    p = np.zeros(8, np.int32)
    sched.submit("s0", "a0", p, 3)
    sched.submit("s0", "a1", p, 3)   # same session: must wait for a0
    sched.submit("s1", "b0", p, 3)
    assert sched.slots[0].req.request_id == "a0"
    assert sched.slots[1].req.request_id == "b0"
    assert [r.request_id for r in sched.pending] == ["a1"]
    order = []
    while sched.busy():
        order.extend(f.request_id for f in sched.step())
    assert order.index("a0") < order.index("a1")
    assert sched.completed == 3 and not sched.pending


# ---------------------------------------------------------------------------
# Full serving stack (queues + frontend + scheduler)
# ---------------------------------------------------------------------------


def _drive(frontend, cloud, n_requests, sessions, prompt_len, max_new, vocab):
    from repro.launch.serve import spawn_workload

    spawn_workload(cloud, frontend, vocab=vocab, n_requests=n_requests,
                   sessions=sessions, prompt_len=prompt_len, max_new=max_new)
    cloud.run()


def test_cross_session_batching_preserves_fifo():
    cfg, model, params = tiny()
    cloud = SimCloud(seed=0)
    fe = build_frontend(cloud, cfg, model, params, mode="continuous",
                        batch_size=4, max_new=4, prompt_len=8)
    _drive(fe, cloud, 12, 4, 8, 4, cfg.vocab)
    assert sum(len(v) for v in fe.completions.values()) == 12
    for sess, ids in fe.completions.items():
        nums = [int(r[1:]) for r in ids]
        assert nums == sorted(nums), f"FIFO violated in {sess}"
    # the whole workload fits one continuous invocation: cross-session batch
    assert fe.runtime.stats["serve"].invocations < 12
    assert fe.scheduler.occupancy() > 1.0


def test_crash_redelivers_batch_without_duplicating_completions():
    """At-least-once delivery through the scheduler: a crash mid-invocation
    (after some completions) redelivers the same batch; completions are
    deduped by request id, so every request completes exactly once."""
    cfg, model, params = tiny()
    cloud = SimCloud(seed=0, faults=FaultPlan(
        crashes={("serve", "post-complete"): 0}))
    fe = build_frontend(cloud, cfg, model, params, mode="continuous",
                        batch_size=4, max_new=3, prompt_len=8)
    _drive(fe, cloud, 8, 4, 8, 3, cfg.vocab)
    assert fe.runtime.stats["serve"].crashes == 1
    assert fe.dispatch.redeliveries >= 1
    done = [r for ids in fe.completions.values() for r in ids]
    assert sorted(done) == [f"r{i}" for i in range(8)], done
    assert len(done) == len(set(done)), "duplicated completions after redelivery"
    for sess, ids in fe.completions.items():
        nums = [int(r[1:]) for r in ids]
        assert nums == sorted(nums), f"FIFO violated in {sess} after redelivery"


def test_whole_batch_fallback_for_encdec():
    """Families without a per-slot decode path (enc-dec) fall back to the
    shared whole-batch flavour and still cross-session batch."""
    fe = run_serving("whisper-base", n_requests=6, max_new=3, sessions=2,
                     batch_size=3, quiet=True)
    assert fe.scheduler is None and fe.mode == "shared"
    assert sum(len(v) for v in fe.completions.values()) == 6


# ---------------------------------------------------------------------------
# Mesh path: dist.cache_shardings on the live decode cache
# ---------------------------------------------------------------------------


def test_cache_shardings_resolve_on_16x16():
    from jax.sharding import AbstractMesh

    cfg, model, params = tiny("qwen3-14b")
    mesh = AbstractMesh((16, 16), ("data", "model"))
    # ring mode: the paged pool's specs are pinned in test_paged_kvcache
    sched = DecodeScheduler(model, params, n_slots=16, max_seq=32, mesh=mesh,
                            kv_mode="ring")
    specs = sched.cache_specs
    # kv rings (L, B, T, H, D): batch on data; the reduced config's 4 kv
    # heads don't divide model=16, so the guard falls back to the time dim
    assert specs["k"][1] == ("data",)
    assert specs["k"][2] == "model"
    assert specs["positions"][1] == ("data",)


def test_scheduler_decodes_under_concrete_mesh():
    from jax.sharding import Mesh

    cfg, model, params = tiny()
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=16, mesh=mesh)
    sched.submit("s0", "r0", np.zeros(8, np.int32), 3)
    out = []
    while sched.busy():
        out.extend(sched.step())
    assert len(out) == 1 and out[0].tokens.shape == (3,)


# ---------------------------------------------------------------------------
# Sampling semantics (top-k fix)
# ---------------------------------------------------------------------------


def test_topk_restricts_support_to_exactly_k():
    """Ties with the k-th logit must NOT widen the candidate set."""
    logits = jnp.asarray([[3.0, 2.0, 2.0, 2.0, -1.0]])  # three-way tie at k=2
    seen = set()
    for s in range(64):
        tok = sampling.temperature_sample(jax.random.key(s), logits,
                                          temperature=1.0, top_k=2)
        seen.add(int(tok[0]))
    assert seen <= {0, 1}, f"top-k leaked tied logits: {seen}"
    assert 0 in seen and 1 in seen  # both top-2 candidates reachable


def test_topk_ge_vocab_and_topk_one():
    logits = jnp.asarray([[0.1, 5.0, -2.0, 1.0]])
    # top_k >= vocab must not index past the sort
    tok = sampling.temperature_sample(jax.random.key(0), logits,
                                      temperature=1.0, top_k=17)
    assert 0 <= int(tok[0]) < 4
    # the -1 "disabled" sentinel means no top-k, not a crash
    tok = sampling.temperature_sample(jax.random.key(0), logits,
                                      temperature=1.0, top_k=-1)
    assert 0 <= int(tok[0]) < 4
    # top_k=1 degenerates to greedy regardless of key
    for s in range(8):
        tok = sampling.temperature_sample(jax.random.key(s), logits,
                                          temperature=1.0, top_k=1)
        assert int(tok[0]) == 1
    # low temperature concentrates on the argmax even without top-k
    tok = sampling.temperature_sample(jax.random.key(0), logits,
                                      temperature=1e-4, top_k=0)
    assert int(tok[0]) == 1
