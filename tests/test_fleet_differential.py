"""Randomized fleet differential harness (FaaSKeeper elasticity, pinned).

Seeded random event sequences — submits (fresh / multi-turn extension /
cross-session shared prefix), scale-up bursts, forced scale-downs, worker
crashes mid-decode / mid-park / mid-restore via ``FaultPlan``, wedged
workers reaped by heartbeat eviction — drive a :class:`FleetController` of
disposable ``DecodeScheduler`` workers over one shared blob store, and every
completed request is asserted **token-for-token equal** to the eviction-free
solo reference.  The fleet-wide ledger (per-worker allocator/refcount audit,
session exclusivity, blob ownership: every ``kv/`` spill exactly one owner,
every ``park/`` journal owned by its record and/or a not-yet-superseded
``park-meta``) is audited after every controller tick, and at quiescence the
store must hold nothing but committed journals and index blobs.

Tier-1 runs a fixed seed set (dense widest; moe and hybrid pin the
family-specific paths).  CI additionally runs a non-blocking chaos sweep
(``FLEET_CHAOS_SWEEP`` = base seed); any failing sequence's event log is
dumped to ``artifacts/diff_failures/`` so the exact trace rides the CI
artifact, exactly like ``test_sched_differential``.

The scale-to-zero round trip and its crash-during-drain fallback are pinned
as dedicated scenarios at the bottom.
"""

from __future__ import annotations

import json
import os
import zlib
from pathlib import Path
from typing import Optional

import jax
import numpy as np
import pytest

import repro.dist  # noqa: F401  (installs the AbstractMesh compat shim)
from repro import configs
from repro.coord import MembershipService
from repro.core import FaultPlan
from repro.core.storage import PageBlobStore
from repro.models import build_model
from repro.serve.fleet import PARK_META_PREFIX, FleetController
from repro.serve.scheduler import DecodeScheduler
from tests.conftest import make_service
from tests.test_sched_differential import SoloRef

MAX_SEQ = 32
PAGE_SIZE = 4
N_SLOTS = 2                       # per-worker decode slots (small: forces
MAX_WORKERS = 3                   # routing + autoscale under modest load)
PREFILL_CHUNK = 3
MAX_NEW = (2, 4)
FRESH_LEN = (5, 12)
EXTEND_LEN = (1, 4)
N_EVENTS = 22
CRASH_POINTS = ("mid-decode", "mid-restore", "mid-park")

# tier-1 seed matrix: dense widest, moe/hybrid pin family-specific KV paths
TIER1_SEEDS = ([("minicpm-2b", s) for s in range(4)]
               + [("moonshot-v1-16b-a3b", s) for s in range(2)]
               + [("recurrentgemma-2b", s) for s in range(2)])

FAILURE_DIR = Path("artifacts/diff_failures")

_ARCH_CACHE = {}


def _arch(name):
    """Build (or fetch) the shared-store worker pool + fleet + solo
    reference for ``name``.  The fleet is constructed once per arch (jit
    once) and ``reset()`` between sequences — the same recycle path a
    worker death takes."""
    if name not in _ARCH_CACHE:
        cfg = configs.get(name).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        store = PageBlobStore()
        workers = [DecodeScheduler(model, params, n_slots=N_SLOTS,
                                   max_seq=MAX_SEQ, page_size=PAGE_SIZE,
                                   prefill_chunk=PREFILL_CHUNK, offload=True,
                                   prefix_sharing=True, park_sessions=True,
                                   blob_store=store, index_journal=True)
                   for _ in range(MAX_WORKERS)]
        fleet = FleetController(workers, min_workers=0, scale_to_zero=True,
                                drain_idle_steps=3)
        ref = SoloRef(model, params)
        _ARCH_CACHE[name] = (cfg, model, params, fleet, ref)
    return _ARCH_CACHE[name]


def _quiesce_ledger(fleet: FleetController) -> None:
    """At quiescence (no workers, no work) the shared store may hold only
    committed state: park journals pointed at by a ``park-meta`` record,
    the meta records themselves, and content-addressed index blobs —
    no preempt spills, no orphaned journals."""
    meta_blobs = {m["blob_key"] for m in fleet._iter_metas().values()}
    for key in fleet.blob_store.blobs:
        assert not key.startswith("kv/"), f"leaked preempt spill {key!r}"
        if key.startswith("park/"):
            assert key in meta_blobs, f"orphaned park journal {key!r}"


def _run_fleet_sequence(arch: str, seed: int,
                        log: Optional[list] = None) -> list:
    """One seeded fleet event sequence; appends every event to ``log`` (a
    caller-owned list survives an assertion failure) and raises on any
    parity or ledger violation."""
    cfg, _model, _params, fleet, ref = _arch(arch)
    tag = f"fleet-{arch}"
    rng = np.random.default_rng(zlib.crc32(tag.encode()) * 100003 + seed)

    # the fault plan is part of the seeded sequence: each (worker, point)
    # can fail-stop once, at a random occurrence of that hazard window
    crashes = {}
    for k in range(MAX_WORKERS):
        for point in CRASH_POINTS:
            if rng.random() < 0.25:
                crashes[(f"fleet:w{k}", point)] = int(rng.integers(0, 6))
    fleet.reset(faults=FaultPlan(crashes=crashes))
    cloud, svc = make_service(seed=seed)
    fleet.membership = MembershipService(svc)

    def sweep():
        # one scheduled-heartbeat run: evicts failed sessions' ephemerals,
        # which is how the controller learns a wedged worker is dead
        svc.start_heartbeat(period=1.0, max_runs=1)
        cloud.run()

    sessions = [f"s{i}" for i in range(int(rng.integers(3, 6)))]
    history = {s: None for s in sessions}
    inflight = {}
    shared_sys = rng.integers(0, cfg.vocab, size=2 * PAGE_SIZE).astype(np.int32)
    log = log if log is not None else []
    log.append({"arch": arch, "seed": seed, "sessions": len(sessions),
                "crashes": [[f, p, n] for (f, p), n in crashes.items()]})
    rid = 0

    def submit(sess):
        nonlocal rid
        h = history[sess]
        roll = rng.random()
        if h is not None and roll < 0.6 and len(h) + 8 <= MAX_SEQ:
            prompt = np.concatenate(
                [h, rng.integers(0, cfg.vocab,
                                 int(rng.integers(*EXTEND_LEN))).astype(np.int32)])
            kind = "extend"
        elif roll < 0.8:
            prompt = np.concatenate(
                [shared_sys, rng.integers(0, cfg.vocab,
                                          int(rng.integers(*FRESH_LEN))).astype(np.int32)])
            kind = "shared"
        else:
            prompt = rng.integers(0, cfg.vocab,
                                  int(rng.integers(*FRESH_LEN))).astype(np.int32)
            kind = "fresh"
        max_new = int(rng.integers(MAX_NEW[0], MAX_NEW[1] + 1))
        max_new = min(max_new, MAX_SEQ - len(prompt))
        if max_new < 1:
            history[sess] = None
            return
        name = f"r{rid}"
        rid += 1
        fleet.submit(sess, name, prompt, max_new)
        inflight[sess] = (name, prompt, max_new)
        log.append({"ev": "submit", "session": sess, "rid": name,
                    "kind": kind, "prompt": prompt.tolist(),
                    "max_new": max_new})

    def on_finished(fins):
        for fin in fins:
            name, prompt, max_new = inflight.pop(fin.session)
            assert fin.request_id == name, \
                "per-session FIFO violated across the fleet"
            expect = ref.run(prompt, max_new, session=fin.session)
            got = np.asarray(fin.tokens)
            log.append({"ev": "complete", "rid": name,
                        "tokens": got.tolist()})
            np.testing.assert_array_equal(
                got, expect,
                err_msg=f"{arch} seed {seed} {name}: fleet diverged from "
                        f"the eviction-free solo reference")
            history[fin.session] = np.concatenate(
                [prompt, got.astype(np.int32)])

    for _ev in range(N_EVENTS):
        for sess in sessions:
            if sess not in inflight and rng.random() < 0.35:
                submit(sess)
        if rng.random() < 0.08:
            w = fleet.scale_up()
            log.append({"ev": "scale-up",
                        "worker": w.worker_id if w else None})
        if rng.random() < 0.08:
            wid = fleet.scale_down()
            log.append({"ev": "scale-down", "worker": wid})
        if rng.random() < 0.06:
            live = [w.worker_id for w in fleet.workers.values()
                    if w.state != "wedged"]
            if live:
                wid = live[int(rng.integers(len(live)))]
                fleet.fail_worker(wid)
                log.append({"ev": "wedge", "worker": wid})
        if rng.random() < 0.25:
            sweep()
        on_finished(fleet.step())
        fleet.audit()
    guard = 0
    while fleet.busy():
        guard += 1
        assert guard < 500, "fleet failed to drain"
        sweep()                       # wedged workers come back only via
        on_finished(fleet.step())     # heartbeat eviction
        fleet.audit()
        log.append({"ev": "drain-step"})
    guard = 0
    while fleet.live_workers():       # idle cooldown down to zero workers
        guard += 1
        assert guard < 100, "fleet failed to scale to zero"
        sweep()
        fleet.step()
        fleet.audit()
    assert not inflight, f"requests lost: {inflight}"
    _quiesce_ledger(fleet)
    fleet.audit()
    return log


def _run_and_dump(arch: str, seed: int) -> None:
    log: list = []
    try:
        _run_fleet_sequence(arch, seed, log)
    except Exception as e:
        # the sequence is a pure function of (arch, seed): the artifact
        # carries the replay recipe + the event trace up to the failure
        FAILURE_DIR.mkdir(parents=True, exist_ok=True)
        path = FAILURE_DIR / f"seq_fleet_{arch}_{seed}.json"
        path.write_text(json.dumps(
            {"arch": arch, "seed": seed, "error": str(e)[:2000],
             "repro": f"_run_fleet_sequence({arch!r}, {seed})",
             "events": log},
            indent=2))
        raise


@pytest.mark.parametrize("arch,seed", TIER1_SEEDS,
                         ids=[f"{a}-{s}" for a, s in TIER1_SEEDS])
def test_fleet_differential(arch, seed):
    _run_and_dump(arch, seed)


SWEEP_BASE = os.environ.get("FLEET_CHAOS_SWEEP")


@pytest.mark.skipif(SWEEP_BASE is None,
                    reason="fleet chaos sweep runs in the non-blocking CI "
                           "job (set FLEET_CHAOS_SWEEP=<base seed>)")
@pytest.mark.parametrize("k", range(4))
def test_fleet_chaos_sweep(k):
    base = int(SWEEP_BASE) % 1_000_000
    for arch in ("minicpm-2b", "moonshot-v1-16b-a3b", "recurrentgemma-2b"):
        _run_and_dump(arch, 5000 + base + k)


# ---------------------------------------------------------------------------
# Scale-to-zero round trip (and its crash-during-drain fallback)
# ---------------------------------------------------------------------------


def _drive(fleet: FleetController, max_steps: int = 500) -> dict:
    fins = {}
    for _ in range(max_steps):
        for fin in fleet.step():
            fins[fin.request_id] = fin
        fleet.audit()
        if not fleet.busy():
            return fins
    raise AssertionError("fleet failed to drain")


def _to_zero(fleet: FleetController, max_steps: int = 60) -> None:
    for _ in range(max_steps):
        if not fleet.live_workers():
            return
        fleet.step()
        fleet.audit()
    raise AssertionError("fleet failed to scale to zero")


def test_scale_to_zero_round_trip():
    """Multi-turn session across a scale-to-zero gap: turn 1 completes, the
    fleet drains to zero (journal + prefix index externalized to blob), and
    turn 2 cold-starts a fresh worker that restores the parked journal,
    re-adopts the index, prefills only the new tokens — and produces output
    identical to the never-scaled solo reference."""
    cfg, _model, _params, fleet, ref = _arch("minicpm-2b")
    fleet.reset()
    fleet.membership = None
    rng = np.random.default_rng(7)
    p1 = rng.integers(0, cfg.vocab, 10).astype(np.int32)
    fleet.submit("sessA", "t1", p1, 3)
    t1 = np.asarray(_drive(fleet)["t1"].tokens)
    np.testing.assert_array_equal(t1, ref.run(p1, 3))

    _to_zero(fleet)
    assert fleet.live_workers() == 0
    assert PARK_META_PREFIX + "sessA" in fleet.blob_store.blobs, \
        "drain did not commit the parked journal to the directory"
    assert any(k.startswith("index/") for k in fleet.blob_store.blobs), \
        "prefix index was not journaled to blob"
    _quiesce_ledger(fleet)

    p2 = np.concatenate([p1, t1.astype(np.int32),
                         rng.integers(0, cfg.vocab, 2).astype(np.int32)])
    fleet.submit("sessA", "t2", p2, 3)
    fin2 = _drive(fleet)["t2"]
    assert fleet.cold_starts_from_zero == 2      # each turn woke the fleet
    assert fleet.meta_adoptions == 1, "cold start did not adopt the journal"
    assert fleet.fleet_stats()["index_adopted"] > 0, \
        "cold start did not rebuild the prefix index from blob"
    assert fin2.reused_tokens >= len(p1), \
        "cold start re-prefilled tokens the journal already covered"
    np.testing.assert_array_equal(np.asarray(fin2.tokens), ref.run(p2, 3))
    # adoption consumed the directory entry once the session completed
    assert PARK_META_PREFIX + "sessA" not in fleet.blob_store.blobs


def test_scale_to_zero_crash_during_drain():
    """The commit-point claim: a crash *between* the journal's KV blob PUT
    and the park-meta PUT leaves no directory entry, the orphaned KV blob is
    GC'd, and the session's next turn falls back to a full re-prefill —
    token-identical output, zero reused tokens (correct, just slower)."""
    cfg, model, params, _fleet_, ref = _arch("minicpm-2b")
    store = PageBlobStore()
    # no prefix sharing: the fallback must not be rescued by the index
    w = DecodeScheduler(model, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                        page_size=PAGE_SIZE, prefill_chunk=PREFILL_CHUNK,
                        park_sessions=True, blob_store=store)
    fleet = FleetController(
        [w], min_workers=0, scale_to_zero=True, drain_idle_steps=2,
        faults=FaultPlan(crashes={("fleet:w0", "mid-park"): 0}))
    rng = np.random.default_rng(11)
    p1 = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    fleet.submit("sessA", "t1", p1, 3)
    t1 = np.asarray(_drive(fleet)["t1"].tokens)
    np.testing.assert_array_equal(t1, ref.run(p1, 3))

    _to_zero(fleet)                   # drain crashes mid-park
    assert fleet.crashes == 1
    assert PARK_META_PREFIX + "sessA" not in store.blobs, \
        "interrupted drain must not leave a committed directory entry"
    assert not any(k.startswith("park/") for k in store.blobs), \
        "orphaned journal KV blob survived the kill-path GC"

    p2 = np.concatenate([p1, t1.astype(np.int32),
                         rng.integers(0, cfg.vocab, 2).astype(np.int32)])
    fleet.submit("sessA", "t2", p2, 3)
    fin2 = _drive(fleet)["t2"]
    assert fin2.reused_tokens == 0, \
        "nothing durable survived — the fallback is a full re-prefill"
    assert fleet.meta_adoptions == 0 and fleet.meta_dropped == 0
    np.testing.assert_array_equal(np.asarray(fin2.tokens), ref.run(p2, 3))
