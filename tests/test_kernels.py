"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, reference_attention
from repro.kernels.paged_attention import (paged_attention,
                                           reference_paged_attention)
from repro.kernels.rglru_scan import reference_rglru, rglru_scan
from repro.kernels.ssd_scan import reference_ssd, ssd_scan

ATOL = {jnp.float32: 2e-4, jnp.bfloat16: 3e-2}


def _tol(dtype):
    return dict(atol=ATOL[dtype], rtol=ATOL[dtype])


def paged_inputs(seed, B, Hkv, G, D, ps, mp, n_pages, dtype,
                 fill=0.8, holes=0):
    """Random pool + per-slot tables: scrambled physical pages, ragged live
    lengths, optional unmapped (-1) holes punched below the live length."""
    rng = np.random.default_rng(seed)
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (B, 1, Hkv * G, D), dtype)
    kp = jax.random.normal(ks[1], (n_pages, ps, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (n_pages, ps, Hkv, D), dtype)
    k_new = jax.random.normal(ks[3], (B, 1, Hkv, D), dtype)
    v_new = jax.random.normal(ks[4], (B, 1, Hkv, D), dtype)
    lengths = rng.integers(1, max(2, int(mp * ps * fill)), size=B)
    pt = np.full((B, mp), -1, np.int32)
    for b in range(B):
        need = -(-int(lengths[b]) // ps)
        pt[b, :need] = rng.choice(n_pages, size=need, replace=False)
        for _ in range(holes):
            pt[b, rng.integers(0, mp)] = -1
    return (q, kp, vp, jnp.asarray(pt), jnp.asarray(lengths, jnp.int32),
            k_new, v_new)


# -- flash attention ------------------------------------------------------------


@pytest.mark.parametrize("B,S,T,H,Hkv,D", [
    (1, 16, 16, 4, 4, 8),      # MHA square
    (2, 32, 32, 8, 2, 16),     # GQA
    (1, 24, 40, 4, 1, 32),     # MQA, S != T, non-multiples of block
    (2, 128, 128, 4, 4, 64),   # block-aligned
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 8])
def test_flash_attention_sweep(B, S, T, H, Hkv, D, dtype, window):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=True, window=window, bq=16, bk=16)
    G = H // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, 1).reshape(B * H, T, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, 1).reshape(B * H, T, D)
    ref = reference_attention(qf, kf, vf, causal=True, window=window)
    ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_matches_model_sdpa():
    """Kernel agrees with the model-layer attention used by the XLA path."""
    from repro.models.layers import sdpa

    ks = jax.random.split(jax.random.key(1), 3)
    B, S, H, Hkv, D = 2, 24, 8, 4, 16
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    got = flash_attention(q, k, v, causal=True, bq=8, bk=8)
    want = sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32),
                               atol=2e-4, rtol=2e-4)


# -- paged attention -------------------------------------------------------------


@pytest.mark.parametrize("B,Hkv,G,D,ps,mp,n_pages,holes", [
    (1, 1, 1, 8, 4, 4, 8, 0),      # MQA/MHA minimal
    (3, 2, 3, 16, 8, 6, 32, 1),    # GQA, scrambled pages + a hole per slot
    (2, 4, 2, 32, 16, 8, 64, 2),   # wider pool, more holes
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 12])
def test_paged_attention_append_sweep(B, Hkv, G, D, ps, mp, n_pages, holes,
                                      dtype, window):
    q, kp, vp, pt, lengths, k_new, v_new = paged_inputs(
        B * 7 + mp, B, Hkv, G, D, ps, mp, n_pages, dtype, holes=holes)
    out = paged_attention(q, kp, vp, pt, lengths, k_new=k_new, v_new=v_new,
                          window=window)
    ref = reference_paged_attention(q, kp, vp, pt, lengths, k_new=k_new,
                                    v_new=v_new, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_post_update_sweep(dtype):
    """No-append mode (hybrid layers: the token is already in the pool) —
    the query sits at the last live lane."""
    B, Hkv, G, D, ps, mp = 3, 2, 2, 16, 8, 5
    q, kp, vp, pt, lengths, _, _ = paged_inputs(
        11, B, Hkv, G, D, ps, mp, 24, dtype, holes=1)
    out = paged_attention(q, kp, vp, pt, lengths, q_pos=lengths - 1,
                          window=8)
    ref = reference_paged_attention(q, kp, vp, pt, lengths,
                                    q_pos=lengths - 1, window=8)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_paged_attention_matches_model_gather_path():
    """Kernel == the gather formulation the decode paths use today:
    ``cache_kv_view`` (logical-order page gather) + ``sdpa_append``."""
    from repro.models import kvcache
    from repro.models.layers import sdpa_append

    B, Hkv, G, D, ps, mp = 2, 2, 4, 16, 4, 6
    q, kp, vp, pt, lengths, k_new, v_new = paged_inputs(
        3, B, Hkv, G, D, ps, mp, 16, jnp.float32, holes=1)
    got = paged_attention(q, kp, vp, pt, lengths, k_new=k_new, v_new=v_new)
    lc = {"kp": kp, "vp": vp, "page_table": pt}
    ck, cv, kv_pos, kv_valid = kvcache.cache_kv_view(lc, upto=lengths)
    want = sdpa_append(q, ck, cv, k_new, v_new, window=None,
                       q_positions=kvcache.decode_positions(lengths, B, 1),
                       kv_positions=kv_pos, kv_valid=kv_valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32),
                               atol=2e-4, rtol=2e-4)


def test_paged_attention_fully_unmapped_slot():
    """A slot with zero mapped pages must fall back to the new token alone
    (softmax over one logit), not NaN."""
    B, Hkv, G, D, ps, mp = 2, 1, 2, 8, 4, 3
    q, kp, vp, pt, _, k_new, v_new = paged_inputs(
        5, B, Hkv, G, D, ps, mp, 8, jnp.float32)
    pt = pt.at[1].set(-1)
    lengths = jnp.asarray([6, 0], jnp.int32)
    out = paged_attention(q, kp, vp, pt, lengths, k_new=k_new, v_new=v_new)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(
        np.asarray(out)[1, 0].reshape(Hkv, G, D),
        np.broadcast_to(np.asarray(v_new)[1, 0][:, None, :], (Hkv, G, D)),
        atol=1e-6, rtol=1e-6)


# -- ssd scan --------------------------------------------------------------------


@pytest.mark.parametrize("B,L,H,P,N,chunk,bh", [
    (1, 16, 2, 4, 8, 8, 2),
    (2, 37, 6, 8, 16, 8, 2),    # ragged L, H % bh != 0
    (1, 64, 4, 16, 32, 16, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, L, H, P, N, chunk, bh, dtype):
    ks = jax.random.split(jax.random.key(2), 4)
    x = jax.random.normal(ks[0], (B, L, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, L, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[0], (B, L, N)) * 0.5).astype(dtype)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, bh=bh)
    yr = reference_ssd(x.astype(jnp.float32), dt.astype(jnp.float32), A,
                       Bm.astype(jnp.float32), Cm.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=ATOL[dtype] * 5, rtol=ATOL[dtype] * 5)


def test_ssd_kernel_matches_model_chunked():
    """Kernel == the model's chunked SSD == the sequential recurrence."""
    from repro.models.mamba2 import ssd_chunked

    ks = jax.random.split(jax.random.key(3), 5)
    B, L, H, P, N = 2, 24, 4, 8, 16
    x = jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, L, N)) * 0.5
    y_kernel = ssd_scan(x, dt, A, Bm, Cm, chunk=8, bh=2)
    y_model, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=1e-4, rtol=1e-4)


# -- rg-lru scan -------------------------------------------------------------------


@pytest.mark.parametrize("B,L,W,bq,bw", [
    (1, 16, 8, 8, 8),
    (2, 29, 24, 8, 8),          # ragged both dims
    (1, 128, 64, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_sweep(B, L, W, bq, bw, dtype):
    ks = jax.random.split(jax.random.key(4), 2)
    a = (jax.nn.sigmoid(jax.random.normal(ks[0], (B, L, W))) * 0.98 + 0.01).astype(dtype)
    b = jax.random.normal(ks[1], (B, L, W), dtype)
    h = rglru_scan(a, b, block_q=bq, block_w=bw)
    hr = reference_rglru(a.astype(jnp.float32), b.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32),
                               atol=ATOL[dtype] * 5, rtol=ATOL[dtype] * 5)


def test_rglru_kernel_matches_model_scan():
    from repro.models.rglru import rglru_scan as model_scan

    ks = jax.random.split(jax.random.key(5), 2)
    B, L, W = 2, 20, 16
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, L, W))) * 0.9 + 0.05
    b = jax.random.normal(ks[1], (B, L, W))
    got = rglru_scan(a, b, block_q=8, block_w=8)
    want = model_scan(b, a)  # model takes (x_in, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_rglru_near_one_decay_stability():
    """a ~ 0.999^c as in trained RG-LRU; long block, no drift."""
    B, L, W = 1, 256, 8
    a = jnp.full((B, L, W), 0.999, jnp.float32)
    b = jnp.ones((B, L, W), jnp.float32) * 0.01
    h = rglru_scan(a, b, block_q=128, block_w=8)
    hr = reference_rglru(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-4, rtol=1e-4)
