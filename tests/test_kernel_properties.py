"""Hypothesis property sweeps over the Pallas kernels: random shapes/blocks
must always match the oracles (interpret mode)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property sweeps need hypothesis")
from hypothesis import HealthCheck, assume, given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention, reference_attention
from repro.kernels.paged_attention import (paged_attention,
                                           reference_paged_attention)
from repro.kernels.rglru_scan import reference_rglru, rglru_scan
from repro.kernels.ssd_scan import reference_ssd, ssd_scan


@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(S=st.integers(2, 40), T=st.integers(2, 40),
       Hkv=st.sampled_from([1, 2, 4]), G=st.sampled_from([1, 2, 3]),
       D=st.sampled_from([8, 16]), bq=st.sampled_from([8, 16]),
       window=st.sampled_from([None, 4, 16]), seed=st.integers(0, 99))
def test_flash_attention_property(S, T, Hkv, G, D, bq, window, seed):
    # exclude query rows with zero valid keys (q past the kv horizon with a
    # window): attention is undefined there — the kernel returns zeros, the
    # dense oracle a uniform average over the masked row.
    assume(window is None or T >= S)
    H = Hkv * G
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (1, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (1, T, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (1, T, Hkv, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, bq=bq, bk=bq)
    qf = q.transpose(0, 2, 1, 3).reshape(H, S, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, 1).reshape(H, T, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, 1).reshape(H, T, D)
    ref = reference_attention(qf, kf, vf, causal=True, window=window)
    ref = ref.reshape(1, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-4, rtol=3e-4)


@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(B=st.integers(1, 4), Hkv=st.sampled_from([1, 2, 4]),
       G=st.sampled_from([1, 2, 3]), D=st.sampled_from([8, 16]),
       ps=st.sampled_from([4, 8, 16]), mp=st.integers(2, 8),
       holes=st.integers(0, 2), window=st.sampled_from([None, 8, 24]),
       append=st.booleans(), seed=st.integers(0, 99))
def test_paged_attention_property(B, Hkv, G, D, ps, mp, holes, window,
                                  append, seed):
    """Any scrambled page table + ragged lengths + unmapped holes: the
    streamed kernel must match the gather oracle on every lane, in both the
    append (pre-update pool + new token) and post-update call modes."""
    from test_kernels import paged_inputs

    n_pages = 2 * mp + 3
    q, kp, vp, pt, lengths, k_new, v_new = paged_inputs(
        seed, B, Hkv, G, D, ps, mp, n_pages, jnp.float32, holes=holes)
    kw = (dict(k_new=k_new, v_new=v_new) if append
          else dict(q_pos=lengths - 1))
    if not append:
        # a slot whose every lane is masked (hole on the only live page
        # inside the window) is undefined: kernel returns zeros, the dense
        # oracle a uniform average — same convention as the flash kernel
        t = np.arange(mp * ps)
        for b in range(B):
            valid = (t < int(lengths[b])) & np.repeat(
                np.asarray(pt)[b] >= 0, ps)
            if window is not None:
                valid &= t > int(lengths[b]) - 1 - window
            assume(valid.any())
    out = paged_attention(q, kp, vp, pt, lengths, window=window, **kw)
    ref = reference_paged_attention(q, kp, vp, pt, lengths, window=window,
                                    **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-4, rtol=3e-4)


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(L=st.integers(2, 48), H=st.sampled_from([2, 4, 6]),
       P=st.sampled_from([4, 8]), N=st.sampled_from([8, 16]),
       chunk=st.sampled_from([4, 8, 16]), seed=st.integers(0, 99))
def test_ssd_scan_property(L, H, P, N, chunk, seed):
    ks = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(ks[0], (1, L, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (1, L, N)) * 0.5
    Cm = jax.random.normal(ks[4], (1, L, N)) * 0.5
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, bh=2)
    yr = reference_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(L=st.integers(2, 64), W=st.sampled_from([8, 16, 24]),
       bq=st.sampled_from([8, 16]), seed=st.integers(0, 99))
def test_rglru_scan_property(L, W, bq, seed):
    ks = jax.random.split(jax.random.key(seed), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, L, W))) * 0.98 + 0.01
    b = jax.random.normal(ks[1], (1, L, W))
    h = rglru_scan(a, b, block_q=bq, block_w=8)
    hr = reference_rglru(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=1e-3, rtol=1e-3)
