"""Bitwise decode/prefill KV parity on the paged pool.

The contract everything in this PR stands on: **an S=1 decode step is the
chunk path at S=1** — same gathered attention view, same pool scatter, same
recurrent-state fold — so the bytes a decode step writes into the page pool
are bitwise identical to what a chunked prefill of the same tokens writes.
Prefix sharing (generated-span publishing), session parking (consumed-span
reuse) and speculative verify-rollback all assume this; these tests prove it
at two levels:

* **model level** — one fixed token stream fed through three different
  chunkings (single chunk, mixed chunks, pure S=1 steps) of
  ``decode_step`` must leave every cache leaf (pool bytes, lengths,
  recurrent rows, conv tails) bitwise identical and emit bitwise-identical
  per-position logits.  Dense, MoE and hybrid archetypes, plus a
  sliding-window dense variant (the window is mask-only on the paged pool —
  no eviction — so parity must survive it).
* **scheduler level** — after a real request completes and parks, the KV
  pages its parked journal owns must hold, byte for byte, what a fresh
  chunked prefill of the consumed history writes.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.dist  # noqa: F401  (installs the AbstractMesh compat shim)
from repro import configs
from repro.models import build_model, kvcache
from repro.serve.scheduler import DecodeScheduler

ARCH_VARIANTS = [
    ("minicpm-2b", None),
    ("minicpm-2b", 8),                 # sliding-window dense
    ("moonshot-v1-16b-a3b", None),
    ("recurrentgemma-2b", None),
]


def _build(arch, window=None):
    cfg = configs.get(arch).reduced()
    if window is not None:
        cfg = dataclasses.replace(cfg, sliding_window=window)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(0))


def _one_slot_paged(model, *, n_pages, page_size):
    """B=1 paged cache with an identity page table (logical == physical)."""

    def ident(tree):
        if not isinstance(tree, dict):
            return tree
        return {k: (jnp.broadcast_to(
                        jnp.arange(v.shape[-1], dtype=jnp.int32), v.shape)
                    if k == "page_table" else ident(v))
                for k, v in tree.items()}

    return ident(kvcache.paged_cache(model, 1, page_size=page_size,
                                     n_pages=n_pages, max_pages=n_pages))


def _feed(model, params, cache, toks, chunks):
    """Run ``toks`` through ``decode_step`` in the given chunking; returns
    the concatenated per-position logits and the final cache."""
    assert sum(chunks) == len(toks)
    step = jax.jit(model.decode_step)
    out, i = [], 0
    for c in chunks:
        logits, cache = step(params, cache,
                             jnp.asarray(toks[None, i:i + c], jnp.int32))
        out.append(np.asarray(logits[0]))
        i += c
    return np.concatenate(out, axis=0), cache


def _assert_trees_bitwise(ca, cb, ctx):
    la = jax.tree_util.tree_leaves_with_path(ca)
    lb = jax.tree_util.tree_leaves(cb)
    assert len(la) == len(lb)
    for (path, a), b in zip(la, lb):
        a, b = np.asarray(a), np.asarray(b)
        if a.tobytes() != b.tobytes():
            # fall back for a readable diff; the raise below catches the
            # +0.0/-0.0 and NaN cases == would paper over
            np.testing.assert_array_equal(
                a, b, err_msg=f"{ctx}: leaf {jax.tree_util.keystr(path)}")
            raise AssertionError(
                f"{ctx}: leaf {jax.tree_util.keystr(path)} differs bitwise "
                f"(signed zero or NaN payload)")


@pytest.mark.parametrize(
    "arch,window", ARCH_VARIANTS,
    ids=[f"{a}{'' if w is None else f'-win{w}'}" for a, w in ARCH_VARIANTS])
def test_pool_bytes_s1_equals_chunked(arch, window):
    """One token stream, three chunkings — single chunk, mixed chunk sizes,
    and an S=1 tail after a prompt-sized chunk (exactly what the scheduler's
    decode loop does) — must agree bitwise on every cache leaf and every
    per-position logit row."""
    cfg, model, params = _build(arch, window)
    L, ps = 13, 4
    rng = np.random.default_rng(42)
    toks = rng.integers(0, cfg.vocab, size=L).astype(np.int32)

    def run(chunks):
        cache = _one_slot_paged(model, n_pages=5, page_size=ps)
        return _feed(model, params, cache, toks, chunks)

    la, ca = run([L])                       # one prefill chunk
    lb, cb = run([5, 4, 4])                 # mixed chunked prefill
    lc, cc = run([5] + [1] * (L - 5))       # prefill chunk + S=1 decode steps

    ctx = f"{arch} window={window}"
    assert la.tobytes() == lb.tobytes() == lc.tobytes(), \
        f"{ctx}: per-position logits diverged across chunkings"
    _assert_trees_bitwise(ca, cb, ctx + " [single vs mixed]")
    _assert_trees_bitwise(ca, cc, ctx + " [single vs S=1]")


@pytest.mark.parametrize("arch", [a for a, w in ARCH_VARIANTS if w is None])
def test_parked_pages_are_prefill_bytes(arch):
    """End-to-end form of the same claim: a parked session's journal pages —
    written partly by chunked prefill, partly by live S=1 decode steps —
    hold bitwise what one fresh prefill of the consumed history writes.
    This is the exactness that lets the prefix index publish generated-span
    pages and lets parked sessions reuse the full consumed span."""
    cfg, model, params = _build(arch)
    ps, P, N = 4, 9, 4
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=P).astype(np.int32)
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=24,
                            kv_mode="paged", page_size=ps, prefill_chunk=5,
                            prefix_sharing=True, park_sessions=True)
    sched.submit("s", "r0", prompt, N)
    n = 0
    while sched.busy():
        sched.step()
        sched.audit()
        n += 1
        assert n < 100
    rec = sched._parked["s"]
    assert rec.consumed == P + N - 1        # last sampled token: no KV yet
    n_pages = -(-rec.consumed // ps)
    assert len(rec.pages) == n_pages

    ref_cache = _one_slot_paged(model, n_pages=n_pages + 1, page_size=ps)
    _, ref_cache = _feed(model, params, ref_cache,
                         np.asarray(rec.history[:rec.consumed], np.int32),
                         [rec.consumed])

    got = kvcache.gather_pages(sched.cache,
                               [int(p) for p in rec.page_row[:n_pages]])
    exp = kvcache.gather_pages(ref_cache, list(range(n_pages)))
    gl = jax.tree_util.tree_leaves_with_path(got)
    el = jax.tree_util.tree_leaves(exp)
    for (path, g), e in zip(gl, el):
        g, e = np.asarray(g), np.asarray(e)
        # (..., n_pages, ps, H, D) -> (..., tokens, H, D); the tail of the
        # last page is unwritten scratch, compared only up to `consumed`
        g = g.reshape(g.shape[:-4] + (n_pages * ps,) + g.shape[-2:])
        e = e.reshape(e.shape[:-4] + (n_pages * ps,) + e.shape[-2:])
        sl = (Ellipsis, slice(0, rec.consumed), slice(None), slice(None))
        assert g[sl].tobytes() == e[sl].tobytes(), \
            f"{arch}: parked pages differ from prefill bytes at " \
            f"{jax.tree_util.keystr(path)}"
