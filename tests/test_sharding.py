"""Sharding-rule unit tests (no multi-device needed: rules are pure functions
of abstract shapes + mesh; a 1x1 mesh exercises the jit path)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import configs
from repro.dist import sharding as shd
from repro.models import build_model


def fake_mesh_16x16():
    """AbstractMesh stands in for the 256-chip mesh: rule resolution only
    needs axis names/sizes, never real devices."""
    from jax.sharding import AbstractMesh

    return AbstractMesh((16, 16), ("data", "model"))


def fake_mesh_multipod():
    from jax.sharding import AbstractMesh

    return AbstractMesh((2, 16, 16), ("pod", "data", "model"))


def _abstract_params(arch):
    cfg = configs.get(arch)
    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.key(0)), cfg


@pytest.mark.parametrize("arch", configs.list_archs())
def test_param_rules_cover_all_weights(arch):
    """Every >=2-dim weight leaf gets at least one sharded dim (16 GB HBM has
    no room for replicated matrices at 110B/235B scale)."""
    p_abs, cfg = _abstract_params(arch)
    mesh = fake_mesh_16x16()
    sh = shd.param_shardings(p_abs, mesh)
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    shapes = {tuple(k for k in path): leaf
              for path, leaf in jax.tree_util.tree_flatten_with_path(p_abs)[0]}
    replicated_big = []
    for path, s in flat:
        leaf = shapes[tuple(k for k in path)]
        if leaf.ndim >= 2 and np.prod(leaf.shape) > 1_000_000:
            if all(ax is None for ax in s.spec):
                replicated_big.append("/".join(str(getattr(k, "key", k)) for k in path))
    assert not replicated_big, f"{arch}: big replicated weights: {replicated_big}"


def test_divisibility_guard_degrades_to_replication():
    mesh = fake_mesh_16x16()
    # kv-head dim 8 does not divide 16 -> cache rule falls back to time dim
    cache = {"k": jax.ShapeDtypeStruct((4, 16, 4096, 8, 128), jnp.bfloat16)}
    sh = shd.cache_shardings(cache, mesh)
    spec = sh["k"].spec
    assert spec[2] == "model" and spec[3] is None  # time sharded, heads not
    # kv=16 divides -> heads sharded
    cache = {"k": jax.ShapeDtypeStruct((4, 16, 4096, 16, 128), jnp.bfloat16)}
    spec = shd.cache_shardings(cache, mesh)["k"].spec
    assert spec[3] == "model"


def test_multipod_dp_axes():
    mesh = fake_mesh_multipod()
    rules = shd.MeshRules.for_mesh(mesh)
    assert rules.dp == ("pod", "data")
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    spec = shd.batch_shardings(batch, mesh)["tokens"].spec
    assert spec[0] == ("pod", "data")


def test_head_weight_not_contraction_sharded():
    """Regression: sharding the head's contraction dim all-reduces the full
    logits tensor (the 40 GB/device whisper incident)."""
    p_abs, _ = _abstract_params("qwen3-14b")
    sh = shd.param_shardings(p_abs, fake_mesh_16x16())
    spec = sh["embedding"]["head"].spec
    assert spec[0] is None and spec[1] == "model"


def test_constrain_identity_without_policy():
    x = jnp.ones((4, 8))
    assert shd.constrain(x, "activation") is x


def test_constrain_applies_with_policy_on_single_device():
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    policy = shd.ShardingPolicy.default(mesh)

    def f(x):
        with shd.activation_sharding(policy):
            return shd.constrain(x, "activation") * 2

    out = jax.jit(f)(jnp.ones((2, 4, 8)))
    np.testing.assert_array_equal(np.asarray(out), 2 * np.ones((2, 4, 8)))


def test_attn_mode_specs():
    mesh = fake_mesh_16x16()
    head = shd.ShardingPolicy.default(mesh, attn_mode="head")
    seq = shd.ShardingPolicy.default(mesh, attn_mode="seq")
    assert head.specs["q_heads"][2] == "model"
    assert seq.specs["q_heads"][1] == "model"
    assert seq.specs["kv_heads"] == P(("data",), None, None, None)
