"""Shared test helpers.

NOTE: XLA device-count flags are deliberately NOT set here — smoke tests and
benches must see the single real CPU device; only ``launch/dryrun.py`` forces
512 placeholder devices (and it does so before importing jax).
"""

from __future__ import annotations

import sys
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple


SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro.core import FaaSKeeperService, FaultPlan, SimCloud  # noqa: E402
from repro.core import znode  # noqa: E402


def make_service(seed: int = 0, faults: Optional[FaultPlan] = None, regions=("region-0",),
                 **kwargs) -> Tuple[SimCloud, FaaSKeeperService]:
    cloud = SimCloud(seed=seed, faults=faults)
    svc = FaaSKeeperService(cloud, regions=regions, **kwargs)
    return cloud, svc


class Observations:
    """Per-client logs collected by workload drivers for invariant checks."""

    def __init__(self):
        self.acks: Dict[str, List[Dict[str, Any]]] = {}     # session -> acked writes
        self.reads: Dict[str, List[Dict[str, Any]]] = {}    # session -> read completions
        self.watch_deliveries: Dict[str, List[Dict[str, Any]]] = {}
        self.watch_registrations: Dict[str, List[Dict[str, Any]]] = {}
        self.errors: Dict[str, List[Dict[str, Any]]] = {}

    def log(self, kind: str, session: str, **fields) -> None:
        getattr(self, kind).setdefault(session, []).append(fields)


def replay_history(acked_ops: List[Dict[str, Any]]) -> Dict[str, List[Dict[str, Any]]]:
    """Replay acked writes in txid order; returns per-path state history."""
    tree: Dict[str, Dict[str, Any]] = {"/": znode.fresh_node("/")}
    tree["/"]["exists"] = True
    history: Dict[str, List[Dict[str, Any]]] = {"/": [dict(tree["/"])]}
    for op in sorted(acked_ops, key=lambda o: o["txid"]):
        path = op["path"]
        parent = znode.parent_path(path)
        node_pre = tree.get(path)
        parent_pre = tree.get(parent) if op["op"] in ("create", "delete") and path != "/" else None
        node_post, parent_post = znode.materialize(
            op["op"], dict(op["args"], path=path), node_pre, parent_pre, op["txid"]
        )
        tree[path] = node_post
        history.setdefault(path, []).append(dict(node_post))
        if parent_post is not None:
            tree[parent] = parent_post
            history.setdefault(parent, []).append(dict(parent_post))
    return {"tree": tree, "history": history}
