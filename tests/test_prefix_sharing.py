"""Refcounted copy-on-write prefix sharing + cross-request session parking.

The headline invariants: (1) a request admitted over shared pages — a parked
session's journal or the content-addressed prefix index — produces
token-for-token identical output to a from-scratch solo run, while paying
prefill only for its tail; (2) shared pages are immutable: a completion must
never free a page another holder still maps (refcounts), and a writer must
never mutate a shared page in place (copy-on-write splits, verified at lane
level against the pool bytes, not just argmax); (3) ``reset()`` forgets the
prefix index and the parked table, so a crash-replayed run cannot observe
another life's shared state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.dist  # noqa: F401  (installs the AbstractMesh compat shim)
from repro import configs
from repro.models import build_model, kvcache
from repro.serve.engine import generate
from repro.serve.lifecycle import SlotState
from repro.serve.scheduler import DecodeScheduler

MAX_SEQ = 32


def tiny(arch="minicpm-2b"):
    cfg = configs.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def drain(sched, got=None, limit=300):
    got = got if got is not None else {}
    it = 0
    while sched.busy():
        for fin in sched.step():
            got[fin.request_id] = fin
        sched.audit()
        it += 1
        assert it < limit, "scheduler failed to drain"
    return got


def solo(model, params, prompt, max_new):
    return np.asarray(generate(model, params, jnp.asarray(prompt)[None],
                               max_new, seq_len=MAX_SEQ))[0]


# ---------------------------------------------------------------------------
# PageAllocator refcounts
# ---------------------------------------------------------------------------


def test_allocator_refcounts():
    a = kvcache.PageAllocator(4)
    p = a.alloc(2)
    assert a.refcount(p[0]) == 1 and a.in_use == 2 and a.total_refs == 2
    a.share([p[0]])
    assert a.refcount(p[0]) == 2 and a.total_refs == 3
    a.release([p[0]])                       # one ref down: still mapped
    assert a.refcount(p[0]) == 1 and a.in_use == 2
    a.release([p[0]])                       # last ref: back to the free list
    assert a.refcount(p[0]) == 0 and a.in_use == 1
    assert a.free_count + a.in_use == a.n_pages
    with pytest.raises(ValueError):
        a.release([p[0]])                   # double release
    with pytest.raises(ValueError):
        a.share([p[0]])                     # sharing a freed page
    a.check()
    a.release([p[1]])
    assert a.free_count == 4 and a.total_refs == 0


def test_allocator_free_alias_keeps_refcount_semantics():
    """`free` (the pre-refcount name) is one release, not a force-free."""
    a = kvcache.PageAllocator(2)
    (p,) = a.alloc(1)
    a.share([p])
    a.free([p])
    assert a.refcount(p) == 1 and a.in_use == 1
    a.free([p])
    assert a.free_count == 2


# ---------------------------------------------------------------------------
# Prefix index: content addressing + LRU eviction
# ---------------------------------------------------------------------------


def test_page_hashes_chain_on_prefix():
    ps = 4
    t1 = np.arange(12, dtype=np.int32)
    t2 = t1.copy()
    t2[1] = 99                               # first page differs
    h1, h2 = kvcache.page_hashes(t1, ps), kvcache.page_hashes(t2, ps)
    assert len(h1) == 3                      # full pages only
    assert h1[0] != h2[0]
    # chaining: identical page-2 *content* still hashes apart because the
    # prefix differs — sharing keys on the whole token history
    assert h1[1] != h2[1] and h1[2] != h2[2]
    assert kvcache.page_hashes(t1[:11], ps) == h1[:2]   # partial page dropped


def test_prefix_index_publish_lookup_evict():
    a = kvcache.PageAllocator(6)
    idx = kvcache.PrefixIndex()
    pages = a.alloc(3)
    hashes = kvcache.page_hashes(np.arange(12, dtype=np.int32), 4)
    assert idx.publish(hashes, pages, a) == 3
    assert all(a.refcount(p) == 2 for p in pages)
    assert idx.publish(hashes, pages, a) == 0          # dedupe: no new refs
    assert idx.lookup(hashes) == pages
    other = kvcache.page_hashes(np.arange(100, 112, dtype=np.int32), 4)
    assert idx.lookup(other) == []
    assert idx.lookup([hashes[0], other[0], hashes[2]]) == [pages[0]]
    # the holder releases: pages survive on the index's reference alone
    a.release(pages)
    assert a.in_use == 3
    # eviction reclaims index references until enough pages are free
    dropped = idx.evict(a, need_free=5)
    assert dropped == 2 and a.free_count == 5 and len(idx) == 1
    idx.clear(a)
    assert a.free_count == 6 and a.total_refs == 0


# ---------------------------------------------------------------------------
# Copy-on-write at the kvcache level: lane-exact, original untouched
# ---------------------------------------------------------------------------


def test_copy_pages_lane_exact():
    rng = np.random.default_rng(3)
    pool = {"kp": jnp.asarray(rng.standard_normal((2, 5, 4, 2, 3)), jnp.float32),
            "vp": jnp.asarray(rng.standard_normal((2, 5, 4, 2, 3)), jnp.float32),
            "page_table": jnp.zeros((2, 1, 2), jnp.int32)}
    out = kvcache.copy_pages(pool, [1, 3], [0, 4])
    for k in ("kp", "vp"):
        np.testing.assert_array_equal(np.asarray(out[k][:, 0]),
                                      np.asarray(pool[k][:, 1]))
        np.testing.assert_array_equal(np.asarray(out[k][:, 4]),
                                      np.asarray(pool[k][:, 3]))
        np.testing.assert_array_equal(np.asarray(out[k][:, [1, 2, 3]]),
                                      np.asarray(pool[k][:, [1, 2, 3]]))
    np.testing.assert_array_equal(np.asarray(out["page_table"]),
                                  np.asarray(pool["page_table"]))


def test_gather_scatter_slot_state_round_trip():
    cfg, model, params = tiny("recurrentgemma-2b")
    sched = DecodeScheduler(model, params, n_slots=3, max_seq=16, page_size=4)
    rng = np.random.default_rng(5)
    sched.submit("s", "r0", rng.integers(0, cfg.vocab, 8).astype(np.int32), 3)
    sched.step(); sched.step()
    snap = jax.device_get(kvcache.gather_slot_state(sched.cache, 0))
    # state excludes the shared pool and the page table
    flat = dict(kvcache._iter_pool_leaves(snap))
    assert all(k[-1] not in ("kp", "vp", "page_table") for k in flat)
    # scatter into a different slot and gather back: bit-identical
    back = kvcache.scatter_slot_state(sched.cache, 2, snap)
    snap2 = jax.device_get(kvcache.gather_slot_state(back, 2))
    jax.tree_util.tree_map(np.testing.assert_array_equal, snap, snap2)


# ---------------------------------------------------------------------------
# The sharp edge: shared page freed under a live reader / CoW mid-decode
# ---------------------------------------------------------------------------


def test_release_keeps_shared_page_and_cow_splits_mid_decode():
    """Two slots share an indexed prefix page; the one that completes first
    must not free it (the other still maps it), and a decode write through
    a shared page must CoW-split — verified lane-level: the shared page's
    pool bytes are bit-identical before and after, not just argmax."""
    cfg, model, params = tiny()
    ps, N = 4, 6
    rng = np.random.default_rng(11)
    sys_p = rng.integers(0, cfg.vocab, size=2 * ps).astype(np.int32)
    pa = np.concatenate([sys_p, rng.integers(0, cfg.vocab, 3).astype(np.int32)])
    pb = np.concatenate([sys_p, rng.integers(0, cfg.vocab, 4).astype(np.int32)])
    pc = np.concatenate([sys_p, rng.integers(0, cfg.vocab, 5).astype(np.int32)])
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=MAX_SEQ,
                            page_size=ps, prefill_chunk=5, prefix_sharing=True)
    got = {}
    sched.submit("a", "r0", pa, N)
    drain(sched, got)                       # publishes a's full pages
    sys_pages = sched.prefix_index.lookup(kvcache.page_hashes(sys_p, ps))
    assert len(sys_pages) == 2
    indexed = sorted(sched.prefix_index.pages)
    before = {k: np.asarray(jnp.take(sched.cache[k], jnp.asarray(indexed),
                                     axis=1))
              for k in ("kp", "vp")}

    # b (short) and c (long) admit concurrently over the shared sys pages
    sched.submit("b", "r1", pb, 3)
    sched.submit("c", "r2", pc, 8)
    assert sched.slots[0].shared == sys_pages
    assert sched.slots[1].shared == sys_pages
    assert sched.allocator.refcount(sys_pages[0]) == 3   # index + b + c

    def step_into(got):
        for fin in sched.step():
            got[fin.request_id] = fin

    it = 0
    while "r1" not in got:        # b (3 tokens) finishes well before c (8)
        step_into(got)
        sched.audit()
        it += 1
        assert it < 20
    # b completed and released its references: the page survives for c
    assert sched.allocator.refcount(sys_pages[0]) == 2   # index + c
    assert all(sched.allocator.refcount(p) >= 1 for p in indexed)
    c_slot = sched.slots[1]
    assert c_slot.state is SlotState.ACTIVE

    # force a *decode* write through a shared page: give c's current append
    # page an external reference (as a parked journal would hold) and step.
    # (step until the append page is resident — a fresh page maps lazily
    # during the decode step itself)
    while int(sched._page_rows[1, c_slot.len // ps]) < 0:
        step_into(got)
        assert c_slot.state is SlotState.ACTIVE
    append_page = int(sched._page_rows[1, c_slot.len // ps])
    sched.allocator.share([append_page])
    page_before = {k: np.asarray(sched.cache[k][:, append_page])
                   for k in ("kp", "vp")}
    cow0 = sched.cow_splits
    step_into(got)
    assert sched.cow_splits == cow0 + 1, "decode write did not CoW-split"
    for k in ("kp", "vp"):                   # original bytes untouched
        np.testing.assert_array_equal(
            np.asarray(sched.cache[k][:, append_page]), page_before[k])
    assert append_page not in sched.slots[1].pages
    sched.allocator.release([append_page])   # drop the synthetic holder
    sched.audit()
    drain(sched, got)

    # lane-level: the published pages never moved a bit through all of it
    after = {k: np.asarray(jnp.take(sched.cache[k], jnp.asarray(indexed),
                                    axis=1))
             for k in ("kp", "vp")}
    for k in ("kp", "vp"):
        np.testing.assert_array_equal(before[k], after[k])
    # token-for-token parity for every request that ran over shared pages
    for rid, p, n in [("r0", pa, N), ("r1", pb, 3), ("r2", pc, 8)]:
        np.testing.assert_array_equal(got[rid].tokens, solo(model, params, p, n),
                                      err_msg=f"{rid} diverged from solo")
    assert got["r2"].reused_tokens == 2 * ps


@pytest.mark.parametrize("arch", ["minicpm-2b", "recurrentgemma-2b"])
def test_multiturn_park_parity(arch):
    """Turn 2/3 extend the session history: the parked journal serves the
    resident prefix, only the tail prefills, and the output is exactly the
    from-scratch solo run.  Both families reuse the whole *consumed* span —
    prompt and generated tokens alike — because decode-written KV is bitwise
    what a re-prefill would write (the S=1 decode path IS the chunk path at
    S=1); only the last sampled token, whose KV was never written, re-feeds."""
    cfg, model, params = tiny(arch)
    N = 3
    rng = np.random.default_rng(7)
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=MAX_SEQ,
                            page_size=4, prefill_chunk=5,
                            park_sessions=True, prefix_sharing=True)
    hist = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    prefill_per_turn = []
    expect_reused = 0
    for turn in range(3):
        before = sched.prefill_tokens
        got = {}
        sched.submit("s", f"r{turn}", hist, N)
        drain(sched, got)
        np.testing.assert_array_equal(
            got[f"r{turn}"].tokens, solo(model, params, hist, N),
            err_msg=f"{arch} turn {turn} diverged")
        prefill_per_turn.append(sched.prefill_tokens - before)
        assert got[f"r{turn}"].reused_tokens == expect_reused
        assert prefill_per_turn[-1] == len(hist) - expect_reused
        # what the journal serves next turn: everything consumed — prompt
        # plus all but the last generated token (its KV was never written)
        expect_reused = len(hist) + N - 1
        hist = np.concatenate([hist, got[f"r{turn}"].tokens.astype(np.int32),
                               rng.integers(0, cfg.vocab, 2).astype(np.int32)])
    # turn >= 2 prefills only the tail while the prompt kept growing
    assert prefill_per_turn[1] < prefill_per_turn[0]
    assert prefill_per_turn[2] <= prefill_per_turn[1]
    assert sched.park_hits == 2 and sched.parks == 3


def test_park_offload_restores_from_blob():
    """Pool pressure offloads a parked journal through the PageBlobStore;
    the session's next request restores the blob (one GET) instead of
    re-prefilling, still token-exact."""
    cfg, model, params = tiny()
    N = 4
    rng = np.random.default_rng(9)
    p1 = rng.integers(0, cfg.vocab, size=10).astype(np.int32)
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=MAX_SEQ,
                            page_size=4, park_sessions=True)
    got = {}
    sched.submit("s", "r0", p1, N)
    drain(sched, got)
    rec = sched._parked["s"]
    sched._offload_parked(rec)
    sched.audit()
    assert rec.blob_key and not rec.pages and rec.slot is None
    assert sched.blob_store.bytes_stored > 0
    p2 = np.concatenate([p1, got["r0"].tokens.astype(np.int32),
                         rng.integers(0, cfg.vocab, 3).astype(np.int32)])
    sched.submit("s", "r1", p2, N)
    drain(sched, got)
    np.testing.assert_array_equal(got["r1"].tokens, solo(model, params, p2, N))
    assert sched.blob_store.gets == 1
    assert got["r1"].reused_tokens == len(p1) + N - 1   # full consumed span


def test_park_blob_restore_slices_to_reused_span():
    """A blob journal can hold more pages than the next request reuses (a
    short extension keeps at least one prompt token as the prefill tail):
    the restore must allocate and inject only the reused span, not the
    whole blob — the whole-blob version over-allocates past the admission's
    reservation."""
    cfg, model, params = tiny()
    rng = np.random.default_rng(31)
    p1 = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=MAX_SEQ,
                            page_size=4, park_sessions=True)
    got = {}
    sched.submit("s", "r0", p1, 12)          # long generated tail: 5-page blob
    drain(sched, got)
    rec = sched._parked["s"]
    sched._offload_parked(rec)
    sched.audit()
    assert len(rec.blob_pidx) == 5           # ceil((8+12-1)/4)
    # next turn is an 11-token prompt: reuse caps at P-1 = 10 tokens (one
    # token must remain as the prefill tail), i.e. 3 of the 5 blob pages
    p2 = np.concatenate([p1, got["r0"].tokens[:3].astype(np.int32)])
    sched.submit("s", "r1", p2, 4)
    assert sched.slots[0].state is SlotState.ADMITTING or \
        sched.slots[1].state is SlotState.ADMITTING
    assert sched.blob_store.gets == 1
    drain(sched, got)
    np.testing.assert_array_equal(got["r1"].tokens, solo(model, params, p2, 4))
    assert got["r1"].reused_tokens == len(p2) - 1


def test_short_matching_resubmission_keeps_journal():
    """A prompt that matches the journal but leaves no prefill tail (hybrid:
    a resubmission of exactly the consumed span) must not be treated as
    divergence — the journal survives and serves the next real extension."""
    cfg, model, params = tiny("recurrentgemma-2b")
    rng = np.random.default_rng(37)
    p1 = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=MAX_SEQ,
                            page_size=4, park_sessions=True)
    got = {}
    sched.submit("s", "r0", p1, 3)
    drain(sched, got)
    hist = np.concatenate([p1, got["r0"].tokens.astype(np.int32)])
    # journal: history = 11, consumed = 10.  P = 10 == consumed leaves no
    # tail token to prefill: consistent but too short — reuse nothing, but
    # do NOT drop the journal
    sched.submit("s", "r1", hist[:10], 3)
    drain(sched, got)
    np.testing.assert_array_equal(got["r1"].tokens,
                                  solo(model, params, hist[:10], 3))
    assert sched.park_misses == 0            # NOT a divergence
    assert got["r1"].reused_tokens == 0
    # a real extension afterwards still park-hits (the superseding journal:
    # history = 13, consumed = 12)
    hist2 = np.concatenate([hist[:10], got["r1"].tokens.astype(np.int32),
                            rng.integers(0, cfg.vocab, 2).astype(np.int32)])
    sched.submit("s", "r2", hist2, 3)
    drain(sched, got)
    np.testing.assert_array_equal(got["r2"].tokens,
                                  solo(model, params, hist2, 3))
    assert sched.park_hits == 1
    assert got["r2"].reused_tokens == 12     # the full consumed span


def test_exact_resubmission_reuses_consumed_span():
    """The case the consumed-span lift unlocks for the hybrid: resubmitting
    the full recorded history (P = consumed + 1) now reuses every consumed
    token and prefills only the last sampled one — previously an exact
    resubmission was 'too short' because the recurrent rows demanded the
    whole prompt be re-fed."""
    cfg, model, params = tiny("recurrentgemma-2b")
    rng = np.random.default_rng(41)
    p1 = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=MAX_SEQ,
                            page_size=4, park_sessions=True)
    got = {}
    sched.submit("s", "r0", p1, 3)
    drain(sched, got)
    hist = np.concatenate([p1, got["r0"].tokens.astype(np.int32)])
    before = sched.prefill_tokens
    sched.submit("s", "r1", hist, 3)         # P = 11 = consumed + 1
    drain(sched, got)
    np.testing.assert_array_equal(got["r1"].tokens,
                                  solo(model, params, hist, 3))
    assert sched.park_hits == 1
    assert got["r1"].reused_tokens == len(hist) - 1
    assert sched.prefill_tokens - before == 1    # only the sampled token


def test_slot_pressure_evicts_parked_then_restores():
    """All slots parked; a third session's admission reclaims the oldest
    residency (rows snapshot to the record); when the evicted session
    returns, its journal restores into a *different* slot — still exact."""
    cfg, model, params = tiny("recurrentgemma-2b")
    N = 3
    rng = np.random.default_rng(13)
    pa = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, size=9).astype(np.int32)
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=MAX_SEQ,
                            page_size=4, park_sessions=True)
    got = {}
    sched.submit("a", "r0", pa, N)
    drain(sched, got)
    sched.submit("b", "r1", pb, N)
    drain(sched, got)
    assert sched.parked_slots() == 2
    pc = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
    sched.submit("c", "r2", pc, N)           # no empty slot: evicts a's
    drain(sched, got)
    assert sched.park_evictions == 1
    assert sched._parked["a"].slot is None
    assert sched._parked["a"].state is not None
    pa2 = np.concatenate([pa, got["r0"].tokens.astype(np.int32),
                          rng.integers(0, cfg.vocab, 2).astype(np.int32)])
    sched.submit("a", "r3", pa2, N)
    drain(sched, got)
    for rid, p in [("r0", pa), ("r1", pb), ("r2", pc), ("r3", pa2)]:
        np.testing.assert_array_equal(got[rid].tokens, solo(model, params, p, N),
                                      err_msg=f"{rid} diverged")
    assert got["r3"].reused_tokens == len(pa) + N - 1


def test_park_ttl_expires_idle_sessions():
    cfg, model, params = tiny()
    rng = np.random.default_rng(17)
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=MAX_SEQ,
                            page_size=4, park_sessions=True, park_ttl_steps=4)
    got = {}
    sched.submit("s", "r0", rng.integers(0, cfg.vocab, 8).astype(np.int32), 3)
    drain(sched, got)
    assert "s" in sched._parked
    # another session keeps the step clock moving past the TTL
    sched.submit("t", "r1", rng.integers(0, cfg.vocab, 8).astype(np.int32), 8)
    drain(sched, got)
    assert sched.park_expirations == 1 and "s" not in sched._parked
    sched.audit()
    # every page the expired journal held is reclaimed
    assert sched.allocator.total_refs == sum(
        len(r.pages) for r in sched._parked.values()) + len(sched.prefix_index)


def test_reset_clears_prefix_index_and_parked_table():
    """Crash replay must not observe stale cross-request sharing: reset()
    forgets the index and the parked table, and the redelivered session
    replays from its prompt — full prefill, same tokens."""
    cfg, model, params = tiny()
    N = 4
    rng = np.random.default_rng(19)
    p1 = rng.integers(0, cfg.vocab, size=10).astype(np.int32)
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=MAX_SEQ,
                            page_size=4, park_sessions=True,
                            prefix_sharing=True)
    got = {}
    sched.submit("s", "r0", p1, N)
    drain(sched, got)
    assert sched._parked and len(sched.prefix_index) > 0
    sched.reset()
    assert not sched._parked and len(sched.prefix_index) == 0
    a = sched.allocator
    assert a.in_use == 0 and a.free_count == a.n_pages and a.total_refs == 0
    # replay: turn-2 prompt finds nothing resident — full prefill, exact
    p2 = np.concatenate([p1, got["r0"].tokens.astype(np.int32),
                         rng.integers(0, cfg.vocab, 3).astype(np.int32)])
    before = sched.prefill_tokens
    sched.submit("s", "r1", p2, N)
    drain(sched, got)
    assert sched.park_hits == 0 and sched.index_hits == 0
    assert sched.prefill_tokens - before == len(p2)
    np.testing.assert_array_equal(got["r1"].tokens, solo(model, params, p2, N))


def test_sharing_requires_paged_pool():
    cfg, model, params = tiny()
    for kw in ({"prefix_sharing": True}, {"park_sessions": True}):
        with pytest.raises(ValueError, match="paged"):
            DecodeScheduler(model, params, n_slots=2, max_seq=16,
                            kv_mode="ring", **kw)


def test_index_sharing_gated_to_attention_families():
    """Hybrid recurrent rows cannot be rebuilt from KV pages alone: the
    index is never consulted (or published) for them, while parking — which
    keeps the rows — stays on."""
    cfg, model, params = tiny("recurrentgemma-2b")
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=MAX_SEQ,
                            page_size=4, prefix_sharing=True,
                            park_sessions=True)
    assert sched.prefix_sharing and not sched._index_sharing
    rng = np.random.default_rng(23)
    got = {}
    sched.submit("s", "r0", rng.integers(0, cfg.vocab, 8).astype(np.int32), 3)
    drain(sched, got)
    assert len(sched.prefix_index) == 0 and sched.parks == 1


def test_preempt_restore_of_unparked_slot_stays_exact():
    """A slot decoding over shared parked pages gets preempted: the blob
    captures the shared prefix too, the restore owns everything, and the
    journal keeps its own references — still token-exact for both lives."""
    cfg, model, params = tiny()
    N = 6
    rng = np.random.default_rng(29)
    p1 = rng.integers(0, cfg.vocab, size=9).astype(np.int32)
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=MAX_SEQ,
                            page_size=4, park_sessions=True, offload=True)
    got = {}
    sched.submit("s", "r0", p1, N)
    drain(sched, got)
    p2 = np.concatenate([p1, got["r0"].tokens.astype(np.int32),
                         rng.integers(0, cfg.vocab, 2).astype(np.int32)])
    sched.submit("s", "r1", p2, N)
    sched.submit("t", "r2", rng.integers(0, cfg.vocab, 8).astype(np.int32), N)
    steps = 0
    while sched.busy():
        if steps == 3:
            victim = next(s for s in sched.slots
                          if s.state is SlotState.ACTIVE and s.pages)
            sched.preempt(victim.index)
        for fin in sched.step():
            got[fin.request_id] = fin
        sched.audit()
        steps += 1
        assert steps < 300
    np.testing.assert_array_equal(got["r1"].tokens, solo(model, params, p2, N))
    assert sched.preemptions == 1 and sched.restores == 1


def test_frontend_bills_park_retention():
    """Parked-retention economics surface through the serving frontend: a
    pressure-offloaded journal's blob accrues Table-4 S3 retention over
    simulated time, the restore GET is billed as an object read, and the
    prompt tokens it saved are itemized next to the bill."""
    from repro.core import SimCloud
    from repro.coord.serving_front import InferenceRequest, ServingFrontend

    cfg, model, params = tiny()
    cloud = SimCloud(seed=0)
    # pool sized so session t's fresh admission must offload s's journal
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=20, page_size=4,
                            kv_pages=5, park_sessions=True,
                            prefix_sharing=True)
    fe = ServingFrontend(cloud, scheduler=sched, batch_size=2)
    rng = np.random.default_rng(0)
    p1 = rng.integers(0, cfg.vocab, size=10).astype(np.int32)
    cloud.run_task(fe.submit(InferenceRequest("s", "q0", p1, 4)), name="c0")
    cloud.run()
    assert sched.parked_slots() == 1
    cloud.run_task(fe.submit(
        InferenceRequest("t", "q1",
                         rng.integers(0, cfg.vocab, 10).astype(np.int32), 4)),
        name="c1")
    cloud.run()
    assert sched.park_offloads == 1          # pool pressure pushed s's blob
    p2 = np.concatenate([p1, np.asarray(fe.results["s"][0], np.int32),
                         rng.integers(0, cfg.vocab, 2).astype(np.int32)])
    cloud.run_task(fe.submit(InferenceRequest("s", "q2", p2, 4)), name="c2")
    cloud.run()
    np.testing.assert_array_equal(
        fe.results["s"][1],
        np.asarray(generate(model, params, jnp.asarray(p2)[None], 4,
                            seq_len=20))[0])
    stats = fe.serving_stats()
    assert stats["park_hits"] == 1
    assert stats["shared_prefix_tokens"] == len(p1) + 4 - 1   # consumed span
    assert stats["park_storage_usd"] > 0.0   # blob bytes x sim-time retention
    assert cloud.op_counts.get("obj_read", 0) >= 1   # the restore GET billed
    assert cloud.op_counts.get("obj_write", 0) >= 1  # the offload PUT billed


def test_shared_pool_specs_survive_sharing():
    """Sharing never changes pool placement: pages have no slot axis, so
    the shared pool keeps its within-page lane dim on ``model`` (replicated
    over dp — the paged kernel's per-(page, head) block slices stay local)
    with the prefix index on."""
    from jax.sharding import AbstractMesh

    cfg, model, params = tiny("qwen3-14b")
    mesh = AbstractMesh((2, 2), ("data", "model"))
    sched = DecodeScheduler(model, params, n_slots=4, max_seq=32,
                            page_size=8, mesh=mesh, prefix_sharing=True,
                            park_sessions=True, offload=True)
    specs = sched.cache_specs
    assert specs is not None
    kp = specs["layers"]["kp"] if "layers" in specs else specs["kp"]
    assert kp[-3] == "model"
    assert all(e is None for e in kp[:-3] + kp[-2:])
    assert sched.stage_specs is not None
