"""Client-visible ZooKeeper semantics (paper §4.1, §4.6)."""

import pytest

from conftest import make_service
from repro.core import (
    BadVersionError,
    NodeExistsError,
    NoNodeError,
    NotEmptyError,
)


def test_create_and_read():
    cloud, svc = make_service()
    c = svc.connect_sync("s1")
    assert c.create("/a", b"x") == "/a"
    data, stat = c.get_data("/a")
    assert data == b"x"
    assert stat.version == 0
    assert stat.modified_txid >= 1


def test_read_your_write_after_ack():
    cloud, svc = make_service()
    c = svc.connect_sync("s1")
    c.create("/a", b"1")
    for i in range(5):
        c.set_data("/a", str(i).encode())
        data, stat = c.get_data("/a")
        assert data == str(i).encode()
        assert stat.version == i + 1


def test_create_existing_fails():
    cloud, svc = make_service()
    c = svc.connect_sync("s1")
    c.create("/a", b"")
    with pytest.raises(NodeExistsError):
        c.create("/a", b"")


def test_missing_node_errors():
    cloud, svc = make_service()
    c = svc.connect_sync("s1")
    with pytest.raises(NoNodeError):
        c.get_data("/nope")
    with pytest.raises(NoNodeError):
        c.set_data("/nope", b"")
    with pytest.raises(NoNodeError):
        c.delete("/nope")
    with pytest.raises(NoNodeError):
        c.create("/no/parent", b"")


def test_conditional_version_semantics():
    cloud, svc = make_service()
    c = svc.connect_sync("s1")
    c.create("/a", b"")
    assert c.set_data("/a", b"1", version=0) == 1
    with pytest.raises(BadVersionError):
        c.set_data("/a", b"2", version=0)
    assert c.set_data("/a", b"2", version=1) == 2
    with pytest.raises(BadVersionError):
        c.delete("/a", version=0)
    c.delete("/a", version=2)
    assert c.exists("/a") is None


def test_delete_nonempty_fails():
    cloud, svc = make_service()
    c = svc.connect_sync("s1")
    c.create("/a", b"")
    c.create("/a/b", b"")
    with pytest.raises(NotEmptyError):
        c.delete("/a")
    c.delete("/a/b")
    c.delete("/a")


def test_children_and_cversion():
    cloud, svc = make_service()
    c = svc.connect_sync("s1")
    c.create("/a", b"")
    c.create("/a/x", b"")
    c.create("/a/y", b"")
    children, stat = c.get_children("/a")
    assert children == ["x", "y"]
    assert stat.cversion == 2
    c.delete("/a/x")
    children, stat = c.get_children("/a")
    assert children == ["y"]
    assert stat.cversion == 3


def test_sequential_nodes_monotone():
    cloud, svc = make_service()
    c1 = svc.connect_sync("s1")
    c2 = svc.connect_sync("s2")
    c1.create("/q", b"")
    paths = [
        c1.create("/q/n-", b"", sequence=True),
        c2.create("/q/n-", b"", sequence=True),
        c1.create("/q/n-", b"", sequence=True),
    ]
    suffixes = [int(p.rsplit("-", 1)[1]) for p in paths]
    assert suffixes == sorted(suffixes)
    assert len(set(suffixes)) == 3


def test_ephemeral_no_children():
    cloud, svc = make_service()
    c = svc.connect_sync("s1")
    c.create("/e", b"", ephemeral=True)
    from repro.core import FKError

    with pytest.raises(FKError):
        c.create("/e/child", b"")


def test_watch_data_change():
    cloud, svc = make_service()
    c1 = svc.connect_sync("s1")
    c2 = svc.connect_sync("s2")
    c1.create("/w", b"0")
    c2.get_data("/w", watch=True)
    c1.set_data("/w", b"1")
    ev = c2.wait_watch("/w")
    assert ev["event"] == "changed"
    # one-shot: a second update does not re-notify
    n_events = len([e for e in c2.client.inbox.events if e.get("kind") == "watch"])
    c1.set_data("/w", b"2")
    cloud.run()
    assert len([e for e in c2.client.inbox.events if e.get("kind") == "watch"]) == n_events


def test_watch_children_and_delete():
    cloud, svc = make_service()
    c1 = svc.connect_sync("s1")
    c2 = svc.connect_sync("s2")
    c1.create("/p", b"")
    c2.get_children("/p", watch=True)
    c1.create("/p/kid", b"")
    ev = c2.wait_watch("/p")
    assert ev["event"] == "child"
    c2.get_data("/p/kid", watch=True)
    c1.delete("/p/kid")
    ev = c2.wait_watch("/p/kid")
    assert ev["event"] == "deleted"


def test_exists_watch_on_creation():
    cloud, svc = make_service()
    c1 = svc.connect_sync("s1")
    c2 = svc.connect_sync("s2")
    assert c2.exists("/soon", watch=True) is None
    c1.create("/soon", b"")
    ev = c2.wait_watch("/soon")
    assert ev["event"] == "created"


def test_multi_region_replication():
    cloud, svc = make_service(regions=("us-east", "eu-west"))
    c_us = svc.connect_sync("s1", region="us-east")
    c_eu = svc.connect_sync("s2", region="eu-west")
    c_us.create("/g", b"payload")
    data, _ = c_eu.get_data("/g")
    assert data == b"payload"
    c_eu.set_data("/g", b"v2")
    data, _ = c_us.get_data("/g")
    assert data == b"v2"


def test_session_close_removes_ephemerals():
    cloud, svc = make_service()
    c1 = svc.connect_sync("s1")
    c2 = svc.connect_sync("s2")
    c1.create("/tmp1", b"", ephemeral=True)
    c1.create("/perm", b"")
    c1.close()
    cloud.run()
    assert c2.exists("/tmp1") is None
    assert c2.exists("/perm") is not None


def test_pipelined_writes_fifo():
    cloud, svc = make_service()
    c = svc.connect_sync("s1")
    c.create("/pipe", b"")

    def script(client):
        rids = []
        for i in range(8):
            rid = yield from client.submit_set_data("/pipe", str(i).encode())
            rids.append(rid)
        txids = []
        for rid in rids:
            res = yield from client.wait_result(rid)
            txids.append(res["txid"])
        return txids

    txids = cloud.run_task(script(c.client))
    assert txids == sorted(txids), "session FIFO order violated"
    data, _ = c.get_data("/pipe")
    assert data == b"7"
