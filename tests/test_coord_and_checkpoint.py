"""coord/ + checkpoint/ integration: membership eviction, transactional
manifests (torn-checkpoint recovery), stragglers, serving FIFO, end-to-end
crash/restart through the training driver."""

from __future__ import annotations


import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.coord import (CoordinatedManifest, MembershipService, ServingFrontend,
                         StragglerDetector)
from repro.coord.serving_front import InferenceRequest
from tests.conftest import make_service


def test_membership_join_leave_evict():
    cloud, svc = make_service()
    mem = MembershipService(svc)
    h = [mem.join(f"w{i}") for i in range(3)]
    assert sorted(mem.members()) == ["w0", "w1", "w2"]
    mem.leave(h[0])
    assert sorted(mem.members()) == ["w1", "w2"]
    mem.members(watch=True)
    mem.fail(h[1])
    svc.start_heartbeat(period=5.0, max_runs=3)
    cloud.run()
    assert mem.members() == ["w2"]


def test_membership_takeover_after_crash_restart():
    """``join``'s stale-ephemeral branch: a worker that crashes and restarts
    *before* the heartbeat evicted its old session finds its own znode still
    there — it must take it over (delete + recreate under the new session),
    and the subsequent eviction of the dead session must not remove the new
    incarnation's ephemeral."""
    cloud, svc = make_service()
    mem = MembershipService(svc)
    h_old = mem.join("w0")
    mem.fail(h_old)                   # crash; no heartbeat has run yet
    h_new = mem.join("w0")            # restart: stale znode -> takeover
    assert mem.members() == ["w0"]
    svc.start_heartbeat(period=5.0, max_runs=3)
    cloud.run()                       # dead session evicted...
    assert mem.members() == ["w0"], \
        "eviction of the stale session removed the takeover's ephemeral"
    mem.leave(h_new)
    assert mem.members() == []


def test_membership_double_join():
    """Two live joins under the same worker id: takeover is not crash-only —
    the latest session owns the znode.  Deletes are by *path* (ZooKeeper
    semantics, and what the takeover branch itself relies on), so a leave
    through the superseded handle still removes the znode; the second leave
    is then an idempotent no-op."""
    cloud, svc = make_service()
    mem = MembershipService(svc)
    h1 = mem.join("w0")
    h2 = mem.join("w0")
    assert mem.members() == ["w0"]
    mem.leave(h1)                     # stale handle, same path
    assert mem.members() == []
    mem.leave(h2)                     # NoNodeError swallowed
    assert mem.members() == []


def test_membership_eviction_vs_rejoin_race():
    """Heartbeat sweep already queued when the restart takes over: the sweep
    evicts the failed session, but the znode it would have removed belongs
    to the new incarnation by then — the rejoined worker must survive."""
    cloud, svc = make_service()
    mem = MembershipService(svc)
    h_old = mem.join("w0")
    mem.join("w1")
    mem.fail(h_old)
    svc.start_heartbeat(period=5.0, max_runs=2)   # sweep queued...
    h_new = mem.join("w0")                        # ...takeover lands first
    cloud.run()
    assert sorted(mem.members()) == ["w0", "w1"]
    mem.leave(h_new)
    assert mem.members() == ["w1"]


def test_mesh_generation_single_system_image():
    cloud, svc = make_service()
    mem = MembershipService(svc)
    for i in range(4):
        mem.join(f"w{i}")
    g1 = mem.propose_mesh(4, model_parallel=2)
    g2 = mem.propose_mesh(4, model_parallel=4)
    assert g2["generation"] == g1["generation"] + 1
    assert mem.current_mesh()["mesh"] == [1, 4]


def test_checkpoint_manifest_atomicity(tmp_path):
    """A crash after the bulk write but before the manifest commit leaves the
    previous checkpoint authoritative — restore never sees the torn one."""
    cloud, svc = make_service()
    manifest = CoordinatedManifest(svc)
    store = CheckpointStore(str(tmp_path), committer=manifest.commit,
                           latest_resolver=manifest.latest)
    tree = {"w": jnp.arange(8.0)}
    store.save(1, tree)
    assert manifest.latest() == 1

    # simulate the crash: bulk files written, manifest commit never runs
    from repro.checkpoint.store import save_pytree

    save_pytree({"w": jnp.arange(8.0) * 99}, store.step_dir(2))
    restored, step = store.restore({"w": jnp.zeros(8)})
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8.0))


def test_checkpoint_async_and_history(tmp_path):
    cloud, svc = make_service()
    manifest = CoordinatedManifest(svc)
    store = CheckpointStore(str(tmp_path), committer=manifest.commit,
                           latest_resolver=manifest.latest)
    for s in (10, 20, 30):
        store.save_async(s, {"w": jnp.full((4,), float(s))})
    store.wait()
    assert manifest.latest() == 30
    assert manifest.history() == ["step_00000010", "step_00000020", "step_00000030"]
    restored, step = store.restore({"w": jnp.zeros(4)}, step=20)
    assert float(restored["w"][0]) == 20.0


def test_straggler_detection():
    cloud, svc = make_service()
    det = StragglerDetector(svc, lag_threshold=2)
    for w, s in [("a", 10), ("b", 9), ("c", 3)]:
        det.report(w, s)
    rep = det.scan()
    assert rep.lagging == ["c"]
    det.report("c", 10)  # caught up
    assert det.scan().lagging == []


def test_serving_front_fifo_and_batching():
    cloud, svc = make_service()
    served = []

    def model_fn(prompts):
        served.append(len(prompts))
        return [p * 2 for p in prompts]

    fe = ServingFrontend(cloud, model_fn, batch_size=4)

    def driver(sess, n):
        for i in range(n):
            yield from fe.submit(InferenceRequest(sess, f"{sess}:{i}", i))
        return None

    for s in ("s0", "s1"):
        cloud.spawn(driver(s, 6), name=s)
    cloud.run()
    for s in ("s0", "s1"):
        assert fe.completions[s] == [f"{s}:{i}" for i in range(6)]
        assert fe.results[s] == [2 * i for i in range(6)]
    assert max(served) > 1  # batching happened


def test_training_driver_crash_and_resume(tmp_path):
    """launch.train end to end: run, crash, restart with --resume, finish."""
    from repro.launch.train import run_training

    out1 = run_training("starcoder2-3b", steps=12, smoke=True,
                        ckpt_dir=str(tmp_path), ckpt_every=4,
                        simulate_failure=9, seq_len=32, global_batch=4)
    assert out1.get("crashed_at") == 9
    out2 = run_training("starcoder2-3b", steps=12, smoke=True,
                        ckpt_dir=str(tmp_path), resume=True,
                        seq_len=32, global_batch=4)
    assert out2["final_loss"] is not None
    # last committed manifest was step 8 (ckpt_every=4, crash at 9): the
    # restart must resume there, not from scratch
    assert len(out2["losses"]) == 4
