"""Per-architecture smoke tests: reduced configs of every assigned arch run
one forward + one train step on CPU, asserting shapes and finiteness; decode
parity is asserted per family (the full configs are exercised only through
the dry-run)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.train import AdamWConfig, make_train_step
from repro.train.step import TrainStepConfig, init_train_state

ARCHS = configs.list_archs()


def _smoke_batch(cfg, B=2, S=16, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encdec.n_frames, cfg.encdec.frame_dim or cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vlm.n_patches, cfg.vlm.patch_dim or cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = configs.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _smoke_batch(cfg)
    logits = jax.jit(model.apply)(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), f"{arch}: NaNs in fwd"

    step_cfg = TrainStepConfig()
    state = init_train_state(model, params, step_cfg)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, total_steps=10), step_cfg))
    params2, state2, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: NaN loss"
    assert float(metrics["grad_norm"]) > 0, f"{arch}: zero grads"
    # params must actually change
    delta = jax.tree_util.tree_reduce(
        lambda a, b: a + float(jnp.abs(b).sum()),
        jax.tree_util.tree_map(lambda x, y: x.astype(jnp.float32) - y.astype(jnp.float32),
                               params, params2), 0.0)
    assert delta > 0, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_decode_step(arch):
    cfg = configs.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, ctx = 2, 12
    cache = model.init_cache(B, ctx)
    tok = jnp.asarray(np.random.default_rng(1).integers(0, cfg.vocab, (B, 1)), jnp.int32)
    logits, cache2 = jax.jit(model.decode_step)(params, cache, tok)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(cache2["length"]) == 1


@pytest.mark.parametrize("arch", ["qwen3-14b", "starcoder2-3b", "mamba2-1.3b",
                                  "recurrentgemma-2b", "moonshot-v1-16b-a3b"])
def test_decode_matches_full_forward(arch):
    """Step-by-step decode reproduces the training forward logits."""
    cfg = configs.get(arch).reduced()
    if cfg.moe is not None:  # avoid capacity drops in the parity check
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(np.random.default_rng(2).integers(0, cfg.vocab, (2, 10)), jnp.int32)
    full = model.apply(params, {"tokens": toks})
    cache = model.init_cache(2, 10)
    outs = []
    step = jax.jit(model.decode_step)
    for i in range(10):
        lg, cache = step(params, cache, toks[:, i:i + 1])
        outs.append(lg[:, 0])
    stepped = jnp.stack(outs, 1)
    # tolerance: bf16 eps at logit magnitudes ~10 is ~0.08; the append-
    # attention decode (write-only cache, §Perf cell 3) adds one extra bf16
    # rounding where the old-cache and new-token outputs combine.
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(stepped, np.float32), atol=8e-2, rtol=8e-2)


@pytest.mark.parametrize("arch", ["qwen3-14b", "recurrentgemma-2b", "mamba2-1.3b"])
def test_prefill_matches_forward(arch):
    cfg = configs.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(np.random.default_rng(3).integers(0, cfg.vocab, (2, 10)), jnp.int32)
    full = model.apply(params, {"tokens": toks})
    pre, cache = jax.jit(model.prefill)(params, toks)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(pre, np.float32), atol=5e-2, rtol=5e-2)


def test_param_counts_match_known_sizes():
    """Config fidelity: derived parameter counts land on the published sizes."""
    expect = {
        "qwen3-14b": (14.8e9, 0.08), "qwen1.5-110b": (111e9, 0.05),
        "starcoder2-3b": (3.0e9, 0.15), "mamba2-1.3b": (1.3e9, 0.2),
        "qwen3-moe-235b-a22b": (235e9, 0.05), "minicpm-2b": (2.4e9, 0.2),
        "recurrentgemma-2b": (2.7e9, 0.15), "whisper-base": (74e6, 0.25),
        "internvl2-2b": (1.8e9, 0.25),
    }
    for arch, (target, tol) in expect.items():
        n = configs.get(arch).param_count()
        assert abs(n - target) / target < tol, f"{arch}: {n/1e9:.2f}B vs {target/1e9:.2f}B"
    active = configs.get("qwen3-moe-235b-a22b").param_count(active_only=True)
    assert abs(active - 22e9) / 22e9 < 0.1  # the A22B in the name


def test_vocab_padding_masked():
    cfg = configs.get("whisper-base").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _smoke_batch(cfg)
    logits = model.apply(params, batch)
    assert cfg.padded_vocab % 256 == 0
    if cfg.padded_vocab > cfg.vocab:
        pad = logits[..., cfg.vocab:]
        assert float(pad.max()) < -1e29, "padded vocab columns must be masked"
