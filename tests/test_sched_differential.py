"""Randomized scheduler differential harness.

Seeded random event sequences — admit (fresh / multi-turn extension /
cross-session shared prefix), force-preempt, park, unpark, restore, TTL
expiry — drive the full ``DecodeScheduler`` stack (paged pool + chunked
prefill + offload + refcounted prefix sharing + session parking) across
3–5 sessions, and every completed request is asserted **token-for-token
equal** to the eviction-free solo reference, with the allocator / refcount /
reservation invariants audited after every step (``DecodeScheduler.audit``).

Tier-1 runs a fixed seed set (dense gets the widest sweep; moe and hybrid
pin the family-specific paths).  CI additionally runs a non-blocking
randomized sweep (``SCHED_DIFF_SWEEP`` = base seed); any failing sequence's
event log is dumped to ``artifacts/diff_failures/`` so the exact trace rides
the CI artifact.

A hypothesis property (import-guarded like the kernel properties) pins the
alloc/share/CoW/release round trip on the allocator alone.
"""

from __future__ import annotations

import json
import os
import zlib
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.dist  # noqa: F401  (installs the AbstractMesh compat shim)
from repro import configs
from repro.models import build_model, kvcache
from repro.serve.engine import make_decode_step, make_prefill
from repro.serve.lifecycle import SlotState
from repro.serve.scheduler import DecodeScheduler

MAX_SEQ = 32
PAGE_SIZE = 4
N_SLOTS = 3
PREFILL_CHUNK = 3
MAX_NEW = (2, 4)                  # per-request decode budget range
FRESH_LEN = (5, 12)               # fresh prompt length range
EXTEND_LEN = (1, 4)               # extra user tokens per multi-turn turn
N_EVENTS = 28

# tier-1 seed matrix: >= 25 sequences total, dense widest
TIER1_SEEDS = ([("minicpm-2b", s) for s in range(15)]
               + [("moonshot-v1-16b-a3b", s) for s in range(5)]
               + [("recurrentgemma-2b", s) for s in range(5)])

# tier-1 speculative matrix: >= 25 sequences, all archetypes, same event
# soup (forced preempts, parking, prefix sharing) with draft-and-verify on.
# ``spec`` = (draft arch, draft init seed, k): draft seed 0 is the target's
# own params (self-draft, high acceptance — exercises the accept fast path);
# a different seed or arch is a disagreeing draft (low acceptance — hammers
# the rejection / length-rewind / hybrid-rollback path every round).
TIER1_SPEC_SEEDS = (
    [("minicpm-2b", ("minicpm-2b", 0, 3), s) for s in range(8)]
    + [("minicpm-2b", ("minicpm-2b", 7, 2), s) for s in range(4)]
    + [("moonshot-v1-16b-a3b", ("moonshot-v1-16b-a3b", 0, 3), s) for s in range(4)]
    + [("moonshot-v1-16b-a3b", ("minicpm-2b", 7, 2), s) for s in range(2)]
    + [("recurrentgemma-2b", ("minicpm-2b", 0, 3), s) for s in range(7)])

FAILURE_DIR = Path("artifacts/diff_failures")

_ARCH_CACHE = {}


class SoloRef:
    """Eviction-free solo greedy reference with jit reuse across prompts:
    one decode step (fixed MAX_SEQ cache shape) and one prefill per distinct
    prompt length, so 25 sequences don't recompile per request.

    ``mesh`` builds a *mesh-matched* reference: params placed through the
    storage registry and the steps policy-bound, so the reference's
    model-axis partitioning (and therefore its bf16 reduction order) is the
    same as the sharded scheduler's.  The sharded moe/hybrid differential
    rows need this — see the sharded section's comment.
    """

    def __init__(self, model, params, mesh=None):
        self.model, self.params = model, params
        self._policy = None
        if mesh is not None:
            from repro.dist import sharding as shd

            msize = shd.MeshRules.for_mesh(mesh).model_size(mesh)
            n_kv = getattr(model.cfg, "n_kv_heads", 0) or model.cfg.n_heads
            self._policy = shd.ShardingPolicy.default(
                mesh, batch_shardable=False,
                attn_mode="head" if n_kv % msize == 0 else "seq",
                decode_stationary=True)
            self.params = jax.device_put(
                params, shd.param_shardings(params, mesh))
        self._decode = jax.jit(make_decode_step(model, policy=self._policy))
        self._prefills = {}
        self._memo = {}

    def run(self, prompt, max_new: int, session: str = "ref") -> np.ndarray:
        # stateless across requests — the session tag only matters for the
        # session-mirroring SchedRef
        key = (np.asarray(prompt, np.int32).tobytes(), max_new)
        if key in self._memo:
            return self._memo[key]
        P = len(prompt)
        pre = self._prefills.get(P)
        if pre is None:
            pre = self._prefills[P] = jax.jit(
                make_prefill(self.model, seq_len=MAX_SEQ,
                             policy=self._policy))
        tok, cache = pre(self.params, jnp.asarray(prompt, jnp.int32)[None])
        out = [int(tok[0])]
        for _ in range(max_new - 1):
            tok, _, cache = self._decode(self.params, cache, tok[:, None])
            out.append(int(tok[0]))
        self._memo[key] = np.asarray(out, np.int32)
        return self._memo[key]


class SchedRef:
    """Eviction-free reference run through a *second scheduler* on the same
    mesh: same jitted step set, same batch/pool shapes and shardings — one
    request at a time, ample pool, no offload/forced preempts/sharing/spec.
    What it isolates is exactly the differential claim: the event soup's
    machinery (preemption, restore, forced parking, CoW, chunked admission
    interleaving, batched draft catch-up, verify rounds) must be
    token-invisible relative to an unstressed run of the *same* sharded
    step set.

    Sessions are mirrored (``park_sessions=True``, no TTL): a multi-turn
    extend in the stressed run reuses its history's decode-written KV, and
    on the mesh decode-written KV is *not* bitwise equal to chunk-prefilled
    KV (the projection gemm's bf16 reduction order depends on dispatch
    shape), so the reference must take the same parked-extend path to
    byte-compare like against like."""

    def __init__(self, model, params, *, mesh, n_slots, attn_backend):
        self._sched = DecodeScheduler(
            model, params, n_slots=n_slots, max_seq=MAX_SEQ,
            page_size=PAGE_SIZE, prefill_chunk=PREFILL_CHUNK,
            park_sessions=True, mesh=mesh, attn_backend=attn_backend)
        self._rid = 0

    def reset(self):
        self._sched.reset()

    def run(self, prompt, max_new: int, session: str = "ref") -> np.ndarray:
        s = self._sched
        self._rid += 1
        s.submit(session, f"ref{self._rid}", np.asarray(prompt, np.int32),
                 max_new)
        for _ in range(10_000):
            fins = s.step()
            if fins:
                return np.asarray(fins[0].tokens)
        raise AssertionError("reference scheduler failed to complete")


def _arch(name, spec=None, sched_kw=None, cache_key=None, ref_mesh=None,
          ref_kind="solo"):
    """Build (or fetch) the scheduler + solo reference for ``name``.

    ``spec=(draft_arch, draft_seed, k)`` turns on draft-and-verify
    speculative decoding; ``draft_seed == 0`` with ``draft_arch == name``
    reuses the target's own params (self-draft).  The solo reference is
    always non-speculative — that IS the parity claim.  ``sched_kw``
    overrides scheduler constructor kwargs (the sharded subset passes
    ``mesh=``/``n_slots=``); ``cache_key`` keys the memo for such variants;
    ``ref_mesh`` builds the solo reference mesh-matched instead of
    single-device; ``ref_kind="sched"`` swaps the solo reference for a
    :class:`SchedRef` (an unstressed second scheduler on the same mesh).
    """
    key = (name, spec, cache_key)
    if key not in _ARCH_CACHE:
        cfg = configs.get(name).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        kw = {}
        if spec is not None:
            draft_arch, draft_seed, k = spec
            if draft_arch == name and draft_seed == 0:
                draft_model, draft_params = model, params
            else:
                draft_model = build_model(configs.get(draft_arch).reduced())
                draft_params = draft_model.init(jax.random.key(draft_seed))
            kw = dict(draft_model=draft_model, draft_params=draft_params,
                      spec_k=k)
        kw.update(sched_kw or {})
        kw.setdefault("n_slots", N_SLOTS)
        sched = DecodeScheduler(model, params,
                                max_seq=MAX_SEQ, page_size=PAGE_SIZE,
                                prefill_chunk=PREFILL_CHUNK, offload=True,
                                prefix_sharing=True, park_sessions=True, **kw)
        if ref_kind == "sched":
            skw = sched_kw or {}
            ref = SchedRef(model, params, mesh=skw["mesh"],
                           n_slots=skw.get("n_slots", N_SLOTS),
                           attn_backend=skw.get("attn_backend", "gather"))
        else:
            ref = SoloRef(model, params, mesh=ref_mesh)
        _ARCH_CACHE[key] = (cfg, sched, ref)
    return _ARCH_CACHE[key]


def _run_sequence(arch: str, seed: int, log: Optional[list] = None,
                  spec=None, sched_kw=None, cache_key=None,
                  ref_mesh=None, ref_kind="solo") -> list:
    """One seeded event sequence; appends every event to ``log`` (so a
    caller-owned list survives an assertion failure) and raises on any
    parity or invariant violation."""
    cfg, sched, ref = _arch(arch, spec, sched_kw, cache_key, ref_mesh,
                            ref_kind)
    sched.reset()
    if hasattr(ref, "reset"):
        ref.reset()               # SchedRef carries per-session KV state
    # zlib.crc32, not hash(): str hashing is salted per process, and a
    # failing (arch, seed) must replay bit-identically from the artifact
    tag = arch if spec is None else f"{arch}+{spec[0]}:{spec[1]}:{spec[2]}"
    rng = np.random.default_rng(zlib.crc32(tag.encode()) * 100003 + seed)
    sched.park_ttl_steps = int(rng.choice([0, 0, 18]))
    sessions = [f"s{i}" for i in range(int(rng.integers(3, 6)))]
    history = {s: None for s in sessions}     # completed conversation so far
    inflight = {}                             # session -> (rid, prompt, max_new)
    shared_sys = rng.integers(0, cfg.vocab, size=2 * PAGE_SIZE).astype(np.int32)
    log = log if log is not None else []
    log.append({"arch": arch, "seed": seed, "ttl": sched.park_ttl_steps,
                "sessions": len(sessions), "spec": spec})
    rid = 0

    def submit(sess):
        nonlocal rid
        h = history[sess]
        roll = rng.random()
        if h is not None and roll < 0.6 and len(h) + 8 <= MAX_SEQ:
            # multi-turn: extend this session's parked conversation
            prompt = np.concatenate(
                [h, rng.integers(0, cfg.vocab,
                                 int(rng.integers(*EXTEND_LEN))).astype(np.int32)])
            kind = "extend"
        elif roll < 0.8:
            # shared system prompt across sessions (prefix-index food)
            prompt = np.concatenate(
                [shared_sys, rng.integers(0, cfg.vocab,
                                          int(rng.integers(*FRESH_LEN))).astype(np.int32)])
            kind = "shared"
        else:
            prompt = rng.integers(0, cfg.vocab,
                                  int(rng.integers(*FRESH_LEN))).astype(np.int32)
            kind = "fresh"
        max_new = int(rng.integers(MAX_NEW[0], MAX_NEW[1] + 1))
        max_new = min(max_new, MAX_SEQ - len(prompt))   # full-ring room
        if max_new < 1:
            history[sess] = None              # conversation too long: restart
            return
        name = f"r{rid}"
        rid += 1
        sched.submit(sess, name, prompt, max_new)
        inflight[sess] = (name, prompt, max_new)
        log.append({"ev": "submit", "session": sess, "rid": name,
                    "kind": kind, "prompt": prompt.tolist(),
                    "max_new": max_new})

    def on_finished(fins):
        for fin in fins:
            name, prompt, max_new = inflight.pop(fin.session)
            assert fin.request_id == name, "per-session FIFO violated"
            expect = ref.run(prompt, max_new, session=fin.session)
            got = np.asarray(fin.tokens)
            log.append({"ev": "complete", "rid": name,
                        "tokens": got.tolist()})
            np.testing.assert_array_equal(
                got, expect,
                err_msg=f"{arch} seed {seed} {name}: scheduler diverged "
                        f"from the eviction-free solo reference")
            history[fin.session] = np.concatenate(
                [prompt, got.astype(np.int32)])

    for _ev in range(N_EVENTS):
        for sess in sessions:
            if sess not in inflight and rng.random() < 0.35:
                submit(sess)
        if rng.random() < 0.12:
            victims = [s for s in sched.slots
                       if s.state is SlotState.ACTIVE and s.pages]
            if victims:
                v = victims[int(rng.integers(len(victims)))]
                log.append({"ev": "preempt", "slot": v.index})
                sched.preempt(v.index)
        fins = sched.step()
        sched.audit()
        on_finished(fins)
    while sched.busy():
        on_finished(sched.step())
        sched.audit()
        log.append({"ev": "drain-step"})
        assert len(log) < 4000, "failed to drain"
    # quiescent state: only parked journals and the index may hold pages
    a = sched.allocator
    held = (sum(len(r.pages) for r in sched._parked.values())
            + len(sched.prefix_index))
    assert a.total_refs == held, f"leaked references: {a.total_refs} != {held}"
    return log


def _run_and_dump(arch: str, seed: int, spec=None, sched_kw=None,
                  cache_key=None, ref_mesh=None, ref_kind="solo") -> None:
    log: list = []
    try:
        _run_sequence(arch, seed, log, spec=spec, sched_kw=sched_kw,
                      cache_key=cache_key, ref_mesh=ref_mesh,
                      ref_kind=ref_kind)
    except Exception as e:
        # the sequence is a pure function of (arch, seed, spec): the artifact
        # carries both the replay recipe and the event trace up to the
        # failure, and CI uploads the directory on failure
        FAILURE_DIR.mkdir(parents=True, exist_ok=True)
        tag = "" if spec is None else f"_spec_{spec[0]}_{spec[1]}_{spec[2]}"
        if cache_key is not None:
            tag += "_" + "_".join(str(p) for p in cache_key)
        path = FAILURE_DIR / f"seq_{arch}{tag}_{seed}.json"
        path.write_text(json.dumps(
            {"arch": arch, "seed": seed, "spec": spec,
             "error": str(e)[:2000],
             "repro": f"_run_sequence({arch!r}, {seed}, spec={spec!r})",
             "events": log},
            indent=2))
        raise


@pytest.mark.parametrize("arch,seed", TIER1_SEEDS,
                         ids=[f"{a}-{s}" for a, s in TIER1_SEEDS])
def test_sched_differential(arch, seed):
    _run_and_dump(arch, seed)


@pytest.mark.parametrize(
    "arch,spec,seed", TIER1_SPEC_SEEDS,
    ids=[f"{a}-draft_{sp[0]}_{sp[1]}_k{sp[2]}-{s}"
         for a, sp, s in TIER1_SPEC_SEEDS])
def test_sched_differential_spec(arch, spec, seed):
    """Same event soup as :func:`test_sched_differential` — multi-turn
    parking, cross-session shared prefixes, forced preempts, TTL expiry —
    with draft-and-verify speculative decoding on, asserted token-for-token
    equal to the *non-speculative* solo reference and audited every step.
    Self-draft rows pin the accept fast path; disagreeing-draft rows reject
    nearly every proposal and so hammer the length-rewind (and, for the
    hybrid, the recurrent-row rollback + replay) machinery."""
    _run_and_dump(arch, seed, spec=spec)


SWEEP_BASE = os.environ.get("SCHED_DIFF_SWEEP")


@pytest.mark.skipif(SWEEP_BASE is None,
                    reason="randomized sweep runs in the non-blocking CI job "
                           "(set SCHED_DIFF_SWEEP=<base seed>)")
@pytest.mark.parametrize("k", range(8))
def test_sched_differential_sweep(k):
    base = int(SWEEP_BASE) % 1_000_000
    for arch in ("minicpm-2b", "moonshot-v1-16b-a3b", "recurrentgemma-2b"):
        _run_and_dump(arch, 1000 + base + k)


# ---------------------------------------------------------------------------
# Multi-device sharded parity (8-device host mesh)
# ---------------------------------------------------------------------------
#
# The CI multi-device job runs these under
# ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; without 8 devices
# they skip (tier-1 covers the path through test_system's subprocess smoke
# instead).  Mesh (2, 4): slots shard on ``data`` (n_slots=4), heads / pool
# lanes on ``model`` — PAGE_SIZE=4 divides model=4, so the paged_kernel rows
# take the shard_map *lane* decomposition of the fused gather.  The event
# soup is the same as above: forced preempts, parking, prefix sharing, spec
# rounds.
#
# Reference choice per family (``ref``):
#
# * ``solo`` — the unmodified single-device reference: the strict 1-device
#   == 8-device token-for-token claim.  Dense holds it (measured ~7e-4
#   bf16 logit drift from cross-shard reduction order, far inside its
#   argmax margins) — including the spec rows and the shard_map lane rows.
# * ``sched`` — a :class:`SchedRef`: the same sharded scheduler, same mesh
#   and backend, run eviction-free one request at a time.  MoE and hybrid
#   need a mesh-matched reference: bf16 cross-shard reduction order shifts
#   the router's top-k on near-tied gates (moe) and feeds back through the
#   recurrence (hybrid), so their 1-vs-8 logits diverge wholesale
#   (~0.1-0.3 at ~0.8 logit scale; exact in fp32, which pins it as
#   reassociation, not a bug).  A solo reference *on the mesh* is still not
#   numerically matched — the batched dp-sharded step and the paged pool's
#   lane layout reassociate differently than a B=1 ring — so the reference
#   goes through the scheduler's own step set, and the differential claim
#   becomes: every scheduler *mechanism* (paging, chunked prefill,
#   preempt/restore, parking, CoW, batched catch-up, verify) is
#   token-invisible on the mesh, bitwise.

N_SLOTS_SHARDED = 4          # divides dp=2 (mesh (2, 4))

SHARDED_SEEDS = [
    ("minicpm-2b", "gather", "solo", 0),
    ("minicpm-2b", "paged_kernel", "solo", 0),
    ("minicpm-2b", "paged_kernel", "solo", 3),
    ("moonshot-v1-16b-a3b", "gather", "sched", 0),
    ("moonshot-v1-16b-a3b", "paged_kernel", "sched", 1),
    ("recurrentgemma-2b", "gather", "sched", 1),
]
# Spec on the mesh: dense rows hold the strict solo claim; moe rows pin the
# rewind machinery (disagreeing draft) and the accept fast path (self-draft)
# against the mesh-matched scheduler reference.  There is NO hybrid spec row
# here, deliberately: the verify chunk scores S = k + 1 tokens per dispatch
# while the non-speculative reference consumes them one S=1 step at a time,
# and on the mesh those two dispatch shapes reassociate bf16 differently —
# the hybrid's recurrence feeds that sub-ulp drift back on itself (and its
# rollback+replay path re-runs accepted spans at yet another chunk shape),
# flipping 1-2 argmaxes per sequence on every seed scanned.  Hybrid spec is
# pinned bitwise single-device (TIER1_SPEC_SEEDS), and hybrid-on-mesh by its
# non-spec row above.
SHARDED_SPEC_SEEDS = [
    ("minicpm-2b", ("minicpm-2b", 0, 3), "solo", 0),
    ("minicpm-2b", ("minicpm-2b", 7, 2), "solo", 1),
    ("moonshot-v1-16b-a3b", ("minicpm-2b", 0, 3), "sched", 0),
    ("moonshot-v1-16b-a3b", ("moonshot-v1-16b-a3b", 0, 3), "sched", 2),
]

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="sharded parity needs an 8-device mesh "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _host_mesh():
    return jax.make_mesh((2, 4), ("data", "model"))


@needs_mesh
@pytest.mark.parametrize(
    "arch,backend,ref,seed", SHARDED_SEEDS,
    ids=[f"{a}-{b}-{r}-{s}" for a, b, r, s in SHARDED_SEEDS])
def test_sched_differential_sharded(arch, backend, ref, seed):
    mesh = _host_mesh()
    sched_kw = dict(mesh=mesh, n_slots=N_SLOTS_SHARDED, attn_backend=backend)
    _run_and_dump(arch, seed, sched_kw=sched_kw,
                  cache_key=("sharded", backend, ref), ref_kind=ref)


@needs_mesh
@pytest.mark.parametrize(
    "arch,spec,ref,seed", SHARDED_SPEC_SEEDS,
    ids=[f"{a}-draft_{sp[0]}_{sp[1]}_k{sp[2]}-{r}-{s}"
         for a, sp, r, s in SHARDED_SPEC_SEEDS])
def test_sched_differential_sharded_spec(arch, spec, ref, seed):
    """Speculative decoding on the mesh: the batched draft catch-up, the
    draft steps and the verify chunk all run policy-bound (spec forces the
    gather backend, so the shard_map pool path is exercised by the non-spec
    rows above).  The reference is always non-speculative."""
    mesh = _host_mesh()
    sched_kw = dict(mesh=mesh, n_slots=N_SLOTS_SHARDED)
    _run_and_dump(arch, seed, spec=spec, sched_kw=sched_kw,
                  cache_key=("sharded", "spec", ref), ref_kind=ref)


# ---------------------------------------------------------------------------
# Hypothesis property: alloc/share/CoW/release round trips on the allocator
# ---------------------------------------------------------------------------

try:  # optional dep, guarded like test_kernel_properties (skip, not error)
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 10_000_000))
    def test_alloc_share_cow_release_property(seed):
        """Random op soup against a shadow refcount model: the allocator's
        ``free + in_use == n_pages`` invariant, per-page refcounts, and the
        total-refs meter all stay exact through alloc / share / release /
        CoW swaps, and releasing every holder returns the pool to fully
        free."""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 12))
        a = kvcache.PageAllocator(n)
        shadow = {}                 # page -> refcount
        holders = []                # one entry per outstanding reference
        for _ in range(60):
            op = rng.choice(["alloc", "share", "release", "cow"])
            if op == "alloc" and a.free_count:
                k = int(rng.integers(1, a.free_count + 1))
                pages = a.alloc(k)
                assert len(set(pages)) == k
                assert not any(p in shadow for p in pages), "page reissued"
                for p in pages:
                    shadow[p] = 1
                    holders.append(p)
            elif op == "share" and shadow:
                p = int(rng.choice(list(shadow)))
                a.share([p])
                shadow[p] += 1
                holders.append(p)
            elif op == "release" and holders:
                p = holders.pop(int(rng.integers(len(holders))))
                a.release([p])
                shadow[p] -= 1
                if not shadow[p]:
                    del shadow[p]
            elif op == "cow" and holders and a.free_count:
                # a writer splits: fresh private page in, old reference out
                old = holders.pop(int(rng.integers(len(holders))))
                new = a.alloc(1)[0]
                shadow[new] = 1
                holders.append(new)
                a.release([old])
                shadow[old] -= 1
                if not shadow[old]:
                    del shadow[old]
            a.check()
            assert a.in_use == len(shadow)
            assert a.total_refs == sum(shadow.values()) == len(holders)
            for p, rc in shadow.items():
                assert a.refcount(p) == rc
        for p in holders:
            a.release([p])
        assert a.free_count == n and a.in_use == 0 and a.total_refs == 0

except ImportError:

    @pytest.mark.skip(reason="optional dep: property sweeps need hypothesis")
    def test_alloc_share_cow_release_property():
        pass
