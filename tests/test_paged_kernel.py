"""Paged-attention decode kernel, wired end to end: the fused Pallas
table-indirect path must be token-for-token identical to the gather
reference through the scheduler (dense / MoE / hybrid, staggered chunked
admissions, CoW-shared rc>1 prefixes), lane-exact at the kvcache helper
level on scrambled and partially-mapped tables, rejected on configurations
it cannot serve, and strictly cheaper than gather in HBM bytes."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

import repro.dist  # noqa: F401  (installs the AbstractMesh compat shim)
from repro.kernels.paged_attention import reference_paged_attention
from repro.models import kvcache
from repro.serve.engine import generate
from repro.serve.scheduler import DecodeScheduler
from test_paged_kvcache import run_all, tiny

# sdpa_append now keeps softmax probs and the value accumulation in fp32
# like the fused kernel does, which shrank the gather-vs-fused attention
# divergence from ~1 ulp of bf16 (the old prob rounding) down to fp32
# summation-order noise.  Dense and hybrid parity is seed-robust after the
# change (each arch previously needed a hand-picked seed where greedy
# argmax had headroom).  The attention *output* still rounds to bf16,
# though, and the MoE router's discreteness can amplify that last bit on
# unlucky prompts — so the MoE seed below still wants headroom, it is just
# no longer knife-edge (most small seeds pass).
PARITY_CASES = [("minicpm-2b", 0), ("moonshot-v1-16b-a3b", 0),
                ("recurrentgemma-2b", 0)]


# ---------------------------------------------------------------------------
# Scheduler-level token parity: fused == gather == solo decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,seed", PARITY_CASES)
def test_paged_kernel_parity_staggered_multichunk(arch, seed):
    """Prompts spanning 1..3 pages, admitted at different steps, prefilled
    in chunks smaller than a page: with ``attn_backend='paged_kernel'``
    every request's tokens must equal both the gather scheduler's and an
    eviction-free solo B=1 decode.  The fused path streams the same pool
    through the page table the gather path materializes, so any divergence
    is a kernel masking/indexing bug."""
    cfg, model, params = tiny(arch)
    page = 8
    lengths = [6, 12, 20]                 # 1, 2 and 3 pages of 8
    N = 4
    max_seq = max(lengths) + N
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, size=L).astype(np.int32)
               for L in lengths]
    ref = {i: np.asarray(generate(model, params, jnp.asarray(p)[None], N,
                                  seq_len=max_seq))[0]
           for i, p in enumerate(prompts)}

    submits = {0: [("a", "r0", prompts[0], N)],
               2: [("b", "r1", prompts[1], N)],
               3: [("c", "r2", prompts[2], N)]}
    kw = dict(n_slots=3, max_seq=max_seq, kv_mode="paged", page_size=page,
              prefill_chunk=5)
    gather = run_all(DecodeScheduler(model, params, **kw), submits)
    fused_sched = DecodeScheduler(model, params, attn_backend="paged_kernel",
                                  **kw)
    fused = run_all(fused_sched, submits)
    assert fused_sched.stats()["attn_backend"] == "paged_kernel"
    assert sorted(gather) == sorted(fused) == [0, 1, 2]
    for i in range(3):
        np.testing.assert_array_equal(
            fused[i], gather[i],
            err_msg=f"{arch} r{i}: paged_kernel != gather scheduler")
        np.testing.assert_array_equal(
            fused[i], ref[i],
            err_msg=f"{arch} r{i}: paged_kernel != solo decode")
    # the gather-mode scheduler must not have been flipped by the fused
    # one's config rebind (they share the model object)
    assert model.cfg.attn_backend == "gather"


def test_paged_kernel_parity_over_shared_cow_prefix():
    """Three requests decode concurrently over the same page-aligned system
    prefix (rc>1 on the shared pages): the fused kernel reads those pages
    through each slot's own table row and must match gather token for
    token — including after the CoW split when a writer lands on a shared
    page."""
    cfg, model, params = tiny()
    ps, N = 8, 4
    rng = np.random.default_rng(7)
    sys_p = rng.integers(0, cfg.vocab, size=2 * ps).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
             for n in (3, 6, 10)]
    prompts = [np.concatenate([sys_p, t]) for t in tails]
    max_seq = max(len(p) for p in prompts) + N
    kw = dict(n_slots=3, max_seq=max_seq, kv_mode="paged", page_size=ps,
              prefill_chunk=5, prefix_sharing=True)

    def drive(**extra):
        sched = DecodeScheduler(model, params, **kw, **extra)
        # phase 1: r0 completes and publishes its full sys pages to the
        # prefix index (one index reference per page)
        got = run_all(sched, {0: [("a", "r0", prompts[0], N)]})
        # phase 2: r1 and r2 admit concurrently over the indexed pages —
        # rc = index + r1 + r2 on the shared prefix while both decode
        sched.submit("b", "r1", prompts[1], N)
        sched.submit("c", "r2", prompts[2], N)
        shared_seen, step = False, 0
        while sched.busy():
            for fin in sched.step():
                got[int(fin.request_id[1:])] = fin.tokens
            a = sched.allocator
            shared_seen |= any(a.refcount(p) > 2 for p in range(a.n_pages))
            step += 1
            assert step < 500
        assert shared_seen, "harness never exercised an rc>1 shared page"
        assert sched.stats()["shared_prefix_tokens"] >= 2 * len(sys_p)
        return got

    fused = drive(attn_backend="paged_kernel")
    gather = drive()
    for i in range(3):
        np.testing.assert_array_equal(
            fused[i], gather[i],
            err_msg=f"r{i}: paged_kernel != gather over shared prefix")


# ---------------------------------------------------------------------------
# kvcache-level: scrambled / partially-mapped tables through the helper
# ---------------------------------------------------------------------------


def _scrambled_layer_cache(rng, *, n_pages, ps, Hkv, D, table):
    shape = (n_pages, ps, Hkv, D)
    return {"kp": jnp.asarray(rng.standard_normal(shape), jnp.float32),
            "vp": jnp.asarray(rng.standard_normal(shape), jnp.float32),
            "page_table": jnp.asarray(table, jnp.int32)}


def test_paged_attn_decode_scrambled_and_holey_table():
    """The model-facing helper on a handcrafted pool: physical pages out of
    logical order, one slot with an unmapped (-1) hole below its length, and
    ragged positions — both call modes must match the gather oracle."""
    Hkv, G, D, ps = 2, 3, 8, 4
    rng = np.random.default_rng(3)
    # slot 0: pages scrambled; slot 1: hole at logical page 1 (its tokens
    # 4..7 were dropped by offload) but still decoding at pos 9
    table = [[5, 2, 7, -1], [1, 6, -1, 3]]
    lc = _scrambled_layer_cache(rng, n_pages=9, ps=ps, Hkv=Hkv, D=D,
                                table=table)
    q = jnp.asarray(rng.standard_normal((2, 1, Hkv * G, D)), jnp.float32)
    pos = jnp.asarray([7, 9], jnp.int32)
    k_new = jnp.asarray(rng.standard_normal((2, 1, Hkv, D)), jnp.float32)
    v_new = jnp.asarray(rng.standard_normal((2, 1, Hkv, D)), jnp.float32)

    for hkw in ({"k_new": k_new, "v_new": v_new}, {"include_new": True}):
        out = kvcache.paged_attn_decode(lc, q, pos, window=None, **hkw)
        rkw = (dict(k_new=k_new, v_new=v_new) if "k_new" in hkw
               else dict(q_pos=pos))
        lengths = pos if "k_new" in hkw else pos + 1
        ref = reference_paged_attention(q, lc["kp"], lc["vp"],
                                        lc["page_table"], lengths, **rkw)
        assert out.shape == q.shape and out.dtype == q.dtype
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-6, rtol=2e-6,
                                   err_msg=f"mode {sorted(hkw)}")
        assert np.isfinite(np.asarray(out)).all()

    # sliding window through the helper trims the same lanes as the oracle
    out = kvcache.paged_attn_decode(lc, q, pos, window=5, k_new=k_new,
                                    v_new=v_new)
    ref = reference_paged_attention(q, lc["kp"], lc["vp"], lc["page_table"],
                                    pos, window=5, k_new=k_new, v_new=v_new)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


# ---------------------------------------------------------------------------
# Configuration validation + stats surface
# ---------------------------------------------------------------------------


def test_paged_kernel_backend_validation():
    cfg, model, params = tiny()
    with pytest.raises(ValueError, match="needs kv_mode='paged'"):
        DecodeScheduler(model, params, n_slots=2, max_seq=16,
                        kv_mode="ring", attn_backend="paged_kernel")
    with pytest.raises(ValueError, match="attn_backend must be"):
        DecodeScheduler(model, params, n_slots=2, max_seq=16,
                        attn_backend="flash")
    _, ssm_model, ssm_params = tiny("mamba2-1.3b")
    with pytest.raises(ValueError, match="SSM decode has no KV pool"):
        DecodeScheduler(ssm_model, ssm_params, n_slots=2, max_seq=16,
                        kv_mode="paged", page_size=4,
                        attn_backend="paged_kernel")
    # default surface unchanged
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=16)
    assert sched.stats()["attn_backend"] == "gather"


# ---------------------------------------------------------------------------
# HBM bytes gate: fused must read strictly less than gather
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-14b", "recurrentgemma-2b"])
def test_paged_decode_cell_fused_reads_fewer_bytes(arch):
    """The roofline cell the bench-smoke gate asserts on: at the same pool
    config the fused table-indirect scan touches only the mapped pages,
    while gather materializes the full per-slot span and re-reads it —
    strictly more traffic, also on hybrids where only the attention layers
    carry a pool."""
    roofline = pytest.importorskip(
        "benchmarks.roofline",
        reason="benchmarks package needs the repo root on sys.path")
    cell = roofline.paged_decode_cell(arch, n_slots=4, page_size=8,
                                      max_pages=16, fill=0.5)
    assert cell["status"] == "OK"
    assert cell["fused_hbm_bytes"] < cell["gather_hbm_bytes"], cell
    assert cell["fused_lt_gather"] and cell["bytes_ratio"] > 1.0
    assert cell["mapped_pages"] * 8 >= cell["live_tokens"]
