"""Property-based validation of the FaaSKeeper consistency model
(paper Appendix B) under adversarial schedules and injected crashes.

Each hypothesis example builds a random multi-session workload over a small
znode universe, optionally crashes the writer/distributor at random crash
points (the queue's at-least-once redelivery must mask it), runs the
simulation to quiescence, and asserts:

  A  Atomicity / exactly-once — replaying the acked writes in txid order
     reproduces the final user-store state exactly; txids are unique.
  L  Linearized writes — per-session ack order == txid order == submission
     order (FIFO).
  S  Single system image — every region converges to identical content, and
     no client ever observes a version regression on a node.
  N  Ordered notifications — a client never reads data of txn v before
     receiving the notification of a watch it registered that was triggered
     by u <= v.
"""

from __future__ import annotations

from typing import Dict, List

import pytest

pytest.importorskip("hypothesis", reason="optional dep: property sweeps need hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import FaultPlan, NoNodeError, NodeExistsError, BadVersionError
from repro.core.znode import NotEmptyError, FKError
from tests.conftest import make_service

PATHS = ["/a", "/b", "/a/x", "/a/y"]
SESSIONS = ["s0", "s1", "s2"]

op_strategy = st.tuples(
    st.sampled_from(SESSIONS),
    st.sampled_from(["create", "set", "delete", "read", "read_watch"]),
    st.sampled_from(PATHS),
    st.integers(0, 255),
)

crash_strategy = st.lists(
    st.tuples(
        st.sampled_from(["writer", "distributor"]),
        st.sampled_from([
            "after_parent_lock", "after_lock", "after_validate", "after_push",
            "after_commit", "after_getnode", "after_trycommit",
            "after_dataupdate", "after_epoch_add", "after_invoke",
            "after_notify", "after_pop",
        ]),
        st.integers(0, 5),
    ),
    max_size=3, unique_by=lambda c: (c[0], c[1]),
)


def _run_workload(ops, crashes, seed, regions=("r0", "r1")):
    faults = FaultPlan(crashes={(f, p): occ for f, p, occ in crashes})
    cloud, svc = make_service(seed=seed, faults=faults, regions=regions)
    clients = {s: svc.connect_sync(s) for s in SESSIONS}
    log = {
        "acks": [],          # (session, op, path, txid, submit_idx)
        "reads": [],         # (session, path, modified_txid, t_complete)
        "watch_dev": [],     # (session, path, txid, t_delivered)
        "watch_reg": [],     # (session, path, t_registered)
    }
    for s, c in clients.items():
        c.client.inbox.on_event = _wrap_on_event(c.client, s, cloud, log)

    def driver(s, my_ops):
        client = clients[s].client
        for idx, (op, path, val) in enumerate(my_ops):
            try:
                if op == "create":
                    yield from client.create(path, bytes([val]))
                    log["acks"].append((s, op, path, client.state.mrd, idx))
                elif op == "set":
                    yield from client.set_data(path, bytes([val]))
                    log["acks"].append((s, op, path, client.state.mrd, idx))
                elif op == "delete":
                    yield from client.delete(path)
                    log["acks"].append((s, op, path, client.state.mrd, idx))
                elif op in ("read", "read_watch"):
                    if op == "read_watch":
                        log["watch_reg"].append((s, path, cloud.now))
                    data, stat = yield from client.get_data(
                        path, watch=(op == "read_watch"))
                    log["reads"].append((s, path, stat.modified_txid, cloud.now))
            except (NoNodeError, NodeExistsError, BadVersionError,
                    NotEmptyError, FKError):
                pass
        return None

    per_session: Dict[str, List] = {s: [] for s in SESSIONS}
    for s, op, path, val in ops:
        per_session[s].append((op, path, val))
    for s, my_ops in per_session.items():
        cloud.spawn(driver(s, my_ops), name=f"driver:{s}")
    cloud.run(max_events=400_000)
    return cloud, svc, clients, log


def _wrap_on_event(client, session, cloud, log):
    base = client._on_event

    def hook(payload):
        if payload.get("kind") == "watch":
            log["watch_dev"].append(
                (session, payload.get("path"), payload.get("txid"), cloud.now))
        base(payload)

    return hook


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
@given(ops=st.lists(op_strategy, min_size=4, max_size=18),
       crashes=crash_strategy, seed=st.integers(0, 2**16))
def test_consistency_model(ops, crashes, seed):
    cloud, svc, clients, log = _run_workload(ops, crashes, seed)

    # -- A: atomicity / exactly-once ------------------------------------------
    txids = [t for (_, _, _, t, _) in log["acks"]]
    assert len(txids) == len(set(txids)), "txid assigned twice (double commit)"

    # -- L: linearized writes (per-session FIFO) --------------------------------
    per_session: Dict[str, List[int]] = {}
    for s, _, _, txid, _idx in log["acks"]:
        per_session.setdefault(s, []).append(txid)
    for s, seq in per_session.items():
        assert seq == sorted(seq), f"session {s} acks out of txid order: {seq}"

    # -- S: single system image ---------------------------------------------------
    stores = list(svc.data_stores.values())
    contents = [
        {k: (v.get("data"), v.get("version"), tuple(sorted(v.get("children", []))))
         for k, v in st_.objects.items()} for st_ in stores
    ]
    for other in contents[1:]:
        assert other == contents[0], "regions diverged"
    # per-client, per-path version monotonicity
    seen: Dict = {}
    for s, path, txid, _t in log["reads"]:
        prev = seen.get((s, path), -1)
        assert txid >= prev, f"{s} observed txid regression on {path}"
        seen[(s, path)] = txid

    # -- N: ordered notifications ---------------------------------------------------
    # Appendix A (ordered notifications): if an update u triggers a watch for
    # client C, C observes the notification before any data of txn v with
    # u < v (STRICT: the registering read may itself return u's data).
    for s, path, v, t_read in log["reads"]:
        regs = [t for (ss, pp, t) in log["watch_reg"] if ss == s and pp == path
                and t < t_read]
        if not regs:
            continue
        for ss, pp, u, t_del in log["watch_dev"]:
            if ss == s and pp == path and u is not None and u < v \
                    and min(regs) < t_del:
                assert t_del <= t_read + 1e-9, (
                    f"{s} saw txn {v} data on {path} at {t_read:.4f} before "
                    f"its watch for txn {u} arrived at {t_del:.4f}")


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**16),
       point=st.sampled_from(["after_lock", "after_push", "after_commit",
                              "after_getnode", "after_dataupdate",
                              "after_epoch_add", "after_notify", "after_pop"]),
       func=st.sampled_from(["writer", "distributor"]))
def test_single_crash_never_loses_acked_write(seed, point, func):
    """A crash anywhere in the pipeline: every acked write survives in every
    region (at-least-once redelivery + idempotent distributor)."""
    faults = FaultPlan(crashes={(func, point): 0})
    cloud, svc = make_service(seed=seed, faults=faults, regions=("r0", "r1"))
    c = svc.connect_sync("w")
    c.create("/n", b"0")
    for i in range(1, 4):
        c.set_data("/n", bytes([i]))
    for store in svc.data_stores.values():
        assert store.objects["/n"]["data"] == bytes([3]), \
            f"acked write lost in {store.region} after {func}@{point}"


def test_writer_distributor_commit_race_regression():
    """Regression for the race found during bring-up: the writer's commit
    lands between the distributor's GETNODE and TryCommit; the update must
    still be distributed (not rejected), exactly once."""
    # seed 6 with 64 kB payloads reproduced the interleaving deterministically
    cloud, svc = make_service(seed=6)
    c = svc.connect_sync("bench")
    c.create("/bench", b"init")
    payload = b"x" * (64 * 1024)
    for _i in range(10):
        c.set_data("/bench", payload)
    store = next(iter(svc.data_stores.values()))
    assert store.objects["/bench"]["data"] == payload
    assert store.objects["/bench"]["version"] == 10
