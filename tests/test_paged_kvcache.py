"""Paged-block KV pool + chunked prefill: exact-match parity against the
ring scheduler and solo decode, page-allocator safety properties, crash-
redelivery of interrupted admissions, and the freed-slot isolation the
pool's free-on-completion depends on."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.dist  # noqa: F401  (installs the AbstractMesh compat shim)
from repro import configs
from repro.models import build_model, kvcache
from repro.serve.engine import generate
from repro.serve.lifecycle import SlotState
from repro.serve.scheduler import DecodeScheduler

PARITY_ARCHS = ["minicpm-2b", "moonshot-v1-16b-a3b", "recurrentgemma-2b"]


def tiny(arch="minicpm-2b"):
    cfg = configs.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def run_all(sched, submits, got=None):
    """Drive a scheduler: ``submits`` maps step-index -> list of
    (session, rid, prompt, max_new); returns {rid_num: tokens}."""
    got = got if got is not None else {}
    step = 0
    while sched.busy() or any(k >= step for k in submits):
        for args in submits.get(step, ()):
            sched.submit(*args)
        for fin in sched.step():
            got[int(fin.request_id[1:])] = fin.tokens
        step += 1
        assert step < 500, "scheduler failed to drain"
    return got


# ---------------------------------------------------------------------------
# Exact-match parity: paged == ring == solo decode (greedy)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_paged_parity_staggered_multichunk(arch):
    """Prompts spanning 1..3 pages, admitted at different steps, prefilled in
    chunks smaller than a page: every request's tokens must equal both the
    PR 2 ring scheduler's and an eviction-free solo B=1 decode, token for
    token.  The paged gather reassembles pages in logical order, so the
    attention view is lane-for-lane the ring view — this is the exactness
    the whole rewrite is held to."""
    cfg, model, params = tiny(arch)
    page = 8
    lengths = [6, 12, 20]                 # 1, 2 and 3 pages of 8
    N = 4
    max_seq = max(lengths) + N
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=L).astype(np.int32)
               for L in lengths]
    ref = {i: np.asarray(generate(model, params, jnp.asarray(p)[None], N,
                                  seq_len=max_seq))[0]
           for i, p in enumerate(prompts)}

    submits = {0: [("a", "r0", prompts[0], N)],
               2: [("b", "r1", prompts[1], N)],
               3: [("c", "r2", prompts[2], N)]}
    ring = run_all(DecodeScheduler(model, params, n_slots=3, max_seq=max_seq,
                                   kv_mode="ring"), submits)
    paged = run_all(DecodeScheduler(model, params, n_slots=3, max_seq=max_seq,
                                    kv_mode="paged", page_size=page,
                                    prefill_chunk=5), submits)
    assert sorted(ring) == sorted(paged) == [0, 1, 2]
    for i in range(3):
        np.testing.assert_array_equal(
            paged[i], ref[i], err_msg=f"{arch} r{i}: paged != solo decode")
        np.testing.assert_array_equal(
            paged[i], ring[i], err_msg=f"{arch} r{i}: paged != ring scheduler")


def test_paged_parity_ssm_chunked():
    """SSM keeps its ring-free O(1) state (no pool pages at all) but the
    chunked admission must still thread the recurrence across chunk
    boundaries exactly."""
    cfg, model, params = tiny("mamba2-1.3b")
    P, N = 12, 5
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, size=P).astype(np.int32)
    ref = np.asarray(generate(model, params, jnp.asarray(prompt)[None], N,
                              seq_len=P + N))[0]
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=P + N,
                            kv_mode="paged", page_size=4, prefill_chunk=5)
    got = run_all(sched, {0: [("s", "r0", prompt, N)]})
    np.testing.assert_array_equal(got[0], ref)
    assert sched.allocator.n_pages == 0          # truly ring-free
    assert sched.stats()["prefill_chunks"] == 3  # 5 + 5 + 2


def test_paged_update_view_matches_ring_lanes():
    """kvcache-level parity: writes routed through an (arbitrarily ordered)
    page table and gathered back must be lane-for-lane identical to the ring
    buffer, with the same validity mask."""
    B, T, H, D, ps = 2, 16, 2, 4, 4
    rng = np.random.default_rng(0)
    ring = {"k": jnp.zeros((B, T, H, D)), "v": jnp.zeros((B, T, H, D)),
            "positions": -jnp.ones((B, T), jnp.int32)}
    # physical pages deliberately scrambled: logical order must not care
    table = jnp.asarray([[5, 2, 7, 0], [1, 6, 3, 4]], jnp.int32)
    paged = {"kp": jnp.zeros((9, ps, H, D)), "vp": jnp.zeros((9, ps, H, D)),
             "page_table": table}
    assert kvcache.cache_capacity(paged) == T

    # two chunked writes per row, staggered row lengths: row 0 fills 0..7,
    # row 1 fills 0..4 (the second chunk's scatter crosses a page boundary)
    for pos, S in [(jnp.asarray([0, 0], jnp.int32), 3),
                   (jnp.asarray([3, 3], jnp.int32), 5)]:
        k_new = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        v_new = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        if S == 5:                  # row 1 stops at length 5: trim its chunk
            k_new = k_new.at[1, 2:].set(0.0)
            v_new = v_new.at[1, 2:].set(0.0)
        ring = kvcache.cache_update_layer(ring, k_new, v_new, pos)
        paged = kvcache.cache_update_layer(paged, k_new, v_new, pos)
    # row 1's ring holds writes past its live length; mask them like upto does
    ring["positions"] = ring["positions"].at[1, 5:].set(-1)

    upto = jnp.asarray([8, 5], jnp.int32)
    rk, rv, rpos, rvalid = kvcache.cache_kv_view(ring)
    pk, pv, ppos, pvalid = kvcache.cache_kv_view(paged, upto=upto)
    w = np.asarray(rvalid)
    np.testing.assert_array_equal(np.asarray(pvalid)[:, : T], w)
    np.testing.assert_array_equal(np.asarray(pk)[w], np.asarray(rk)[w])
    np.testing.assert_array_equal(np.asarray(pv)[w], np.asarray(rv)[w])

    # a write whose page is unmapped (or off the table) is dropped, not
    # wrapped into someone else's page
    hole = {"kp": paged["kp"], "vp": paged["vp"],
            "page_table": table.at[0, 1].set(-1)}
    before = np.asarray(hole["kp"])
    after = kvcache.cache_update_layer(
        hole, k_new[:, :1], v_new[:, :1], jnp.asarray([ps, T], jnp.int32))
    np.testing.assert_array_equal(np.asarray(after["kp"]), before)


# ---------------------------------------------------------------------------
# Freed-slot isolation (the PR 2 _step_impl inactive-slot fix)
# ---------------------------------------------------------------------------


def test_freed_slot_cannot_corrupt_later_admission():
    """A freed slot's stale state keeps flowing through the batched decode
    step.  Its token writes and output-ring advance must be masked out, and
    its unmapped page table must drop its pool writes — otherwise reused
    pages would be corrupted.  The sequence: complete A (pages freed), keep B
    decoding (the stale A row rides along), then admit C into A's slot reusing
    A's pages — C must still match solo decode exactly."""
    cfg, model, params = tiny()
    P, N_short, N_long = 8, 2, 12
    rng = np.random.default_rng(11)
    pa = rng.integers(0, cfg.vocab, size=P).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, size=P).astype(np.int32)
    pc = rng.integers(0, cfg.vocab, size=P).astype(np.int32)
    max_seq = P + N_long
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=max_seq,
                            kv_mode="paged", page_size=4,
                            kv_pages=2 * ((P + N_long) // 4 + 1))
    sched.submit("a", "r0", pa, N_short)
    sched.submit("b", "r1", pb, N_long)
    got = {}
    steps_after_free = 0
    submitted_c = False
    n = 0
    while sched.busy():
        n += 1
        assert n < 300
        for fin in sched.step():
            got[int(fin.request_id[1:])] = fin.tokens
        if 0 in got and not submitted_c:
            steps_after_free += 1
            if steps_after_free == 3:    # stale row rode along for 3 steps
                sched.submit("c", "r2", pc, N_short)
                submitted_c = True
    for i, (p, N) in enumerate([(pa, N_short), (pb, N_long), (pc, N_short)]):
        ref = np.asarray(generate(model, params, jnp.asarray(p)[None], N,
                                  seq_len=max_seq))[0]
        np.testing.assert_array_equal(got[i], ref, err_msg=f"r{i} corrupted")
    # pool fully drained and the invariant held
    a = sched.allocator
    assert a.in_use == 0 and a.free_count == a.n_pages


def test_inactive_slot_outputs_frozen():
    """The regression the paged pool makes load-bearing: a decode step must
    not advance out_pos or write tokens for slots that are not active."""
    cfg, model, params = tiny()
    sched = DecodeScheduler(model, params, n_slots=3, max_seq=16,
                            kv_mode="paged", page_size=4)
    sched.submit("s", "r0", np.zeros(4, np.int32), 8)
    for _ in range(3):
        sched.step()
    out_pos = np.asarray(sched.out_pos)
    lengths = np.asarray(sched.cache["length"])
    assert out_pos[0] == 4                      # 1 prefill token + 3 steps
    np.testing.assert_array_equal(out_pos[1:], 0)
    np.testing.assert_array_equal(lengths[1:], 0)
    np.testing.assert_array_equal(np.asarray(sched.out_buf)[1:], 0)


# ---------------------------------------------------------------------------
# Crash redelivery of a half-finished chunked admission
# ---------------------------------------------------------------------------


def test_reset_mid_admission_replays_exactly():
    """reset() while a slot is still `admitting` (some chunks landed) +
    queue redelivery must reproduce the exact same tokens, and the half-
    prefilled slot must never have reached sampling."""
    cfg, model, params = tiny("recurrentgemma-2b")
    P, N = 20, 4
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab, size=P).astype(np.int32)
    max_seq = P + N
    ref = np.asarray(generate(model, params, jnp.asarray(prompt)[None], N,
                              seq_len=max_seq))[0]

    sched = DecodeScheduler(model, params, n_slots=2, max_seq=max_seq,
                            kv_mode="paged", page_size=8, prefill_chunk=6)
    sched.submit("s", "r0", prompt, N)
    sched.step()                       # chunk 1 of 4 lands
    sched.step()                       # chunk 2 of 4 lands
    st = sched.slots[0]
    assert st.state is SlotState.ADMITTING and st.chunk_i == 2
    assert sched.admitted == 0, "half-prefilled slot reached sampling"
    assert sched.allocator.in_use > 0

    sched.reset()                      # crash: abort in-flight admission
    a = sched.allocator
    assert a.in_use == 0 and a.free_count == a.n_pages
    assert (sched._page_rows == -1).all()

    sched.submit("s", "r0", prompt, N)  # queue redelivery
    got = run_all(sched, {})
    np.testing.assert_array_equal(got[0], ref,
                                  err_msg="redelivered admission diverged")


def test_frontend_crash_redelivery_with_chunked_prefill():
    """End-to-end at-least-once through the queue layer with the paged
    scheduler: a crash after the first completion redelivers; every request
    completes exactly once and in FIFO order per session."""
    from repro.core import SimCloud
    from repro.core.simcloud import FaultPlan
    from repro.launch.serve import build_frontend, spawn_workload

    cfg, model, params = tiny()
    cloud = SimCloud(seed=0, faults=FaultPlan(
        crashes={("serve", "post-complete"): 0}))
    fe = build_frontend(cloud, cfg, model, params, mode="continuous",
                        batch_size=4, max_new=3, prompt_len=8,
                        kv_mode="paged", page_size=4, prefill_chunk=3)
    spawn_workload(cloud, fe, vocab=cfg.vocab, n_requests=8, sessions=4,
                   prompt_len=8, max_new=3)
    cloud.run()
    assert fe.runtime.stats["serve"].crashes == 1
    done = [r for ids in fe.completions.values() for r in ids]
    assert sorted(done) == [f"r{i}" for i in range(8)]
    assert len(done) == len(set(done))
    a = fe.scheduler.allocator
    assert a.in_use == 0 and a.free_count + a.in_use == a.n_pages
    stats = fe.serving_stats()
    assert stats["kv_pages_high_water"] > 0
    assert stats["prefill_chunks"] >= 8 * 3   # 8 tokens / chunk 3 -> 3 chunks


# ---------------------------------------------------------------------------
# Pool sizing / admission gate
# ---------------------------------------------------------------------------


def test_admission_waits_for_pool_pages():
    """With a pool sized for one request, the second request holds in
    pending until the first completes and frees its pages — lazy mapping
    must never be able to deadlock mid-decode."""
    cfg, model, params = tiny()
    P, N = 8, 4
    need = -(-(P + N - 1) // 4)
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=P + N,
                            kv_mode="paged", page_size=4, kv_pages=need)
    p = np.zeros(P, np.int32)
    sched.submit("a", "r0", p, N)
    sched.submit("b", "r1", p, N)
    assert sched.slots[0].occupied and sched.slots[1].empty
    assert [r.request_id for r in sched.pending] == ["r1"]
    got = run_all(sched, {})
    assert sorted(got) == [0, 1]
    assert sched.allocator.high_water <= need


def test_page_starved_request_not_overtaken_by_its_session():
    """Per-session FIFO survives the pool gate: when a session's long r0 is
    held for pages, its short r1 must be held with it — not slip into the
    free slot ahead of it."""
    cfg, model, params = tiny()
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=24,
                            kv_mode="paged", page_size=4, kv_pages=8)
    sched.submit("x", "r0", np.zeros(16, np.int32), 8)   # takes 6 pages
    sched.submit("y", "r1", np.zeros(16, np.int32), 8)   # starved: needs 6
    sched.submit("y", "r2", np.zeros(4, np.int32), 2)    # fits, but gated by r1
    assert sched.slots[1].empty
    assert [r.request_id for r in sched.pending] == ["r1", "r2"]
    order = []
    while sched.busy():
        order.extend(f.request_id for f in sched.step())
    assert order.index("r1") < order.index("r2"), "pool gate broke session FIFO"


def test_prompt_overrunning_page_table_rejected():
    cfg, model, params = tiny()
    sched = DecodeScheduler(model, params, n_slots=1, max_seq=8,
                            kv_mode="paged", page_size=4)
    with pytest.raises(ValueError, match="no decode room"):
        sched.submit("s", "r0", np.zeros(8, np.int32), 4)
    with pytest.raises(ValueError):
        DecodeScheduler(model, params, n_slots=1, max_seq=64,
                        kv_mode="paged", page_size=4, kv_pages=2)


# ---------------------------------------------------------------------------
# Page-pool sharding rules
# ---------------------------------------------------------------------------


def test_paged_cache_shardings_resolve_on_16x16():
    from jax.sharding import AbstractMesh

    cfg, model, params = tiny("qwen3-14b")
    mesh = AbstractMesh((16, 16), ("data", "model"))
    sched = DecodeScheduler(model, params, n_slots=16, max_seq=32,
                            kv_mode="paged", page_size=16, mesh=mesh)
    specs = sched.cache_specs
    # pool (L, Np, ps, H, D): shared across slots -> replicated over data;
    # the reduced config's 4 kv heads don't divide model=16, so the guard
    # falls back to the within-page lane dim (never the page dim — the
    # kernel's table-indirect page slices would all-gather the pool)
    assert all(e is None or e == "model" for e in specs["kp"])
    assert specs["kp"][2] == "model" and specs["kp"][1] is None
    # page table (L, n_slots, max_pages): slot batch on data
    assert specs["page_table"][1] == ("data",)


def test_paged_scheduler_decodes_under_concrete_mesh():
    from jax.sharding import Mesh

    cfg, model, params = tiny()
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=16, mesh=mesh,
                            kv_mode="paged", page_size=4, prefill_chunk=4)
    sched.submit("s0", "r0", np.zeros(8, np.int32), 3)
    got = run_all(sched, {})
    assert got[0].shape == (3,)


# ---------------------------------------------------------------------------
# PageAllocator properties (hypothesis)
# ---------------------------------------------------------------------------


def test_allocator_basics():
    a = kvcache.PageAllocator(4)
    p = a.alloc(3)
    assert len(set(p)) == 3 and a.free_count == 1 and a.high_water == 3
    a.free(p[:2])
    assert a.free_count + a.in_use == 4
    with pytest.raises(ValueError):
        a.free([p[0]])               # double free
    with pytest.raises(RuntimeError):
        a.alloc(4)                   # exhausted
    a.reset()
    assert a.free_count == 4 and a.in_use == 0


def _allocator_property(n_pages, ops):
    """Random submit/complete/reset interleavings: pages handed out are
    always distinct live pages, free + mapped == n_pages at every step, and
    reset() returns the pool to fully free."""
    a = kvcache.PageAllocator(n_pages)
    live = {}                        # request key -> pages
    for op, key, n in ops:
        if op == "submit":
            if key in live or n > a.free_count:
                continue
            pages = a.alloc(n)
            flat = [p for ps in live.values() for p in ps]
            assert not (set(pages) & set(flat)), "double-mapped page"
            assert all(0 <= p < n_pages for p in pages)
            live[key] = pages
        elif op == "complete":
            if key in live:
                a.free(live.pop(key))
        else:
            a.reset()
            live.clear()
            assert a.free_count == n_pages and a.in_use == 0
        assert a.free_count + a.in_use == n_pages, "page leak"
        assert a.in_use == sum(len(p) for p in live.values())
        assert a.high_water <= n_pages
    a.reset()
    assert a.free_count == n_pages and a.in_use == 0


try:  # optional dep, guarded like test_kernel_properties (skip, not error)
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.integers(1, 12),
           st.lists(st.tuples(st.sampled_from(["submit", "complete", "reset"]),
                              st.integers(0, 11), st.integers(1, 6)),
                    max_size=40))
    def test_allocator_never_double_maps_or_leaks(n_pages, ops):
        _allocator_property(n_pages, ops)

except ImportError:

    @pytest.mark.skip(reason="optional dep: property sweeps need hypothesis")
    def test_allocator_never_double_maps_or_leaks():
        pass
