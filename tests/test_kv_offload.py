"""Storage-backed KV page offload + the explicit slot lifecycle.

The headline invariant: a preempted-then-restored slot produces
token-for-token identical output to a never-preempted run — across
dense/moe/hybrid — because ``gather_pages``/``scatter_pages`` are exact
inverses through the page table and a PREEMPTED slot's rows are frozen
under the decode mask.  Plus: the pressure/idleness preemption policy,
restore funding (no deadlock / thrash), lifecycle transition legality,
crash-reset blob hygiene, offload billing through the serving frontend,
staging-buffer sharding specs, and the startup pool-sizing validation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.dist  # noqa: F401  (installs the AbstractMesh compat shim)
from repro import configs
from repro.core.storage import PageBlobStore
from repro.models import build_model, kvcache
from repro.serve.engine import generate
from repro.serve.lifecycle import IllegalTransition, Slot, SlotState
from repro.serve.scheduler import DecodeScheduler

PARITY_ARCHS = ["minicpm-2b", "moonshot-v1-16b-a3b", "recurrentgemma-2b"]


def tiny(arch="minicpm-2b"):
    cfg = configs.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def drain(sched, got=None, hooks=None, limit=500):
    """Run a scheduler dry; ``hooks`` maps an iteration index to a callback
    (e.g. a forced preemption or a late submit)."""
    got = got if got is not None else {}
    hooks = hooks or {}
    it = 0
    while sched.busy():
        if it in hooks:
            hooks[it](sched)
        for fin in sched.step():
            got[int(fin.request_id[1:])] = fin
        it += 1
        assert it < limit, "scheduler failed to drain"
    return got


# ---------------------------------------------------------------------------
# Lifecycle state machine
# ---------------------------------------------------------------------------


def test_lifecycle_transitions_validated():
    s = Slot(index=0)
    s.to(SlotState.ADMITTING).to(SlotState.ACTIVE).to(SlotState.PREEMPTED)
    with pytest.raises(IllegalTransition):
        s.to(SlotState.ACTIVE)          # preempted must go through RESTORING
    s.to(SlotState.RESTORING)
    with pytest.raises(IllegalTransition):
        s.to(SlotState.PREEMPTED)       # a funded restore runs to completion
    s.to(SlotState.ACTIVE).to(SlotState.DRAINED).to(SlotState.EMPTY)
    with pytest.raises(IllegalTransition):
        Slot(index=1).to(SlotState.ACTIVE)   # EMPTY cannot skip ADMITTING
    # crash recovery is the one escape hatch
    s2 = Slot(index=2)
    s2.to(SlotState.ADMITTING)
    s2.force_empty()
    assert s2.state is SlotState.EMPTY and s2.req is None


def test_scheduler_slots_expose_states():
    cfg, model, params = tiny()
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=16,
                            page_size=4, prefill_chunk=3)
    assert all(s.empty for s in sched.slots)
    sched.submit("s", "r0", np.zeros(7, np.int32), 3)
    assert sched.slots[0].state is SlotState.ADMITTING
    sched.step()                         # chunk 1/3
    assert sched.admitting_slots() == 1 and sched.active_slots() == 0
    drain(sched)
    assert all(s.empty for s in sched.slots)


# ---------------------------------------------------------------------------
# gather/scatter exact-inverse property (scrambled page tables)
# ---------------------------------------------------------------------------


def _round_trip(n_pages, ps, H, D, table_rows, seed):
    """extract(inject(pages)) == pages: pages extracted through one
    (scrambled) page table, injected into a cold pool through another,
    and re-extracted must be bit-identical — layer-stacked pool included."""
    rng = np.random.default_rng(seed)
    L = 2
    pool = {
        "kp": jnp.asarray(rng.standard_normal((L, n_pages, ps, H, D)),
                          jnp.float32),
        "vp": jnp.asarray(rng.standard_normal((L, n_pages, ps, H, D)),
                          jnp.float32),
        "page_table": jnp.asarray(table_rows, jnp.int32)[None].repeat(L, 0),
        "length": jnp.zeros((len(table_rows),), jnp.int32),
    }
    for row in table_rows:
        ids = [p for p in row if p >= 0]      # logical order through the table
        if not ids:
            continue
        blob = kvcache.gather_pages(pool, ids)
        assert set(blob) == {"kp", "vp"}
        assert blob["kp"].shape == (L, len(ids), ps, H, D)
        # inject into a cold pool at *different* physical pages (restore
        # never gets the same pages back) and extract again
        new_ids = [(p + 1) % n_pages for p in ids]
        cold = {
            "kp": jnp.zeros_like(pool["kp"]),
            "vp": jnp.zeros_like(pool["vp"]),
            "page_table": pool["page_table"],
            "length": pool["length"],
        }
        back = kvcache.gather_pages(
            kvcache.scatter_pages(cold, new_ids, blob), new_ids)
        for k in ("kp", "vp"):
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(blob[k]))
        # nbytes metering matches the staged payload
        assert kvcache.blob_nbytes(blob) == sum(
            np.asarray(blob[k]).nbytes for k in ("kp", "vp"))


def test_gather_scatter_round_trip_scrambled():
    _round_trip(9, 4, 2, 3, [[5, 2, 7, -1], [1, 6, -1, -1], [-1, -1, -1, -1]],
                seed=0)


def test_scatter_leaves_other_pages_untouched():
    rng = np.random.default_rng(1)
    pool = {"kp": jnp.asarray(rng.standard_normal((4, 2, 2, 2)), jnp.float32),
            "vp": jnp.asarray(rng.standard_normal((4, 2, 2, 2)), jnp.float32),
            "page_table": jnp.zeros((1, 2), jnp.int32)}
    blob = kvcache.gather_pages(pool, [3])
    out = kvcache.scatter_pages(pool, [0], blob)
    np.testing.assert_array_equal(np.asarray(out["kp"][1:]),
                                  np.asarray(pool["kp"][1:]))
    np.testing.assert_array_equal(np.asarray(out["kp"][0]),
                                  np.asarray(pool["kp"][3]))
    # slicing a blob is slicing its page axis
    piece = kvcache.slice_page_blob(blob, 0, 1)
    assert piece["kp"].shape == (1, 2, 2, 2)


try:  # optional dep, guarded like test_kernel_properties (skip, not error)
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 10_000), st.integers(1, 3), st.integers(1, 4))
    def test_gather_scatter_round_trip_property(seed, rows, max_pages):
        rng = np.random.default_rng(seed)
        n_pages = rows * max_pages + 3
        table = np.full((rows, max_pages), -1, np.int64)
        perm = rng.permutation(n_pages)
        k = 0
        for r in range(rows):               # scrambled, partially-filled rows
            fill = int(rng.integers(0, max_pages + 1))
            table[r, :fill] = perm[k:k + fill]
            k += fill
        _round_trip(n_pages, int(rng.integers(1, 5)), 2, 3, table.tolist(),
                    seed=seed + 1)

except ImportError:

    @pytest.mark.skip(reason="optional dep: property sweeps need hypothesis")
    def test_gather_scatter_round_trip_property():
        pass


# ---------------------------------------------------------------------------
# Preempt-mid-decode -> restore -> finish: token-for-token parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_preempt_restore_parity(arch):
    """Force a preemption mid-decode while a second slot keeps the batch
    (and the shared pool) evolving, let the restore interleave chunk by
    chunk, and require the preempted request's tokens to equal the
    eviction-free solo reference exactly."""
    cfg, model, params = tiny(arch)
    P, N = 12, 8
    rng = np.random.default_rng(7)
    pa = rng.integers(0, cfg.vocab, size=P).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, size=P).astype(np.int32)
    refs = [np.asarray(generate(model, params, jnp.asarray(p)[None], N,
                                seq_len=P + N))[0] for p in (pa, pb)]

    sched = DecodeScheduler(model, params, n_slots=2, max_seq=P + N,
                            page_size=4, prefill_chunk=5, offload=True)
    sched.submit("a", "r0", pa, N)
    sched.submit("b", "r1", pb, N)

    def force(s):
        s.preempt(0)
        assert s.slots[0].state is SlotState.PREEMPTED
        assert not s.slots[0].pages and s.blob_store.puts == 1

    got = drain(sched, hooks={6: force})
    for i in range(2):
        np.testing.assert_array_equal(
            got[i].tokens, refs[i],
            err_msg=f"{arch} r{i}: preempt/restore diverged from solo")
    assert got[0].preempts == 1 and got[1].preempts == 0
    assert sched.restores == 1 and sched.restored_pages == sched.offload_pages
    a = sched.allocator
    assert a.in_use == 0 and a.free_count == a.n_pages
    assert sched.blob_store.bytes_stored == 0     # restored blob deleted


def test_pressure_preemption_admits_starved_request():
    """A pool-gated arrival triggers the policy: the longest-resident ACTIVE
    slot is evicted to storage, the newcomer admits immediately instead of
    stalling, and the victim restores when pressure clears — both exact."""
    cfg, model, params = tiny()
    P, N = 8, 12
    need = -(-(P + N - 1) // 4)                   # 5 pages each
    rng = np.random.default_rng(9)
    pa = rng.integers(0, cfg.vocab, size=P).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, size=P).astype(np.int32)
    refs = [np.asarray(generate(model, params, jnp.asarray(p)[None], N,
                                seq_len=P + N))[0] for p in (pa, pb)]

    sched = DecodeScheduler(model, params, n_slots=2, max_seq=P + N,
                            page_size=4, kv_pages=need + 1, offload=True)
    sched.submit("a", "r0", pa, N)

    def arrive(s):
        assert s.slots[0].state is SlotState.ACTIVE
        s.submit("b", "r1", pb, N)                # pool-gated: 1 page free
        assert s.preemptions == 1, "pressure did not preempt"
        assert s.slots[0].state is SlotState.PREEMPTED
        assert s.slots[1].state is SlotState.ADMITTING, \
            "starved request should admit right after the eviction"

    got = drain(sched, hooks={3: arrive})
    for i in range(2):
        np.testing.assert_array_equal(got[i].tokens, refs[i],
                                      err_msg=f"r{i} corrupted by preemption")
    assert got[0].admitted_step < got[1].admitted_step
    assert got[0].finished_step > got[1].finished_step  # victim finished last
    assert sched.restores == 1


def test_idle_floor_blocks_preemption():
    """`idle_preempt_steps` is the anti-thrash floor: a slot younger than it
    is not preemptible, so the arrival holds in pending instead."""
    cfg, model, params = tiny()
    P, N = 8, 12
    need = -(-(P + N - 1) // 4)
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=P + N,
                            page_size=4, kv_pages=need + 1, offload=True,
                            idle_preempt_steps=1000)
    sched.submit("a", "r0", np.zeros(P, np.int32), N)
    for _ in range(3):
        sched.step()
    sched.submit("b", "r1", np.zeros(P, np.int32), N)
    assert sched.preemptions == 0
    assert [r.request_id for r in sched.pending] == ["r1"]
    got = drain(sched)
    assert sorted(got) == [0, 1]                  # completion-time frees admit it
    assert sched.preemptions == 0


def test_restore_waits_for_pressure_to_clear():
    """A preempted slot must not steal its pages back while the request it
    was evicted for is still decoding (preempt<->restore thrash)."""
    cfg, model, params = tiny()
    P, N = 8, 12
    need = -(-(P + N - 1) // 4)
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=P + N,
                            page_size=4, kv_pages=need + 1, offload=True)
    sched.submit("a", "r0", np.zeros(P, np.int32), N)
    sched.step(); sched.step()
    sched.submit("b", "r1", np.zeros(P, np.int32), N)   # evicts r0
    assert sched.slots[0].state is SlotState.PREEMPTED
    for _ in range(4):
        sched.step()
        assert sched.slots[0].state is SlotState.PREEMPTED, \
            "restore funded while the pool is still under pressure"
    drain(sched)
    assert sched.restores == 1 and sched.completed == 2


def test_reset_with_preempted_slot_replays_cleanly():
    """Crash recovery with a blob in flight: reset() clears the store and
    the preempted slot; redelivery replays from the prompt and still
    matches the solo reference."""
    cfg, model, params = tiny("recurrentgemma-2b")
    P, N = 12, 6
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab, size=P).astype(np.int32)
    ref = np.asarray(generate(model, params, jnp.asarray(prompt)[None], N,
                              seq_len=P + N))[0]
    sched = DecodeScheduler(model, params, n_slots=2, max_seq=P + N,
                            page_size=4, offload=True)
    sched.submit("s", "r0", prompt, N)
    sched.step(); sched.step()
    sched.preempt(0)
    assert sched.blob_store.bytes_stored > 0
    sched.reset()
    assert sched.blob_store.bytes_stored == 0 and not sched.blob_store.blobs
    a = sched.allocator
    assert a.in_use == 0 and a.free_count == a.n_pages
    assert all(s.empty for s in sched.slots)
    sched.submit("s", "r0", prompt, N)            # queue redelivery
    got = drain(sched)
    np.testing.assert_array_equal(got[0].tokens, ref)


def test_offload_requires_paged_pool():
    cfg, model, params = tiny()
    with pytest.raises(ValueError, match="paged"):
        DecodeScheduler(model, params, n_slots=2, max_seq=16,
                        kv_mode="ring", offload=True)
    with pytest.raises(ValueError, match="preempt_policy"):
        DecodeScheduler(model, params, n_slots=2, max_seq=16,
                        preempt_policy="lru")


# ---------------------------------------------------------------------------
# Frontend: billing + gauges through the serving stack
# ---------------------------------------------------------------------------


def test_frontend_bills_offload_storage_ops():
    from repro.core import SimCloud
    from repro.launch.serve import build_frontend, spawn_workload

    cfg, model, params = tiny()
    P, N = 8, 8
    need = -(-(P + N - 1) // 4)
    cloud = SimCloud(seed=0)
    fe = build_frontend(cloud, cfg, model, params, mode="continuous",
                        batch_size=2, max_new=N, prompt_len=P,
                        page_size=4, kv_pages=need + 1, offload=True)
    spawn_workload(cloud, fe, vocab=cfg.vocab, n_requests=4, sessions=4,
                   prompt_len=P, max_new=N)
    cloud.run()
    assert sum(len(v) for v in fe.completions.values()) == 4
    stats = fe.serving_stats()
    assert stats["preemptions"] >= 1 and stats["restores"] >= 1
    assert stats["offload_bytes"] > 0 and stats["restore_bytes"] > 0
    # every put/get journaled by the store was billed by the frontend
    assert stats["offload_storage_ops"] == (stats["offload_puts"]
                                            + stats["offload_gets"]
                                            + fe.scheduler.blob_store.deletes)
    from repro.core.cost import page_blob_cost
    assert stats["offload_storage_usd"] == pytest.approx(
        page_blob_cost(stats["offload_puts"], stats["offload_gets"]))
    assert cloud.op_counts.get("obj_write", 0) >= stats["offload_puts"]
    assert cloud.op_counts.get("obj_read", 0) >= stats["offload_gets"]


def test_blob_store_metering():
    bs = PageBlobStore()
    bs.put("a", {"x": 1}, 2048)
    bs.put("b", {"x": 2}, 1024)
    assert bs.bytes_stored == 3072 and bs.high_water_bytes == 3072
    assert bs.get("a") == {"x": 1} and bs.bytes_in == 2048
    bs.delete("a")
    assert bs.bytes_stored == 1024 and bs.high_water_bytes == 3072
    ops = bs.drain_ops()
    assert [o[0] for o in ops] == ["put", "put", "get", "delete"]
    assert bs.drain_ops() == []
    with pytest.raises(KeyError):
        bs.get("a")
    bs.clear()
    assert bs.bytes_stored == 0 and not bs.blobs


# ---------------------------------------------------------------------------
# Staging-buffer sharding + startup sizing validation
# ---------------------------------------------------------------------------


def test_offload_stage_shardings_resolve():
    from jax.sharding import AbstractMesh

    from repro.dist.sharding import offload_stage_shardings

    cfg, model, params = tiny("qwen3-14b")
    mesh = AbstractMesh((16, 16), ("data", "model"))
    sched = DecodeScheduler(model, params, n_slots=16, max_seq=32,
                            page_size=16, mesh=mesh, offload=True)
    specs = sched.stage_specs
    # the chunk mirrors the pool's lane-first rule: page_size=16 divides
    # model=16, so the within-page lane dim rides the model axis and nothing
    # else does (page dim replicated even though it would divide)
    assert specs is not None and "kp" in specs
    assert specs["kp"][-3] == "model"
    assert all(e is None for i, e in enumerate(specs["kp"]) if i != len(specs["kp"]) - 3)
    # when the lane doesn't divide, heads are the fallback — exactly the
    # pool's own fallback order, so scatter/gather stay shard-local
    mesh2 = AbstractMesh((2, 2), ("data", "model"))
    stage2 = {"kp": jax.ShapeDtypeStruct((3, 5, 4, 8), jnp.bfloat16)}
    specs2 = jax.tree_util.tree_map(
        lambda s: s.spec, offload_stage_shardings(stage2, mesh2))
    assert specs2["kp"][-2] == "model"
    assert all(e is None for i, e in enumerate(specs2["kp"]) if i != 2)
    # neither divides -> fully replicated (never the page dim)
    stage3 = {"kp": jax.ShapeDtypeStruct((4, 5, 3, 8), jnp.bfloat16)}
    specs3 = jax.tree_util.tree_map(
        lambda s: s.spec, offload_stage_shardings(stage3, mesh2))
    assert all(e is None for e in specs3["kp"])


def test_pool_sizing_validated_at_startup():
    from repro.launch.serve import validate_pool_sizing

    # one 16+8-token admission = 6 pages of 4, plus 3 more decoding slots
    assert validate_pool_sizing(batch_size=4, prompt_len=16, max_new=8,
                                page_size=4, kv_pages=9) == 9
    with pytest.raises(ValueError, match="max-size admission"):
        validate_pool_sizing(batch_size=4, prompt_len=16, max_new=8,
                             page_size=4, kv_pages=8)
    # offload relaxes the floor to one admission (preemption absorbs the
    # rest) but the largest single request must still fit the pool
    assert validate_pool_sizing(batch_size=4, prompt_len=16, max_new=8,
                                page_size=4, kv_pages=6, offload=True) == 6
    with pytest.raises(ValueError, match="even one max-size admission"):
        validate_pool_sizing(batch_size=4, prompt_len=16, max_new=8,
                             page_size=4, kv_pages=5, offload=True)
    with pytest.raises(ValueError, match="--page-size"):
        validate_pool_sizing(batch_size=2, prompt_len=8, max_new=4,
                             page_size=0)
    with pytest.raises(ValueError, match="--prefill-chunk"):
        validate_pool_sizing(batch_size=2, prompt_len=8, max_new=4,
                             page_size=4, prefill_chunk=0)

    from repro.launch.serve import run_serving
    with pytest.raises(ValueError, match="max-size admission"):
        run_serving("minicpm-2b", n_requests=1, max_new=8, prompt_len=16,
                    batch_size=4, page_size=4, kv_pages=8, quiet=True)
