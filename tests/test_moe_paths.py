"""Numeric parity of the MoE execution paths.

moe_ffn picks between three implementations (plain, shard_map EP train path,
stationary-weights decode path) depending on policy/shape.  On a 1x1 mesh
every collective is the identity, so all paths must agree numerically with
the no-policy reference — this pins down the dispatch/combine plumbing
(slot arithmetic, D-slicing, psum/all_gather axes) that the dry-run only
type-checks.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.dist import sharding as shd
from repro.models import build_model
from repro.models.config import ArchConfig, MoEConfig
from repro.models.moe import DECODE_TOKEN_THRESHOLD, moe_ffn

CFG = ArchConfig(name="moe-paths", family="moe", n_layers=2, d_model=32,
                 n_heads=4, n_kv_heads=4, d_ff=0, vocab=64, head_dim=8,
                 moe=MoEConfig(n_experts=4, top_k=2, d_expert=16,
                               capacity_factor=4.0),
                 remat="none")


def _params_and_input(T):
    model = build_model(CFG)
    params = model.init(jax.random.key(0))
    layer0 = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
    x = jax.random.normal(jax.random.key(1), (1, T, CFG.d_model), jnp.bfloat16)
    return layer0["moe"], x


def _mesh_1x1():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_stationary_decode_path_matches_plain():
    """T=4 <= DECODE_TOKEN_THRESHOLD -> stationary path under a policy."""
    p, x = _params_and_input(4)
    ref, _ = moe_ffn(p, CFG, x)                      # no policy: plain path
    policy = shd.ShardingPolicy.default(_mesh_1x1(), decode_stationary=True)

    def run(x):
        with shd.activation_sharding(policy):
            out, aux = moe_ffn(p, CFG, x)
        return out

    got = jax.jit(run)(x)
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(got, np.float32), atol=2e-2, rtol=2e-2)


def test_shard_map_train_path_matches_plain():
    """T above the decode threshold -> shard_map EP path under a policy."""
    T = DECODE_TOKEN_THRESHOLD + 48
    p, x = _params_and_input(T)
    ref, aux_ref = moe_ffn(p, CFG, x)
    policy = shd.ShardingPolicy.default(_mesh_1x1())

    def run(x):
        with shd.activation_sharding(policy):
            out, aux = moe_ffn(p, CFG, x)
        return out, aux

    got, aux = jax.jit(run)(x)
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(got, np.float32), atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(float(aux_ref), float(aux), rtol=1e-3)


def test_capacity_drops_are_deterministic():
    """With capacity_factor small enough to force drops, outputs are still
    finite and deterministic (dropped tokens contribute zero, not garbage)."""
    cfg = dataclasses.replace(
        CFG, moe=MoEConfig(n_experts=4, top_k=2, d_expert=16, capacity_factor=0.25))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    layer0 = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
    x = jax.random.normal(jax.random.key(2), (2, 16, cfg.d_model), jnp.bfloat16)
    o1, _ = moe_ffn(layer0["moe"], cfg, x)
    o2, _ = moe_ffn(layer0["moe"], cfg, x)
    assert bool(jnp.isfinite(o1.astype(jnp.float32)).all())
    np.testing.assert_array_equal(np.asarray(o1, np.float32),
                                  np.asarray(o2, np.float32))


def test_sort_rank_matches_onehot_reference():
    from repro.models.moe import _rank_within_expert

    rng = np.random.default_rng(0)
    e = jnp.asarray(rng.integers(0, 8, size=64), jnp.int32)
    got = _rank_within_expert(e, 8)
    onehot = jax.nn.one_hot(e, 8, dtype=jnp.int32)
    want = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
