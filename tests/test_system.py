"""System-level tests: dry-run cells in a subprocess (512 placeholder
devices), serving driver, and example smoke runs."""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
ENV = dict(os.environ, PYTHONPATH=f"{ROOT}/src")


def _run(cmd, timeout=420):
    return subprocess.run(cmd, cwd=ROOT, env=ENV, capture_output=True,
                          text=True, timeout=timeout)


@pytest.mark.parametrize("arch,shape", [
    ("whisper-base", "decode_32k"),
    ("recurrentgemma-2b", "long_500k"),
])
def test_dryrun_cell_subprocess(arch, shape):
    """One real 256-chip lower+compile per family class (the full 66-cell
    matrix is artifacts/dryrun_matrix.json; this keeps CI honest)."""
    r = _run([sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
              "--shape", shape, "--mesh", "single"])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert '"status": "OK"' in r.stdout


def test_dryrun_multipod_subprocess():
    r = _run([sys.executable, "-m", "repro.launch.dryrun", "--arch",
              "whisper-base", "--shape", "decode_32k", "--mesh", "multi"])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "2x16x16" in r.stdout


def test_dryrun_matrix_artifact_complete():
    """The committed artifact must cover every (arch x shape x mesh) cell
    with status OK — 33 applicable cells x 2 meshes, plus the paged-kernel
    decode dispatch axis (every attention-bearing decode cell again through
    the fused pool), the speculative verify-chunk axis (the same cells at
    S = spec_k + 1) and the shard_map lane-merge axis (the paged cells with
    shard_map_pool=True) — 60 x 2 = 120."""
    path = ROOT / "artifacts" / "dryrun_matrix.json"
    if not path.exists():
        pytest.skip("matrix artifact not built yet (scripts/run_matrices.sh)")
    rows = json.loads(path.read_text())
    from repro import configs
    from repro.models.config import SHAPES_BY_NAME

    base = sum(len(configs.get(a).shapes) for a in configs.list_archs())
    # mirror launch/dryrun.py::paged_kernel_applicable without importing the
    # module (its XLA_FLAGS device-count spoof must not leak into this
    # process); spec cells share the paged applicability rule
    paged = sum(1 for a in configs.list_archs()
                for s in configs.get(a).shapes
                if SHAPES_BY_NAME[s].kind == "decode"
                and configs.get(a).family in ("dense", "moe", "hybrid"))
    expected = (base + 3 * paged) * 2
    ok = [r for r in rows if r.get("status") == "OK"]
    assert len(rows) == expected == 120
    assert sum(1 for r in rows if r.get("kernel") == "paged") == paged * 2 == 18
    assert sum(1 for r in rows if r.get("kernel") == "spec") == paged * 2 == 18
    assert sum(1 for r in rows
               if r.get("kernel") == "shardmap") == paged * 2 == 18
    assert len(ok) == len(rows), [
        (r["arch"], r["shape"], r.get("error")) for r in rows if r not in ok]


def test_wire_bytes_regression_gate():
    """Every committed matrix cell's wire_bytes_per_device must stay within
    tolerance of the committed baseline — a sharding-rule regression fails
    tier-1 as a named cell (the gate also runs in CI via
    scripts/check_wire_bytes.py on the rebuilt matrix)."""
    matrix = ROOT / "artifacts" / "dryrun_matrix.json"
    baseline = ROOT / "artifacts" / "wire_bytes_baseline.json"
    if not matrix.exists() or not baseline.exists():
        pytest.skip("matrix/baseline not built (scripts/run_matrices.sh, "
                    "scripts/check_wire_bytes.py --update)")
    r = _run([sys.executable, "scripts/check_wire_bytes.py", str(matrix),
              "--baseline", str(baseline)])
    assert r.returncode == 0, r.stdout + r.stderr
    import json
    rows = json.loads(matrix.read_text())
    base = json.loads(baseline.read_text())
    assert f"{len(base)}/{len(base)} cells within" in r.stdout, r.stdout
    # the baseline must cover the whole matrix (new cells get baselined, not
    # silently ungated)
    assert len(base) == len(rows), (
        f"baseline covers {len(base)} of {len(rows)} cells; run "
        "scripts/check_wire_bytes.py --update and commit the diff")


def test_serving_driver():
    from repro.launch.serve import run_serving

    fe = run_serving("whisper-base", n_requests=6, max_new=3, sessions=2,
                     batch_size=3)
    assert sum(len(v) for v in fe.completions.values()) == 6


def test_quickstart_example():
    r = _run([sys.executable, "examples/quickstart.py"])
    assert r.returncode == 0, r.stderr[-1500:]
    assert "pay-as-you-go bill" in r.stdout


def test_elastic_scaling_example():
    r = _run([sys.executable, "examples/elastic_scaling.py"])
    assert r.returncode == 0, r.stderr[-1500:]
    assert "single system image holds" in r.stdout
