"""Training substrate: optimizer, schedules, grad accumulation equivalence,
int8 error-feedback compression, deterministic data pipeline."""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import DataConfig, SyntheticPipeline
from repro.models import build_model
from repro.models.config import ArchConfig, ShapeSpec
from repro.train import AdamWConfig, lr_at_step
from repro.train.step import (TrainStepConfig, cross_entropy, init_train_state,
                              make_train_step)

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab=64, head_dim=8,
                  remat="none")


def _setup(step_cfg=TrainStepConfig(), optim=None, seed=0):
    model = build_model(TINY)
    params = model.init(jax.random.key(seed))
    state = init_train_state(model, params, step_cfg)
    optim = optim or AdamWConfig(lr=1e-2, total_steps=100, warmup_steps=5)
    step = jax.jit(make_train_step(model, optim, step_cfg))
    pipe = SyntheticPipeline(TINY, ShapeSpec("t", 16, 8, "train"), DataConfig(seed=0))
    return model, params, state, step, pipe


def test_wsd_schedule_shape():
    cfg = AdamWConfig(lr=1.0, schedule="wsd", warmup_steps=10, total_steps=100,
                      stable_frac=0.8, min_ratio=0.1)
    assert float(lr_at_step(jnp.asarray(0.0), cfg)) == 0.0
    assert float(lr_at_step(jnp.asarray(10.0), cfg)) == pytest.approx(1.0)
    assert float(lr_at_step(jnp.asarray(50.0), cfg)) == pytest.approx(1.0)  # stable
    assert float(lr_at_step(jnp.asarray(100.0), cfg)) == pytest.approx(0.1)  # decayed
    mid = float(lr_at_step(jnp.asarray(91.0), cfg))
    assert 0.1 < mid < 1.0  # inside the decay tail


def test_minicpm_config_selects_wsd():
    from repro.configs.minicpm_2b import WSD

    assert set(WSD) == {"warmup_steps", "stable_frac", "min_ratio"}


def test_loss_decreases():
    model, params, state, step, pipe = _setup()
    losses = []
    for i in range(30):
        params, state, m = step(params, state, pipe.host_batch(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2
    assert losses[-1] > pipe.optimal_loss() - 0.05  # can't beat chain entropy


def test_grad_accumulation_equivalence():
    """accum=2 over the same global batch == accum=1 (same update)."""
    outs = {}
    for accum in (1, 2):
        sc = TrainStepConfig(accum_steps=accum)
        model, params, state, step, pipe = _setup(sc)
        p2, _, m = step(params, state, pipe.host_batch(0))
        outs[accum] = (jax.tree_util.tree_leaves(p2), float(m["loss"]))
    for a, b in zip(outs[1][0], outs[2][0]):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3, rtol=2e-3)
    assert outs[1][1] == pytest.approx(outs[2][1], rel=1e-2)


def test_compression_error_feedback():
    """int8 compression perturbs single steps but error feedback keeps the
    long-run trajectory close to uncompressed."""
    trajs = {}
    for comp in (False, True):
        sc = TrainStepConfig(compress_grads=comp)
        model, params, state, step, pipe = _setup(sc)
        losses = []
        for i in range(25):
            params, state, m = step(params, state, pipe.host_batch(i))
            losses.append(float(m["loss"]))
        trajs[comp] = losses
    # both learn, and end within a small margin of each other
    assert trajs[True][-1] < trajs[True][0] - 0.2
    assert abs(trajs[True][-1] - trajs[False][-1]) < 0.25


def test_cross_entropy_matches_naive():
    lg = jax.random.normal(jax.random.key(0), (2, 8, 32))
    labels = jax.random.randint(jax.random.key(1), (2, 8), 0, 32)
    got = cross_entropy(lg, labels)
    naive = -(jax.nn.log_softmax(lg)[
        jnp.arange(2)[:, None], jnp.arange(8)[None, :], labels]).mean()
    np.testing.assert_allclose(float(got), float(naive), rtol=1e-6)


def test_grad_clip_engages():
    cfg = AdamWConfig(lr=1e-2, grad_clip=1e-6, total_steps=10)
    model, params, state, step, pipe = _setup(optim=cfg)
    p2, _, m = step(params, state, pipe.host_batch(0))
    delta = jax.tree_util.tree_reduce(
        lambda a, b: max(a, float(jnp.max(jnp.abs(b)))),
        jax.tree_util.tree_map(lambda x, y: x - y, params, p2), 0.0)
    assert delta < 1e-3  # tiny clip -> tiny step


# -- data pipeline -------------------------------------------------------------------


def test_pipeline_deterministic_and_restartable():
    shape = ShapeSpec("t", 16, 8, "train")
    p1 = SyntheticPipeline(TINY, shape, DataConfig(seed=7))
    p2 = SyntheticPipeline(TINY, shape, DataConfig(seed=7))
    b1 = p1.global_batch(5)
    b2 = p2.global_batch(5)  # fresh pipeline, same (seed, step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_pipeline_host_sharding_partitions_global_batch():
    shape = ShapeSpec("t", 16, 8, "train")
    full = SyntheticPipeline(TINY, shape, DataConfig(seed=1)).global_batch(3)
    parts = [SyntheticPipeline(TINY, shape, DataConfig(seed=1, host_index=i,
                                                       host_count=4)).host_batch(3)
             for i in range(4)]
    glued = np.concatenate([np.asarray(p["tokens"]) for p in parts])
    np.testing.assert_array_equal(glued, full["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    shape = ShapeSpec("t", 16, 4, "train")
    b = SyntheticPipeline(TINY, shape, DataConfig(seed=2)).global_batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_frontend_stubs():
    wcfg = configs.get("whisper-base").reduced()
    shape = ShapeSpec("t", 8, 2, "train")
    b = SyntheticPipeline(wcfg, shape, DataConfig()).global_batch(0)
    assert b["frames"].shape == (2, wcfg.encdec.n_frames, wcfg.encdec.frame_dim)
    vcfg = configs.get("internvl2-2b").reduced()
    b = SyntheticPipeline(vcfg, shape, DataConfig()).global_batch(0)
    assert b["patch_embeds"].shape == (2, vcfg.vlm.n_patches, vcfg.vlm.patch_dim)
