#!/usr/bin/env python
"""Wire-bytes regression gate over the dry-run matrix.

Every cell of ``artifacts/dryrun_matrix.json`` records
``collectives.wire_bytes_per_device`` — the bytes a chip puts on the wire
per step, the cost the sharding registry exists to control.  This gate
pins each cell against ``artifacts/wire_bytes_baseline.json`` and fails
when any cell grows past the tolerance (default +10%), so a sharding-rule
regression (a replicated matrix sneaking into an all-gather, a batch dim
falling off ``dp``) shows up in CI as a named cell, not as a slow fleet.

Usage:
  scripts/check_wire_bytes.py [matrix.json] [--baseline B.json]
                              [--tolerance 0.10] [--update]

``--update`` rewrites the baseline from the given matrix (run it after a
*deliberate* layout change and commit the diff — the baseline is the
reviewed record of expected wire traffic).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DEFAULT_MATRIX = ROOT / "artifacts" / "dryrun_matrix.json"
DEFAULT_BASELINE = ROOT / "artifacts" / "wire_bytes_baseline.json"


def cell_key(row: dict) -> str:
    """``arch|shape|mesh``, with non-default decode dispatch appended (the
    paged-kernel cells gate independently of their gather twins)."""
    key = f"{row['arch']}|{row['shape']}|{row['mesh']}"
    if row.get("kernel") and row["kernel"] != "gather":
        key += f"|{row['kernel']}"
    return key


def load_wire_bytes(matrix_path: Path) -> dict:
    rows = json.loads(matrix_path.read_text())
    out = {}
    for r in rows:
        if r.get("status") != "OK":
            continue
        wire = (r.get("collectives") or {}).get("wire_bytes_per_device")
        if wire is not None:
            out[cell_key(r)] = float(wire)
    return out


def check(matrix_path: Path, baseline_path: Path, tolerance: float) -> int:
    current = load_wire_bytes(matrix_path)
    if not baseline_path.exists():
        print(f"no baseline at {baseline_path}; run with --update to create")
        return 1
    baseline = json.loads(baseline_path.read_text())
    failures, missing = [], []
    for key, base in sorted(baseline.items()):
        got = current.get(key)
        if got is None:
            missing.append(key)
        elif got > base * (1.0 + tolerance):
            failures.append((key, base, got))
    for key, base, got in failures:
        print(f"REGRESSION {key}: wire {got:.3e} B/device vs baseline "
              f"{base:.3e} (+{100 * (got / base - 1):.1f}% > "
              f"+{100 * tolerance:.0f}% tolerance)")
    for key in missing:
        print(f"MISSING {key}: cell in baseline but absent/failed in matrix")
    improved = sum(1 for k, b in baseline.items()
                   if k in current and current[k] < b * (1.0 - tolerance))
    print(f"wire-bytes gate: {len(baseline) - len(failures) - len(missing)}/"
          f"{len(baseline)} cells within +{100 * tolerance:.0f}% "
          f"({improved} improved past -{100 * tolerance:.0f}%; "
          f"re-baseline with --update to bank them)")
    return 1 if failures or missing else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("matrix", nargs="?", default=str(DEFAULT_MATRIX))
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE))
    ap.add_argument("--tolerance", type=float, default=0.10)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from this matrix")
    args = ap.parse_args()
    matrix = Path(args.matrix)
    baseline = Path(args.baseline)
    if args.update:
        wire = load_wire_bytes(matrix)
        baseline.parent.mkdir(parents=True, exist_ok=True)
        baseline.write_text(json.dumps(wire, indent=1, sort_keys=True) + "\n")
        print(f"wrote {len(wire)} cells -> {baseline}")
        return 0
    return check(matrix, baseline, args.tolerance)


if __name__ == "__main__":
    sys.exit(main())
