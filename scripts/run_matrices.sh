#!/usr/bin/env bash
# Build the full dry-run matrix artifact: every (arch x shape x mesh) cell is
# lowered AND compiled against 512 spoofed host devices, and the per-cell
# memory / flops / wire-bytes records land in artifacts/dryrun_matrix.json
# (consumed by tests/test_system.py::test_dryrun_matrix_artifact_complete).
# Decode cells run on every dispatch path (--kernel both): the classic
# gathered ring, the fused Pallas paged-attention pool, the speculative
# verify chunk (S = spec_k + 1 over the paged pool), and the shard_map
# lane-merge pool (shard_map_pool=True), so a sharding regression in any
# layout fails the wire-bytes gate as a named cell.
#
# Usage:  scripts/run_matrices.sh [out.json]
#
# The full matrix is compile-heavy (the 110B/235B cells take minutes each on
# CPU); CI runs it as a non-blocking job.  JAX_PLATFORMS=cpu keeps the spoofed
# device count deterministic on machines with accelerators.
set -euo pipefail
cd "$(dirname "$0")/.."

OUT="${1:-artifacts/dryrun_matrix.json}"
mkdir -p "$(dirname "$OUT")"

JAX_PLATFORMS=cpu PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.launch.dryrun --all --mesh both --kernel both --out "$OUT"

python - "$OUT" <<'EOF'
import json, sys
rows = json.load(open(sys.argv[1]))
ok = [r for r in rows if r.get("status") == "OK"]
print(f"{len(ok)}/{len(rows)} cells OK -> {sys.argv[1]}")
for r in rows:
    if r.get("status") != "OK":
        print("  FAIL:", r["arch"], r["shape"], r["mesh"], r.get("error"))
EOF
