"""End-to-end driver: train a ~100M-param LM for a few hundred steps with the
FaaSKeeper control plane doing what ZooKeeper does for production fleets —
membership, transactional checkpoints, crash recovery, straggler scanning.

Acts out a node failure mid-run and recovers from the last *committed*
manifest (never a torn checkpoint — paper Appendix B atomicity, applied to
training state).

    PYTHONPATH=src python examples/train_with_coordination.py [--steps 200]
"""

import argparse
import dataclasses
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro import configs
from repro.checkpoint import CheckpointStore
from repro.coord import CoordinatedManifest, MembershipService, StragglerDetector
from repro.core import FaaSKeeperService, SimCloud
from repro.data import DataConfig, SyntheticPipeline
from repro.models import build_model
from repro.models.config import ShapeSpec
from repro.train import AdamWConfig, make_train_step
from repro.train.step import TrainStepConfig, init_train_state

# ~100M params: a scaled-down qwen3-family config (same code path as the
# assigned full-scale config — only dims differ).
CFG_100M = dataclasses.replace(
    configs.get("qwen3-14b"),
    name="qwen3-100m", n_layers=10, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=2560, vocab=16384, head_dim=64, remat="none",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300,
                    help="~3 s/step on CPU; use --steps 30 for a smoke run")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()
    if args.fail_at is None:
        args.fail_at = max(2, args.steps * 3 // 5)

    cloud = SimCloud(seed=0)
    svc = FaaSKeeperService(cloud)
    membership = MembershipService(svc)
    stragglers = StragglerDetector(svc)
    manifest = CoordinatedManifest(svc, job="example")
    worker = membership.join("worker-0", {"devices": jax.device_count()})
    print(f"[coord] members: {membership.members()}")

    model = build_model(CFG_100M)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(model.init(jax.random.key(0))))
    print(f"model: {CFG_100M.name}, {n_params/1e6:.1f}M params")

    shape = ShapeSpec("ex", seq_len=64, global_batch=2, kind="train")
    pipe = SyntheticPipeline(CFG_100M, shape, DataConfig(seed=0))
    optim = AdamWConfig(lr=1e-3, total_steps=args.steps,
                        warmup_steps=max(2, args.steps // 10), schedule="cosine")
    step_cfg = TrainStepConfig(accum_steps=2)
    params = model.init(jax.random.key(0))
    state = init_train_state(model, params, step_cfg)
    train_step = jax.jit(make_train_step(model, optim, step_cfg))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        store = CheckpointStore(ckpt_dir, committer=manifest.commit,
                                latest_resolver=manifest.latest)
        losses = []
        step = 0
        crashed = False
        while step < args.steps:
            if step == args.fail_at and not crashed:
                crashed = True
                print(f"\n[fault] worker crashes at step {step}!")
                membership.fail(worker)
                svc.start_heartbeat(period=5.0, max_runs=3)
                cloud.run()
                print(f"[coord] heartbeat evicted it; members: {membership.members()}")
                # --- recovery: rejoin, restore from last committed manifest ---
                membership.join("worker-0b")
                try:
                    restored, at = store.restore({"params": params, "opt": state})
                except FileNotFoundError:
                    # crashed before the first checkpoint committed: start over
                    print("[coord] no committed checkpoint; restarting from 0\n")
                    params = model.init(jax.random.key(0))
                    state = init_train_state(model, params, step_cfg)
                    losses.clear()
                    step = 0
                    continue
                params, state = restored["params"], restored["opt"]
                step = at
                print(f"[coord] recovered at committed step {at} "
                      f"(manifest txid-ordered via FaaSKeeper)\n")
                continue
            batch = pipe.host_batch(step)
            params, state, metrics = train_step(params, state, batch)
            losses.append(float(metrics["loss"]))
            stragglers.report("worker-0", step)
            step += 1
            if step % max(5, args.steps // 10) == 0:
                print(f"step {step:4d}  loss {losses[-1]:.4f}")
            if step % max(10, args.steps // 6) == 0:
                store.save(step, {"params": params, "opt": state})
                print(f"[coord] checkpoint committed at step {step} "
                      f"(latest -> {manifest.latest()})")
        print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"(markov-chain floor {pipe.optimal_loss():.3f})")
        assert losses[-1] < losses[0], "training must improve"
        print(f"[coord] total control-plane bill: "
              f"${svc.cost_summary()['total_usd']:.6f}")


if __name__ == "__main__":
    main()
