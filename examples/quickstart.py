"""Quickstart: FaaSKeeper in five minutes.

Spins up the simulated serverless cloud, connects two clients, and walks
through the ZooKeeper feature set the paper reproduces: znodes, versions,
sequential + ephemeral nodes, watches, and the pay-per-operation bill.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import FaaSKeeperService, SimCloud


def main() -> None:
    cloud = SimCloud(seed=42)
    svc = FaaSKeeperService(cloud)
    alice = svc.connect_sync("alice")
    bob = svc.connect_sync("bob")

    # -- basic znode CRUD -------------------------------------------------------
    path = alice.create("/config", b"v1")
    print(f"created {path}")
    data, stat = bob.get_data("/config")
    print(f"bob reads: {data!r} (version {stat.version})")

    version = alice.set_data("/config", b"v2")
    print(f"alice updated to version {version}")

    # -- watches: ordered push notifications --------------------------------------
    data, _ = bob.get_data("/config", watch=True)
    alice.set_data("/config", b"v3")
    event = bob.wait_watch("/config")
    print(f"bob's watch fired: {event['event']} txid={event['txid']}")
    data, _ = bob.get_data("/config")
    assert data == b"v3", "watch preceded the data it announces (Ordered Notifications)"

    # -- sequential + ephemeral nodes (leader election building blocks) -----------
    alice.create("/election", b"")
    alice.create("/election/cand-", b"", ephemeral=True, sequence=True)
    bob.create("/election/cand-", b"", ephemeral=True, sequence=True)
    children, _ = alice.get_children("/election")
    leader = min(children)
    print(f"candidates {children} -> leader {leader}")

    # -- scale-to-zero economics ---------------------------------------------------
    bill = svc.cost_summary()
    print("\npay-as-you-go bill for this session:")
    for k, v in bill.items():
        print(f"  {k:15s} ${v:.6f}")
    print("(a 3-VM ZooKeeper ensemble bills $1.66/day whether used or not)")


if __name__ == "__main__":
    main()
