"""Serving example: the paper's queue/batcher fronts a real decode loop.

Inference requests take the exact write-request path from the paper —
per-session FIFO queues, batched event-function invocation, ordered
completions, pay-per-invoke billing — with a reduced recurrentgemma serving
tokens behind it.  Shows batching amortization and per-session FIFO order.

    PYTHONPATH=src python examples/serve_batched.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import run_serving


def main() -> None:
    frontend = run_serving("recurrentgemma-2b", n_requests=12, max_new=6,
                           sessions=3, batch_size=4)
    # per-session FIFO: completions must arrive in submission order
    for sess, ids in frontend.completions.items():
        nums = [int(r[1:]) for r in ids]
        assert nums == sorted(nums), f"FIFO violated in {sess}"
    print("\nper-session FIFO order verified across batched invocations")


if __name__ == "__main__":
    main()
