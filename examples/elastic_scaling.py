"""Elastic re-meshing example: watch-driven reconfiguration.

Workers join/leave a FaaSKeeper membership directory (ephemeral znodes);
a controller watches it and publishes new mesh generations; workers pick up
the new mesh from a single strongly consistent read and recompile.  This is
the serverless replacement for ZooKeeper-based cluster managers — scale-out,
scale-in, and crash eviction all through the same primitives.

    PYTHONPATH=src python examples/elastic_scaling.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import FaaSKeeperService, SimCloud
from repro.coord import MembershipService


def compile_for(n_workers: int):
    """Pretend each worker contributes one device; recompile a data-parallel
    matmul for the current world size (CPU has 1 device; the mesh math and
    recompilation flow are what the example demonstrates)."""
    devices = jax.devices()[:1]
    mesh = Mesh(devices, ("data",))
    x = jax.device_put(jnp.ones((max(1, n_workers) * 4, 64)),
                       NamedSharding(mesh, P("data")))

    @jax.jit
    def step(x):
        return (x @ x.T).sum()

    return float(step(x))


def main() -> None:
    cloud = SimCloud(seed=0)
    svc = FaaSKeeperService(cloud)
    membership = MembershipService(svc)

    handles = [membership.join(f"w{i}") for i in range(4)]
    print("members:", membership.members())
    gen = membership.propose_mesh(len(membership.members()), model_parallel=2)
    print(f"generation {gen['generation']}: mesh {gen['mesh']}")
    compile_for(gen["workers"])

    # scale-in: one worker crashes; heartbeat evicts; controller re-meshes
    membership.members(watch=True)
    membership.fail(handles[1])
    svc.start_heartbeat(period=5.0, max_runs=3)
    cloud.run()
    members = membership.members()
    gen = membership.propose_mesh(len(members), model_parallel=2)
    print(f"after crash: members {members} -> generation {gen['generation']} "
          f"mesh {gen['mesh']}")
    compile_for(gen["workers"])

    # scale-out: two workers join; re-mesh again
    handles += [membership.join(f"w{i}") for i in (4, 5)]
    members = membership.members()
    gen = membership.propose_mesh(len(members), model_parallel=2)
    print(f"after join: members {members} -> generation {gen['generation']} "
          f"mesh {gen['mesh']}")
    compile_for(gen["workers"])

    # every worker converges on the same config via one consistent read
    views = {w.worker_id: membership.current_mesh()["generation"] for w in handles[2:]}
    assert len(set(views.values())) == 1, "single system image violated"
    print(f"all workers observe generation {gen['generation']} — "
          f"single system image holds")


if __name__ == "__main__":
    main()
