"""Roofline analysis per (arch x shape) cell — EXPERIMENTS.md §Roofline.

Methodology (see also EXPERIMENTS.md §Dry-run):

* XLA's HloCostAnalysis counts while-loop bodies ONCE, so flops/bytes from a
  scan-over-layers (or grad-accum) compile are structurally undercounted.
  We therefore compile two ANALYSIS VARIANTS per cell — depths d1 < d2 with
  ``scan_layers=False`` (unrolled), ``accum_steps=1`` and streaming-attention
  disabled (its kv-block lax.scan would hide attention flops the same way) —
  and extrapolate every quantity linearly in depth:

      q(L) = a + b*L,   b = (q(d2) - q(d1)) / (d2 - d1)

  Exact for flops/bytes/collective-bytes because each is affine in layer
  count.  The full-depth production compile (scan + remat + accum) is still
  what the dry-run validates for memory/shardability; this module only
  replaces its *counters*.

* Terms (per training/serve step, seconds):
      compute    = flops_per_dev        / peak_bf16
      memory     = hbm_bytes_per_dev    / hbm_bw
      collective = wire_bytes_per_dev   / ici_bw
  with the wire model documented in launch/hlo_analysis.py.

* MODEL_FLOPS: train = 6*N*tokens (8*N*tokens under full remat — we report
  the 6N D convention and list remat separately), prefill = 2*N*tokens,
  decode = 2*N_active*batch.  The ratio MODEL_FLOPS / HLO_FLOPS_total flags
  remat/redundancy waste.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

from .common import save_artifact, table

from repro import configs
from repro.launch import hlo_analysis
from repro.models.config import SHAPES_BY_NAME


def _analysis_depths(cfg) -> Tuple[int, int]:
    if cfg.family == "hybrid":
        u = len(cfg.hybrid.pattern)
        return u, 2 * u
    return 2, 4


def _depth_overrides(cfg, depth: int) -> Dict:
    ov: Dict = {"n_layers": depth, "scan_layers": False}
    if cfg.family == "audio":
        ov["encdec"] = dataclasses.replace(cfg.encdec, n_encoder_layers=depth)
    return ov


def _counters(rec: Dict) -> Dict[str, float]:
    coll = rec.get("collectives", {})
    return {
        "flops": rec.get("flops_per_device", 0.0),
        "bytes": rec.get("hbm_bytes_per_device", 0.0),
        "wire": float(coll.get("wire_bytes_per_device", 0) or 0),
    }


def model_flops(cfg, shape) -> float:
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: 1 new token/sequence


def analyze_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                 step_overrides: Optional[Dict] = None,
                 extra_cfg_overrides: Optional[Dict] = None,
                 policy_kw: Optional[Dict] = None) -> Dict:
    """Two shallow unrolled compiles -> extrapolated roofline terms."""
    from repro.launch.dryrun import run_cell
    from repro.models import layers as L
    from repro.train.step import TrainStepConfig

    cfg = configs.get(arch)
    shape = SHAPES_BY_NAME[shape_name]
    d1, d2 = _analysis_depths(cfg)
    old_threshold = L.STREAM_KV_THRESHOLD
    L.STREAM_KV_THRESHOLD = 1 << 60  # disable streaming in analysis variants
    try:
        recs = []
        for depth in (d1, d2):
            ov = _depth_overrides(cfg, depth)
            if extra_cfg_overrides:
                ov.update(extra_cfg_overrides)
            kw: Dict = {"cfg_overrides": ov, "policy_kw": policy_kw}
            if shape.kind == "train":
                kw["step_cfg"] = TrainStepConfig(**(step_overrides or {}))
            rec = run_cell(arch, shape_name, multi_pod=multi_pod, **kw)
            if rec.get("status") != "OK":
                return {"arch": arch, "shape": shape_name, "status": "ANALYSIS_FAIL",
                        "error": rec.get("error")}
            recs.append(_counters(rec))
    finally:
        L.STREAM_KV_THRESHOLD = old_threshold

    full = cfg.n_layers
    out = {}
    for key in ("flops", "bytes", "wire"):
        b = (recs[1][key] - recs[0][key]) / (d2 - d1)
        a = recs[0][key] - b * d1
        out[key] = a + b * full

    n_chips = 512 if multi_pod else 256
    terms = hlo_analysis.roofline(
        flops_total=out["flops"] * n_chips,
        hbm_bytes_total=out["bytes"] * n_chips,
        wire_bytes_per_device=out["wire"],
        n_chips=n_chips,
    )
    mf = model_flops(cfg, shape)
    hlo_total = out["flops"] * n_chips
    return {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "status": "OK",
        "flops_per_dev": out["flops"], "hbm_bytes_per_dev": out["bytes"],
        "wire_bytes_per_dev": out["wire"],
        "compute_s": terms.compute_s, "memory_s": terms.memory_s,
        "collective_s": terms.collective_s, "dominant": terms.dominant,
        "bound_s": terms.bound_s,
        "model_flops": mf,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "mfu_bound": terms.mfu_bound(mf),
    }


def paged_decode_cell(arch: str = "qwen3-14b", *, n_slots: int = 8,
                      page_size: int = 16, max_pages: int = 32,
                      fill: float = 0.6, measure: bool = False) -> Dict:
    """Gather-vs-fused HBM traffic for one paged decode step (§Tentpole 6).

    The gather path pays the pooled view three times per attention layer:
    the table-indexed pool read, the materialized ``(B, max_pages *
    page_size, Hkv, D)`` write, and the attention re-read — all over the
    *full logical span* regardless of how many lanes are live.  The fused
    kernel streams each **mapped** page exactly once (unmapped blocks clamp
    to an already-resident page and are masked in compute), so its bytes
    scale with live pages.  HloCostAnalysis cannot see this (interpret-mode
    Pallas lowers to a scan whose body it counts once), so the cell is an
    analytic byte model over the same pool config, with step latency from
    the chip's HBM bandwidth; ``measure=True`` adds wall-clock per decode
    step for both scheduler backends on the reduced config (CPU: the fused
    path runs the kernel in interpret mode, so wall time there is a
    correctness proxy, not a speed claim — the bytes model is the claim).
    """
    from repro.models.config import layer_pattern
    from repro.models.layers import COMPUTE_DTYPE

    cfg = configs.get(arch)
    if cfg.family == "hybrid":
        n_attn_layers = layer_pattern(cfg).count("a")
    else:
        n_attn_layers = cfg.n_layers
    span = max_pages * page_size
    # ragged live lengths: slot i holds a deterministic fraction of the span
    lengths = [max(1, int(span * fill * (i + 1) / n_slots))
               for i in range(n_slots)]
    mapped_pages = sum(-(-l // page_size) for l in lengths)
    lane_bytes = (2 * cfg.n_kv_heads * cfg.the_head_dim()
                  * jnp_dtype_bytes(COMPUTE_DTYPE))           # K+V per token
    qo_bytes = n_slots * cfg.n_heads * cfg.the_head_dim() * 4 * 2

    gather_layer = 3 * n_slots * span * lane_bytes + qo_bytes
    fused_layer = mapped_pages * page_size * lane_bytes + qo_bytes
    gather_bytes = gather_layer * n_attn_layers
    fused_bytes = fused_layer * n_attn_layers

    flops = (4 * sum(lengths) * cfg.n_heads * cfg.the_head_dim()
             * n_attn_layers)
    g = hlo_analysis.roofline(flops_total=flops, hbm_bytes_total=gather_bytes,
                              wire_bytes_per_device=0.0, n_chips=1)
    f = hlo_analysis.roofline(flops_total=flops, hbm_bytes_total=fused_bytes,
                              wire_bytes_per_device=0.0, n_chips=1)
    out = {
        "cell": "paged_decode", "arch": arch, "status": "OK",
        "n_slots": n_slots, "page_size": page_size, "max_pages": max_pages,
        "live_tokens": sum(lengths), "mapped_pages": mapped_pages,
        "attn_layers": n_attn_layers,
        "gather_hbm_bytes": gather_bytes, "fused_hbm_bytes": fused_bytes,
        "bytes_ratio": round(gather_bytes / fused_bytes, 3),
        "gather_step_ms": round(g.bound_s * 1e3, 4),
        "fused_step_ms": round(f.bound_s * 1e3, 4),
        "fused_lt_gather": fused_bytes < gather_bytes,
    }
    if measure:
        out["measured"] = _measure_paged_decode(arch, n_slots=n_slots,
                                                page_size=page_size)
    return out


def jnp_dtype_bytes(dtype) -> int:
    import jax.numpy as jnp

    return jnp.dtype(dtype).itemsize


def _measure_paged_decode(arch: str, *, n_slots: int, page_size: int,
                          steps: int = 8) -> Dict:
    """Steady-state wall-clock per decode step, both backends, reduced cfg."""
    import time

    import jax
    import numpy as np

    from repro.models import build_model
    from repro.serve.scheduler import DecodeScheduler

    cfg = configs.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    # prompt seed is pinned for headroom: sdpa_append matches the kernel's
    # fp32 prob/accumulation discipline now, but the attention output still
    # rounds to bf16 and the two paths sum in different orders, so on this
    # 40-layer reduced config the greedy argmax can hit a last-bit tie on
    # unlucky prompts.  The gate is meaningful as long as the seed has
    # argmax headroom — a real masking/indexing bug diverges on any seed.
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=8).astype(np.int32)
               for _ in range(n_slots)]
    out: Dict = {}
    tokens: Dict = {}
    for backend in ("gather", "paged_kernel"):
        sched = DecodeScheduler(model, params, n_slots=n_slots,
                                max_seq=8 + steps, kv_mode="paged",
                                page_size=page_size, attn_backend=backend)
        for s in range(n_slots):
            sched.submit(f"s{s}", f"r{s}", prompts[s], steps)
        sched.step()                                   # admission + compile
        t0 = time.time()
        n = 0
        while sched.busy():
            sched.step()
            n += 1
        out[f"{backend}_wall_ms_per_step"] = round(
            (time.time() - t0) * 1e3 / max(n, 1), 2)
        tokens[backend] = np.asarray(sched.out_buf).copy()
    out["token_parity"] = bool(
        np.array_equal(tokens["gather"], tokens["paged_kernel"]))
    return out


def fmt_row(r: Dict) -> Dict:
    if r.get("status") != "OK":
        return {"arch": r.get("arch"), "shape": r.get("shape"),
                "dominant": "FAIL", "note": r.get("error", "")[:60]}
    return {
        "arch": r["arch"], "shape": r["shape"],
        "compute_ms": round(r["compute_s"] * 1e3, 2),
        "memory_ms": round(r["memory_s"] * 1e3, 2),
        "collective_ms": round(r["collective_s"] * 1e3, 2),
        "dominant": r["dominant"],
        "useful_ratio": round(r["useful_ratio"], 3),
        "mfu_bound_%": round(100 * r["mfu_bound"], 1),
    }


def run(cells: Optional[List[Tuple[str, str]]] = None, quick: bool = True) -> Dict:
    """Default ('quick') mode analyses one representative cell per family so
    ``python -m benchmarks.run`` stays fast; the full 33-cell table is built
    by scripts/run_roofline_matrix.py (results in EXPERIMENTS.md)."""
    if cells is None:
        cells = [("qwen3-14b", "train_4k"), ("mamba2-1.3b", "train_4k"),
                 ("moonshot-v1-16b-a3b", "train_4k"),
                 ("whisper-base", "train_4k")] if quick else [
            (a, s) for a in configs.list_archs()
            for s in configs.get(a).shapes]
    rows = []
    for arch, shape in cells:
        rows.append(analyze_cell(arch, shape))
        print(json.dumps(fmt_row(rows[-1])), flush=True)
    print(table("Roofline terms (single-pod 16x16, per step)",
                [fmt_row(r) for r in rows],
                ["arch", "shape", "compute_ms", "memory_ms", "collective_ms",
                 "dominant", "useful_ratio", "mfu_bound_%"]))
    save_artifact("roofline", rows)
    return {"rows": rows}


if __name__ == "__main__":
    import sys

    run(quick="--full" not in sys.argv)
