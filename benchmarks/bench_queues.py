"""Paper Table 7a + Fig 7b: serverless queue invocation latency & throughput.

§5.2: end-to-end latency of an empty function triggered via direct
invocation / standard SQS / SQS FIFO / DynamoDB Streams (the paper's
counter-intuitive result: FIFO is *fastest*), and the FIFO saturation
behaviour that bounds per-session throughput; plus the 160x SQS-vs-streams
cost ratio.
"""

from __future__ import annotations

from typing import Dict, List

from .common import pct_row, save_artifact, table

from repro.core import FifoQueue, SimCloud
from repro.core.functions import FunctionRuntime
from repro.core.simcloud import Sleep


def _bench_latency(n: int = 500) -> List[Dict]:
    rows = []
    for label, trigger in [("direct invoke", "direct_invoke"),
                           ("SQS standard", "std_trigger"),
                           ("SQS FIFO", "fifo_trigger"),
                           ("DynamoDB Stream", "stream_trigger")]:
        cloud = SimCloud(seed=3)
        runtime = FunctionRuntime(cloud)
        samples = []
        done = []

        def body(ctx, batch):
            # empty function returning over a warm TCP channel (§5.2: 864 us)
            yield Sleep(cloud.sample("tcp_rtt"))
            done.append(cloud.now)
            return None

        fn = runtime.wrap("probe", body)
        if label == "direct invoke":
            def driver():
                for _i in range(n):
                    t0 = cloud.now
                    task = cloud.spawn(fn([None]), name="direct",
                                       delay=cloud.sample("direct_invoke"))
                    from repro.core.simcloud import Wait
                    yield Wait((task,))
                    samples.append(cloud.now - t0)
                return None

            cloud.run_task(driver(), name="driver")
        else:
            q = FifoQueue(cloud, label, handler=fn, batch_size=1,
                          trigger_kind=trigger)

            def driver():
                for i in range(n):
                    t0 = cloud.now
                    start = len(done)
                    yield from q.push({"i": i})
                    while len(done) <= start:
                        yield Sleep(0.0005)
                    samples.append(cloud.now - t0)
                return None

            cloud.run_task(driver(), name="driver")
        rows.append(pct_row(label, samples))
    return rows


def _bench_throughput(duration: float = 10.0) -> List[Dict]:
    """Fig 7b: saturation throughput of a single FIFO queue vs batch size."""
    rows = []
    for batch_size, label in [(1, "FIFO batch=1"), (10, "FIFO batch=10 (SQS cap)"),
                              (100, "hypothetical batch=100")]:
        cloud = SimCloud(seed=4)
        runtime = FunctionRuntime(cloud)
        served = {"n": 0}

        def body(ctx, batch):
            yield Sleep(cloud.sample("fn_overhead"))
            served["n"] += len(batch)
            return None

        q = FifoQueue(cloud, "tput", handler=runtime.wrap("probe", body),
                      batch_size=batch_size)

        def producer():
            while cloud.now < duration:
                yield from q.push({"t": cloud.now})
            return None

        for _ in range(4):
            cloud.spawn(producer(), name="producer")
        cloud.run(until=duration + 2.0)
        rows.append({"config": label, "req_per_s": round(served["n"] / duration, 1)})
    return rows


def _cost_ratio() -> Dict:
    """§5.2: SQS 64 kB billing units vs DynamoDB-stream 1 kB write units."""
    sqs_per_million = 0.5
    ddb_stream_per_million_64kb = 1.25 * 64  # 64 write units per 64 kB message
    return {"sqs_usd_per_M_64kB": sqs_per_million,
            "ddb_stream_usd_per_M_64kB": ddb_stream_per_million_64kb,
            "ratio": ddb_stream_per_million_64kb / sqs_per_million}


def run() -> Dict:
    lat = _bench_latency()
    thr = _bench_throughput()
    cost = _cost_ratio()
    print(table("Table 7a — function invocation latency (ms)", lat,
                ["name", "min", "p50", "p95", "p99", "max"]))
    print(table("Fig 7b — FIFO queue throughput", thr, ["config", "req_per_s"]))
    print(f"\nSQS vs DynamoDB-streams cost ratio: {cost['ratio']:.0f}x "
          f"(paper: 160x)")
    payload = {"latency": lat, "throughput": thr, "cost": cost}
    save_artifact("bench_queues", payload)
    return payload


if __name__ == "__main__":
    run()
