"""Paper Fig 11: heartbeat function time & daily monitoring cost.

§5.5: execution time of the scheduled heartbeat (scan sessions table + ping
clients in parallel) across memory allocations and client counts, and the
daily cost at 1-per-minute scheduling — the "fraction of VM price" claim.
"""

from __future__ import annotations

from typing import Dict

from .common import ms, save_artifact, table
from repro.core.cost import VM_DAILY, f as fn_cost
from tests.conftest import make_service


def run() -> Dict:
    rows = []
    for n_clients in (4, 16, 64):
        for memory_mb in (512, 1024, 2048):
            cloud, svc = make_service(seed=8, function_memory_mb=memory_mb)
            clients = [svc.connect_sync(f"c{i}") for i in range(n_clients)]
            for i, c in enumerate(clients):
                c.create(f"/eph{i}", b"x", ephemeral=True)
            svc.start_heartbeat(period=60.0, max_runs=10)
            cloud.run()
            runtimes = svc.runtime.stats["heartbeat"].runtimes
            mean_rt = sum(runtimes) / len(runtimes)
            invocations_per_day = 24 * 60  # highest AWS schedule frequency
            daily = invocations_per_day * fn_cost(mean_rt, memory_mb)
            rows.append({
                "clients": n_clients,
                "memory_MB": memory_mb,
                "mean_ms": ms(mean_rt),
                "daily_usd": round(daily, 4),
                "vs_t3small_%": round(100 * daily / VM_DAILY["t3.small"], 2),
                "alloc_time_%_of_day": round(
                    100 * invocations_per_day * mean_rt / 86400, 3),
            })
    print(table("Fig 11 — heartbeat runtime and daily monitoring cost", rows,
                ["clients", "memory_MB", "mean_ms", "daily_usd",
                 "vs_t3small_%", "alloc_time_%_of_day"]))
    payload = {"rows": rows}
    save_artifact("bench_heartbeat", payload)
    return payload


if __name__ == "__main__":
    run()
